"""Backward-compatibility shim -- the cost model moved to ``repro.cost``.

The trip-count-aware HLO cost model grew into an instruction-level
memory-traffic accounting subsystem (normalized parsing, per-op byte
attribution with in-place/slice aliasing rules, ``Cost.by_op``
category breakdown, version-normalized ``cost_analysis()``).  See
``src/repro/cost/README.md``.  Existing imports keep working:

    from repro import hlo_cost
    hlo_cost.analyze_text(...)  # same API, corrected accounting
"""

from __future__ import annotations

from repro.cost import (COLLECTIVE_OPS, Cost, HloCostModel,  # noqa: F401
                        analyze_text, analyze_compiled, attribute,
                        shape_bytes, shape_dims, xla_cost_analysis)
from repro.cost.parser import Instr, parse_instruction  # noqa: F401
