"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any model
built on ``lax.scan`` (every serious JAX model) under-reports FLOPs by
~the layer count, and nested chunk scans compound it.  This module
re-derives the three roofline quantities from the optimized HLO with
loop trip counts multiplied through:

  * flops            -- 2 * prod(result_dims) * prod(contracting_dims)
                        for every ``dot`` (matmuls dominate; elementwise
                        work is deliberately excluded, as in MFU math)
  * bytes            -- per instruction: result + operand bytes
                        (fusion internals excluded -- they don't touch
                        HBM), i.e. XLA's "bytes accessed" convention
  * collective bytes -- output bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute,
                        by kind

Trip counts: a scan's ``while`` condition compares the induction
variable against a literal ``constant(N)``; we take the largest s32
constant in the condition computation.

All quantities are per-partition (the dry-run compiles the SPMD
partitioned module), which is exactly the per-chip roofline input.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")


def shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    """All 'dtype[d0,d1]' tokens in a (possibly tuple) shape string."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str        # result shape string (may be a tuple)
    opcode: str
    operands: List[str]
    attrs: str


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # rest = "<shape> <opcode>(<args>), attrs..."  shape may be a tuple
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        shape = rest[: i + 1]
        rest2 = rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        rest2 = rest[sp + 1:].strip()
    pm = re.match(r"([\w\-]+)\((.*)$", rest2, re.DOTALL)
    if not pm:
        return None
    opcode = pm.group(1)
    tail = pm.group(2)
    depth = 1
    for i, ch in enumerate(tail):
        depth += ch == "("
        depth -= ch == ")"
        if depth == 0:
            break
    args = tail[:i]
    attrs = tail[i + 1:]
    operands = re.findall(r"%([\w\.\-]+)", args)
    return Instr(name, shape, opcode, operands, attrs)


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    body: List[Instr] = []
    for line in hlo.splitlines():
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
            if m:
                cur = m.group(1)
                body = []
                if line.startswith("ENTRY"):
                    comps["__entry__"] = body
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur] = body if cur not in comps or comps[cur] is not body \
                else comps[cur]
            comps.setdefault(cur, body)
            comps[cur] = body
            cur = None
            continue
        ins = _parse_instr(line)
        if ins:
            body.append(ins)
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in COLLECTIVE_OPS}

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k in COLLECTIVE_OPS:
            self.coll[k] += other.coll[k] * times

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id"}


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        # constant values need the raw args; reparse constants crudely
        self._const: Dict[Tuple[str, str], int] = {}
        cur = None
        for line in hlo_text.splitlines():
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
            if m and not line.strip().startswith("%constant"):
                cur = m.group(1)
                continue
            cm = re.match(r"\s*(?:ROOT\s+)?%([\w\.\-]+) = s32\[\] "
                          r"constant\((\d+)\)", line)
            if cm and cur:
                self._const[(cur, cm.group(1))] = int(cm.group(2))
        self._memo: Dict[str, Cost] = {}

    def _symtab(self, comp: List[Instr]) -> Dict[str, str]:
        return {i.name: i.shape for i in comp}

    def trip_count(self, cond_name: str) -> int:
        vals = [v for (c, _), v in self._const.items() if c == cond_name]
        return max(vals) if vals else 1

    def _dot_flops(self, ins: Instr, sym: Dict[str, str]) -> float:
        res = 1
        for _, dims in shape_dims(ins.shape):
            for d in dims:
                res *= d
        lhs = sym.get(ins.operands[0]) if ins.operands else None
        contract = 1
        if lhs:
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
            ldims = shape_dims(lhs)
            if m and ldims:
                dims = ldims[0][1]
                for i in (int(x) for x in m.group(1).split(",") if x):
                    if i < len(dims):
                        contract *= dims[i]
        return 2.0 * res * contract

    def cost_of(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name, [])
        sym = self._symtab(comp)
        total = Cost()
        self._memo[name] = total        # cycle guard
        for ins in comp:
            op = ins.opcode
            if op == "dot":
                total.flops += self._dot_flops(ins, sym)
            elif op == "convolution":
                # flops ~ 2 * result * (kernel spatial * in_ch): approximate
                # with result * operand1 elements (rare in this codebase)
                res = shape_bytes(ins.shape) / 2
                total.flops += 2.0 * res
            elif op == "while":
                body = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                cond = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                trips = self.trip_count(cond.group(1)) if cond else 1
                if body:
                    total.add(self.cost_of(body.group(1)), trips)
                if cond:
                    total.add(self.cost_of(cond.group(1)), trips)
            elif op in ("call", "fusion", "conditional", "map",
                        "reduce", "reduce-window", "sort", "scatter"):
                for m in re.finditer(
                        r"(?:calls|to_apply|branch_computations)="
                        r"\{?%?([\w\.\-,% ]+)\}?", ins.attrs):
                    for c in re.findall(r"[\w\.\-]+", m.group(1)):
                        sub = self.cost_of(c)
                        # fusion internals: flops yes, bytes no
                        total.flops += sub.flops
                        for k in COLLECTIVE_OPS:
                            total.coll[k] += sub.coll[k]
            if op in COLLECTIVE_OPS or any(
                    op == f"{c}-start" for c in COLLECTIVE_OPS):
                kind = op.replace("-start", "")
                total.coll[kind] += shape_bytes(ins.shape)
            if op not in _SKIP_BYTES:
                if op == "dynamic-update-slice":
                    # in-place: traffic = update read + slice write, NOT
                    # the whole buffer (XLA aliases operand 0)
                    upd = (shape_bytes(sym[ins.operands[1]])
                           if len(ins.operands) > 1 and ins.operands[1] in sym
                           else shape_bytes(ins.shape))
                    total.bytes += 2 * upd
                elif op == "dynamic-slice":
                    total.bytes += 2 * shape_bytes(ins.shape)
                elif op == "gather":
                    total.bytes += 2 * shape_bytes(ins.shape)
                elif op == "scatter":
                    upd = (shape_bytes(sym[ins.operands[2]])
                           if len(ins.operands) > 2 and ins.operands[2] in sym
                           else shape_bytes(ins.shape))
                    total.bytes += 2 * upd
                else:
                    b = shape_bytes(ins.shape)
                    for o in ins.operands:
                        if o in sym:
                            b += shape_bytes(sym[o])
                    total.bytes += b
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        if "__entry__" in self.comps:
            return self.cost_of("__entry__")
        # fall back: largest computation
        name = max(self.comps, key=lambda n: len(self.comps[n]))
        return self.cost_of(name)


def analyze_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()


def attribute(hlo_text: str, top: int = 20, min_bytes: float = 1e11):
    """Per-(opcode, shape) byte attribution with trip multipliers --
    the §Perf profiling tool (what dominates the memory term?)."""
    import collections
    model = HloCostModel(hlo_text)
    tally = collections.Counter()

    def walk(name, mult):
        comp = model.comps.get(name, [])
        sym = {i.name: i.shape for i in comp}
        for ins in comp:
            op = ins.opcode
            if op == "while":
                b = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                c = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                t = model.trip_count(c.group(1)) if c else 1
                if b:
                    walk(b.group(1), mult * t)
                continue
            if op in _SKIP_BYTES:
                continue
            if op == "dynamic-update-slice":
                upd = (shape_bytes(sym[ins.operands[1]])
                       if len(ins.operands) > 1 and ins.operands[1] in sym
                       else 0)
                b = 2 * upd
            elif op in ("dynamic-slice", "gather"):
                b = 2 * shape_bytes(ins.shape)
            else:
                b = shape_bytes(ins.shape)
                for o in ins.operands:
                    if o in sym:
                        b += shape_bytes(sym[o])
            bm = b * mult
            key = (op, ins.shape[:48] if bm > min_bytes else "(small)")
            tally[key] += bm

    walk("__entry__", 1)
    return tally.most_common(top)
