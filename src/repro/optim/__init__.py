from repro.optim.adamw import (AdamWConfig, AdamWState, apply_updates,
                               cosine_lr, global_norm, init_state,
                               state_specs)
from repro.optim import compression

__all__ = ["AdamWConfig", "AdamWState", "apply_updates", "cosine_lr",
           "global_norm", "init_state", "state_specs", "compression"]
