"""Error-feedback int8 gradient compression for the DP gradient sync.

At 1000+ node scale the data-parallel gradient reduction is the largest
recurring collective.  This module compresses gradients to int8 with a
per-block scale (block = the paper's fixed-size quantum: 8192 f32 values
= 32 KB) and keeps the quantization residual in an error-feedback buffer
so the bias cancels across steps (1-bit Adam lineage).

Usage: the compressed train step (train/compressed.py) computes
per-device gradients inside ``repro.compat.shard_map`` (the version-
portable spelling) over the data axes and calls ``sync_mean`` instead of
``psum``:

  1. add residual to the local gradient,
  2. quantize to int8 + f32 per-block scales,
  3. all_gather (int8, scales) over the data axes -- 4x fewer bytes than
     an f32 all-gather, ~2x fewer than bf16 ring all-reduce traffic;
     (a psum of int8 would overflow, and XLA's all-reduce cannot carry
     per-shard scales),
  4. dequantize + average locally; store the new residual.

The collective-bytes saving is measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

BLOCK = 8192  # f32 values per scale block (the paper's 32 KB quantum)


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: flat f32, length multiple of BLOCK -> (int8 codes, f32 scales)."""
    xb = x.reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale[:, None]).reshape(-1)


def flatten_tree(tree) -> Tuple[jax.Array, Any, List]:
    """Pytree -> (padded flat f32 vector, treedef, shapes)."""
    flat, treedef = jax.tree.flatten(tree)
    shapes = [(f.shape, f.size) for f in flat]
    parts = []
    for f in flat:
        v = f.astype(jnp.float32).reshape(-1)
        parts.append(jnp.pad(v, (0, (-v.size) % BLOCK)))
    return jnp.concatenate(parts), treedef, shapes


def unflatten_tree(vec: jax.Array, treedef, shapes):
    out, off = [], 0
    for shp, n in shapes:
        out.append(jax.lax.dynamic_slice_in_dim(vec, off, n).reshape(shp))
        off += n + ((-n) % BLOCK)
    return treedef.unflatten(out)


def sync_mean(vec: jax.Array, residual: jax.Array,
              axes: Tuple[str, ...]) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: error-feedback int8 mean over ``axes``.

    vec/residual: this device's flat gradient + residual (full length).
    Returns (mean vector, new residual).
    """
    v = vec + residual
    q, s = quantize(v)
    new_r = v - dequantize(q, s)
    qg = jax.lax.all_gather(q, axes)          # (n, blocks, BLOCK)
    sg = jax.lax.all_gather(s, axes)          # (n, blocks)
    qg = qg.reshape(-1, *q.shape)
    sg = sg.reshape(-1, *s.shape)
    n = qg.shape[0]
    total = jnp.sum(jax.vmap(dequantize)(qg, sg), axis=0)
    return total / n, new_r
