"""AdamW with global-norm clipping and cosine schedule, from scratch.

State shards like the parameters (same logical axes), so TP-sharded
weights get TP-sharded moments for free.  Optional ZeRO-1: moments are
additionally sharded over the data axis (rule override in the launcher)
-- legal because moments are elementwise, and XLA inserts the
reduce-scatter/all-gather pair around the update.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array       # ()
    mu: Any               # pytree like params
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * \
        (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def state_specs(param_specs) -> AdamWState:
    sds = jax.ShapeDtypeStruct
    zeros = jax.tree.map(lambda p: sds(p.shape, jnp.float32), param_specs)
    return AdamWState(sds((), jnp.int32), zeros, zeros)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads,
                  state: AdamWState) -> Tuple[Any, AdamWState, Dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
