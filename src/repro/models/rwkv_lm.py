"""RWKV6 language model: stacked (time-mix + channel-mix) blocks.

Attention-free: decode state is O(1) per layer (matrix state + two
token-shift vectors), so the paged-KV machinery is inapplicable by
design (DESIGN.md §5) -- long_500k runs here precisely because of that.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.shardings import constrain
from repro.models import rwkv6 as R
from repro.models.common import (AxTree, Params, chunked_lm_loss,
                                 dense_init, rmsnorm)
from repro.models.lm import _stack_axes, eval_shape_with_aux


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RWKVState:
    """Decode state: (L,B,d) shift vectors + (L,B,H,dk,dk) wkv state."""
    mix_x: jax.Array
    ffn_x: jax.Array
    wkv: jax.Array

    def tree_flatten(self):
        return (self.mix_x, self.ffn_x, self.wkv), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


class RWKVLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _init_layer(self, rng):
        cfg = self.cfg
        r1, r2 = jax.random.split(rng)
        mix, mix_ax = R.init_rwkv6_mix(r1, cfg)
        ffn, ffn_ax = R.init_rwkv6_ffn(r2, cfg)
        p = {"mix": mix, "ffn": ffn,
             "ln1": jnp.zeros((cfg.d_model,), cfg.jdtype),
             "ln2": jnp.zeros((cfg.d_model,), cfg.jdtype)}
        ax = AxTree(mix=mix_ax, ffn=ffn_ax, ln1=(None,), ln2=(None,))
        return p, ax

    def init(self, rng) -> Tuple[Params, AxTree]:
        cfg = self.cfg
        r = jax.random.split(rng, 3)
        p: Params = {
            "embed": dense_init(r[0], cfg.vocab_size, cfg.d_model,
                                cfg.jdtype, scale=1.0),
            "ln_in": jnp.zeros((cfg.d_model,), cfg.jdtype),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.jdtype),
            "lm_head": dense_init(r[1], cfg.d_model, cfg.vocab_size,
                                  cfg.jdtype),
        }
        ax = AxTree(embed=("vocab", "embed"), ln_in=(None,),
                    final_norm=(None,), lm_head=("embed", "vocab"))
        rngs = jax.random.split(r[2], cfg.num_layers)
        p["layers"] = jax.vmap(lambda rr: self._init_layer(rr)[0])(rngs)
        _, lax_ = eval_shape_with_aux(self._init_layer, jax.random.PRNGKey(0))
        ax["layers"] = _stack_axes(lax_)
        return p, ax

    def param_specs(self):
        return eval_shape_with_aux(lambda rr: self.init(rr),
                                   jax.random.PRNGKey(0))

    def _layer(self, lp, x, state=None, lengths=None):
        """state: None (train from zeros) or (mix_x, ffn_x, wkv);
        ``lengths`` masks right padding out of the recurrence and picks
        the shift vectors at each row's true last position."""
        cfg = self.cfg
        mix_x = state.mix_x if state else None
        wkv = state.wkv if state else None
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps, gemma_style=True)
        y, (last_x, wkv_out) = R.rwkv6_mix_fwd(lp["mix"], h, cfg,
                                               prev_x=mix_x, state_in=wkv,
                                               lengths=lengths)
        x = constrain(x + y, "batch", "seq", None)
        h = rmsnorm(x, lp["ln2"], cfg.norm_eps, gemma_style=True)
        prev = (state.ffn_x[:, None] if state
                else jnp.zeros_like(h[:, :1]))
        hh = jnp.concatenate([prev, h[:, :-1]], axis=1)
        x = constrain(x + R.rwkv6_ffn(lp["ffn"], h, hh), "batch", "seq", None)
        if lengths is not None:
            idx = (lengths.astype(jnp.int32) - 1)[:, None, None]
            last_h = jnp.take_along_axis(h, idx, axis=1)[:, 0]
        else:
            last_h = h[:, -1]
        new_state = RWKVState(last_x, last_h, wkv_out)
        return x, new_state

    def forward_hidden(self, p: Params, batch: Dict[str, jax.Array], *,
                       remat: bool = False, state: "RWKVState" = None,
                       lengths=None, **_):
        cfg = self.cfg
        x = rmsnorm(p["embed"][batch["tokens"]], p["ln_in"], cfg.norm_eps,
                    gemma_style=True)
        x = constrain(x, "batch", None, None)

        def body(x, xs):
            if state is None:
                lp = xs
                st = None
            else:
                lp, st = xs
            x, new_st = self._layer(lp, x, st, lengths=lengths)
            return x, new_st

        body_fn = jax.checkpoint(body) if remat else body
        xs = p["layers"] if state is None else (p["layers"], state)
        x, states = jax.lax.scan(body_fn, x, xs)
        return x, jnp.zeros((), jnp.float32), states

    def forward(self, p, batch, **kw):
        x, aux, states = self.forward_hidden(p, batch, **kw)
        cfg = self.cfg
        logits = (rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
                  @ p["lm_head"]).astype(jnp.float32)
        return logits, aux, states

    def loss(self, p, batch, *, remat: bool = False, **_):
        cfg = self.cfg
        x, _, _ = self.forward_hidden(p, batch, remat=remat)
        xn = rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
        nll, cnt = chunked_lm_loss(xn, p["lm_head"], batch["targets"])
        loss = nll / jnp.maximum(cnt, 1.0)
        return loss, {"nll": loss}

    # ---------------- serving ----------------
    def init_state(self, batch: int) -> RWKVState:
        cfg = self.cfg
        L, d, H = cfg.num_layers, cfg.d_model, cfg.num_heads
        dk = d // H
        return RWKVState(jnp.zeros((L, batch, d), cfg.jdtype),
                         jnp.zeros((L, batch, d), cfg.jdtype),
                         jnp.zeros((L, batch, H, dk, dk), jnp.float32))

    def state_specs(self, batch: int) -> RWKVState:
        return jax.eval_shape(lambda: self.init_state(batch))

    def decode_state_specs(self, batch: int, max_seq: int = 0,
                           num_blocks=None, dp_groups: int = 1):
        """Shape specs of the decode-time state (dry-run surface)."""
        return self.state_specs(batch)

    # -- constant-state pool glue (ConstantStateStrategy surface) --
    @property
    def state_elems(self) -> int:
        """Float32 elements of ONE sequence's decode state -- the
        constant-state pool's (exact) block quantum: two shift vectors
        and the per-head wkv matrix state, per layer."""
        cfg = self.cfg
        d, H = cfg.d_model, cfg.num_heads
        dk = d // H
        return cfg.num_layers * (2 * d + H * dk * dk)

    def state_to_rows(self, state: RWKVState) -> jax.Array:
        """Flatten the (L, B, ...) state to (B, state_elems) rows."""
        B = state.mix_x.shape[1]
        m = jnp.moveaxis(state.mix_x, 1, 0).reshape(B, -1)
        f = jnp.moveaxis(state.ffn_x, 1, 0).reshape(B, -1)
        w = jnp.moveaxis(state.wkv, 1, 0).reshape(B, -1)
        return jnp.concatenate([m, f, w], axis=1).astype(jnp.float32)

    def rows_to_state(self, rows: jax.Array) -> RWKVState:
        """Inverse of ``state_to_rows`` (shift vectors back in the
        compute dtype; the wkv state stays float32)."""
        cfg = self.cfg
        L, d, H = cfg.num_layers, cfg.d_model, cfg.num_heads
        dk = d // H
        B = rows.shape[0]
        m = jnp.moveaxis(rows[:, : L * d].reshape(B, L, d), 0, 1
                         ).astype(cfg.jdtype)
        f = jnp.moveaxis(rows[:, L * d: 2 * L * d].reshape(B, L, d), 0, 1
                         ).astype(cfg.jdtype)
        w = jnp.moveaxis(rows[:, 2 * L * d:].reshape(B, L, H, dk, dk), 0, 1)
        return RWKVState(m, f, w)

    def prefill(self, p, batch, state: RWKVState, lengths=None):
        logits, _, states = self.forward(p, batch, state=state,
                                         lengths=lengths)
        if lengths is None:
            return logits[:, -1], states
        idx = (lengths.astype(jnp.int32) - 1)[:, None, None]
        return jnp.take_along_axis(logits, idx, axis=1)[:, 0], states

    def decode_step(self, p: Params, tokens: jax.Array, state: RWKVState):
        cfg = self.cfg
        x = rmsnorm(p["embed"][tokens], p["ln_in"], cfg.norm_eps,
                    gemma_style=True)

        def body(x, xs):
            lp, mix_x, ffn_x, wkv = xs
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps, gemma_style=True)
            y, (last_x, wkv_new) = R.rwkv6_mix_step(lp["mix"], h, cfg,
                                                    mix_x, wkv)
            x = x + y
            h = rmsnorm(x, lp["ln2"], cfg.norm_eps, gemma_style=True)
            x = x + R.rwkv6_ffn(lp["ffn"], h, ffn_x)
            return x, (last_x, h, wkv_new)

        x, (mix_x, ffn_x, wkv) = jax.lax.scan(
            body, x, (p["layers"], state.mix_x, state.ffn_x, state.wkv))
        logits = (rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
                  @ p["lm_head"]).astype(jnp.float32)
        return logits, RWKVState(mix_x, ffn_x, wkv)
