"""Attention layers: GQA/MQA (+ local windows, softcap, qk-norm) and MLA.

Each layer exposes:
  init(rng, cfg)                  -> (params, axes)
  fwd(params, x, cfg, layer_meta) -> y                  (training/prefill)
  fwd_kv(...)                     -> y, (k, v)          (prefill: KV out)
  decode(params, x, cache slices) -> y, new kv          (one token, paged)

Decode reads the paged pool through the reference gather path (what the
dry-run lowers); on TPU the Pallas ``paged_attention`` kernel implements
the same contract (tests assert equality).  MLA decode uses the
**absorbed** form: only the compressed latent stream (kv_lora + rope) is
cached -- the paper's block-quantum argument taken to its logical end.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ref as kref
from repro.launch import shardings as SH
from repro.models.common import (AxTree, Params, apply_rope, dense_init,
                                 flash_attention, head_rmsnorm)

_NEG = -1e30


# ===================== GQA =====================
def init_gqa(rng, cfg: ModelConfig) -> Tuple[Params, AxTree]:
    d, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.hd
    r = jax.random.split(rng, 4)
    p = {"wq": dense_init(r[0], d, H * hd, cfg.jdtype),
         "wk": dense_init(r[1], d, KVH * hd, cfg.jdtype),
         "wv": dense_init(r[2], d, KVH * hd, cfg.jdtype),
         "wo": dense_init(r[3], H * hd, d, cfg.jdtype)}
    ax = AxTree(wq=("embed", "attn_heads"), wk=("embed", "attn_heads"),
                wv=("embed", "attn_heads"), wo=("attn_heads", "embed"))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.jdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.jdtype)
        ax["q_norm"] = (None,)
        ax["k_norm"] = (None,)
    return p, ax


def _gqa_qkv(p: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
             rope_theta: Optional[float] = None):
    B, S, d = x.shape
    H, KVH, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KVH, hd)
    v = (x @ p["wv"]).reshape(B, S, KVH, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = head_rmsnorm(k, p["k_norm"], cfg.norm_eps)
    # static gate on cfg (traced per-layer theta allowed, e.g. gemma3's
    # dual local/global rope base)
    if cfg.rope_theta > 0:
        theta = rope_theta if rope_theta is not None else cfg.rope_theta
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def gqa_fwd_kv(p: Params, x: jax.Array, cfg: ModelConfig, *,
               window: Optional[int], positions: jax.Array,
               causal: bool = True, q_chunk: int = 1024,
               rope_theta=None):
    """Full-sequence attention; returns output and (k, v) for prefill."""
    q, k, v = _gqa_qkv(p, x, cfg, positions, rope_theta)
    B, S = x.shape[:2]
    if SH.use_ctx_parallel(cfg.num_heads):
        # context parallelism: query sequence over 'model', heads whole
        q = SH.constrain(q, "batch", "ctx", None, None)
        k = SH.constrain(k, "batch", None, None, None)
        v = SH.constrain(v, "batch", None, None, None)
        o = flash_attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.attn_softcap, scale=cfg.query_scale,
                            q_chunk=S)
        o = SH.constrain(o, "batch", "ctx", None, None)
    else:
        o = flash_attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.attn_softcap, scale=cfg.query_scale,
                            q_chunk=q_chunk)
    return o.reshape(B, S, -1) @ p["wo"], (k, v)


def gqa_fwd(p, x, cfg, *, window=None, positions=None, causal=True,
            q_chunk=1024):
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    y, _ = gqa_fwd_kv(p, x, cfg, window=window, positions=positions,
                      causal=causal, q_chunk=q_chunk)
    return y


def gqa_decode(p: Params, x: jax.Array, cfg: ModelConfig,
               k_pool: jax.Array, v_pool: jax.Array,
               block_tables: jax.Array, seq_lens: jax.Array, *,
               window: Optional[jax.Array] = None, rope_theta=None,
               dp_groups: int = 1):
    """One-token decode against the paged pool.

    x: (B, d) hidden of the new token.  k_pool/v_pool: (NB, BT, KVH, hd)
    this layer's slices.  Returns (y (B, d), (k_new, v_new)) -- the
    caller writes k_new/v_new into the pool at seq_lens (pre-advance).
    ``window``: None or traced scalar (0 => global) so local/global
    layers share one scanned body.
    """
    B, d = x.shape
    H, KVH, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
    positions = seq_lens[:, None]                     # new token's position
    q, k, v = _gqa_qkv(p, x[:, None], cfg, positions, rope_theta)
    q = q[:, 0].reshape(B, KVH, H // KVH, hd)
    k_new, v_new = k[:, 0], v[:, 0]                   # (B, KVH, hd)
    # pin the decode-attention layout: kv-head sharded when divisible,
    # otherwise batch-only (replicated over 'model' -- decode attention
    # FLOPs are negligible, and an ambiguous layout makes GSPMD all-
    # gather the whole pool carry, see EXPERIMENTS.md §Perf cell B)
    tp = SH.tp_size()
    if tp > 1:
        # pin ONLY q and the output: pinning k_new/v_new too fights the
        # pool's propagated layout and makes XLA re-lay-out the whole
        # stacked pool accumulator every layer (measured 10x memory)
        ha = "heads" if KVH % tp == 0 else None
        q = SH.constrain(q, "batch", ha, None, None)
    scale = cfg.query_scale if cfg.query_scale is not None else hd ** -0.5

    # attention over cached tokens (the new token is merged below):
    o_c, l_c, m_c = _paged_ref(q, k_pool, v_pool, block_tables, seq_lens,
                               scale=scale, softcap=cfg.attn_softcap,
                               window=window, dp_groups=dp_groups)
    # new token attends to itself (always inside any window):
    s_self = jnp.einsum("bhgd,bhd->bhg", q.astype(jnp.float32) * scale,
                        k_new.astype(jnp.float32))
    if cfg.attn_softcap is not None:
        s_self = cfg.attn_softcap * jnp.tanh(s_self / cfg.attn_softcap)
    o = _merge_self(o_c, l_c, m_c, s_self,
                    v_new[:, :, None, :].astype(jnp.float32))
    if tp > 1:
        o = SH.constrain(o, "batch", "heads" if KVH % tp == 0 else None,
                         None, None)
    y = o.reshape(B, H * hd).astype(x.dtype) @ p["wo"]
    return y, (k_new, v_new)


def _scatter_span(pool_l, kv, write_tables, bt: int, dp_groups: int = 1):
    """Scatter a block-aligned token span into the pool.

    kv: (B, SQ, KVH, hd) with SQ % bt == 0; write_tables: (B, SQ // bt)
    physical block ids.  Aliased (COW-shared) and padding positions carry
    the sink block id: those writes land in the sink block and are never
    read back.  Group-batched when dp_groups > 1.
    """
    B, SQ = kv.shape[:2]
    nb = SQ // bt
    val = kv.astype(pool_l.dtype).reshape(B, nb, bt, *kv.shape[2:])
    if dp_groups <= 1:
        return pool_l.at[write_tables.reshape(B * nb)].set(
            val.reshape(B * nb, bt, *kv.shape[2:]))
    NBl = pool_l.shape[0] // dp_groups
    Bl = B // dp_groups
    pg = pool_l.reshape(dp_groups, NBl, *pool_l.shape[1:])
    out = jax.vmap(lambda pl, tb, vv: pl.at[tb].set(vv))(
        pg, write_tables.reshape(dp_groups, Bl * nb),
        val.reshape(dp_groups, Bl * nb, bt, *kv.shape[2:]))
    return out.reshape(pool_l.shape)


def gqa_prefill_paged(p: Params, x: jax.Array, cfg: ModelConfig,
                      k_pool: jax.Array, v_pool: jax.Array,
                      block_tables: jax.Array, kv_lens: jax.Array,
                      q_starts: jax.Array, write_tables: jax.Array, *,
                      window: Optional[jax.Array] = None, rope_theta=None,
                      dp_groups: int = 1):
    """Suffix-only prefill against the paged pool (COW prefix sharing).

    x: (B, SQ, d) hiddens of the un-cached suffix; row b's token i sits
    at absolute position q_starts[b] + i.  The suffix's KV is scattered
    into the pool FIRST (through ``write_tables`` -- sink where the block
    is aliased from the parent, which already holds identical values),
    then every suffix query attends *through the block table* to the
    whole prefix+suffix with causal masking offset by the cached length.
    Prefix sharing thereby saves FLOPs, not just bytes.

    Returns (y (B, SQ, d), (k_pool, v_pool) updated).  On TPU the Pallas
    ``kernels.paged_prefill`` kernel implements the same contract (tests
    assert equality); this reference path is what the dry-run lowers.
    """
    B, SQ, _ = x.shape
    H, KVH, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
    bt = k_pool.shape[1]
    positions = q_starts[:, None] + jnp.arange(SQ)[None, :]
    q, k, v = _gqa_qkv(p, x, cfg, positions, rope_theta)
    k_pool = _scatter_span(k_pool, k, write_tables, bt, dp_groups)
    v_pool = _scatter_span(v_pool, v, write_tables, bt, dp_groups)
    qh = q.reshape(B, SQ, KVH, H // KVH, hd)
    scale = cfg.query_scale if cfg.query_scale is not None else hd ** -0.5
    o = _paged_prefill_ref(qh, k_pool, v_pool, block_tables, kv_lens,
                           positions, scale=scale, softcap=cfg.attn_softcap,
                           window=window, dp_groups=dp_groups)
    y = o.reshape(B, SQ, H * hd).astype(x.dtype) @ p["wo"]
    return y, (k_pool, v_pool)


def _paged_prefill_ref(q, k_pool, v_pool, block_tables, kv_lens, positions, *,
                       scale: float, softcap: Optional[float],
                       window: Optional[jax.Array],
                       v_dim: Optional[int] = None, dp_groups: int = 1):
    """Reference suffix-prefill attention through the block table.

    q: (B, SQ, KVH, G, Dk); positions: (B, SQ) absolute query positions.
    Same masking conventions as ``_paged_ref`` but causal per query row
    (kv <= q) with the window anchored at each query (kv > q - window,
    traced scalar, 0 => global).  Fully-masked rows return 0.
    """
    B, SQ, KVH, G, Dk = q.shape
    NB, BT = k_pool.shape[:2]
    MB = block_tables.shape[1]
    Dv = v_dim if v_dim is not None else v_pool.shape[-1]

    tbl = jnp.maximum(block_tables, 0)
    k = _grouped_gather(k_pool, tbl, dp_groups).reshape(B, MB * BT, KVH, -1)
    v = _grouped_gather(v_pool, tbl, dp_groups
                        ).reshape(B, MB * BT, KVH, -1)[..., :Dv]
    s = jnp.einsum("bqhgd,bshd->bhgqs", (q * scale).astype(k.dtype), k,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kv_pos = jnp.arange(MB * BT)[None, None, :]
    q_abs = positions[:, :, None]                     # (B, SQ, 1)
    valid = jnp.logical_and(kv_pos <= q_abs,
                            kv_pos < kv_lens[:, None, None])
    if window is not None:
        lo = jnp.where(window > 0, q_abs - window + 1, -1)
        valid &= kv_pos >= lo
    validb = valid[:, None, None, :, :]               # (B,1,1,SQ,S)
    s = jnp.where(validb, s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    pr = jnp.exp(s - m) * validb
    l = jnp.sum(pr, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqs,bshd->bqhgd",
                   (pr / jnp.maximum(l, 1e-30)).astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o


def _grouped_gather(pool, tbl, dp_groups: int):
    """pool (NB, BT, ...), tbl (B, MB) of group-LOCAL ids when dp_groups>1.

    The dp dimension is a *batch* dimension of the gather, so under GSPMD
    (pool blocks and batch co-sharded over the data axes) every shard
    gathers only from its own pool range -- no cross-device block motion.
    """
    if dp_groups <= 1:
        return pool[tbl]
    NB, B = pool.shape[0], tbl.shape[0]
    pg = pool.reshape(dp_groups, NB // dp_groups, *pool.shape[1:])
    tg = tbl.reshape(dp_groups, B // dp_groups, tbl.shape[1])
    out = jax.vmap(lambda pl, tb: pl[tb])(pg, tg)
    return out.reshape(B, tbl.shape[1], *pool.shape[1:])


def _merge_self(o_c, l_c, m_c, s_self, v_self):
    """Numerically-stable merge of cached-attention stats with the
    current token's score.  o_c: (B,KVH,G,Dv) normalized; l_c, m_c, s_self:
    (B,KVH,G); v_self: (B,KVH,1,Dv) broadcastable."""
    m_new = jnp.maximum(m_c, s_self)
    a_c = jnp.exp(m_c - m_new) * l_c                  # cached mass
    a_s = jnp.exp(s_self - m_new)                     # self mass
    denom = jnp.maximum(a_c + a_s, 1e-30)
    return (o_c * a_c[..., None] + v_self * a_s[..., None]) / denom[..., None]


def _paged_ref(q, k_pool, v_pool, block_tables, seq_lens, *,
               scale: float, softcap: Optional[float],
               window: Optional[jax.Array], v_dim: Optional[int] = None,
               dp_groups: int = 1):
    """Reference paged attention returning normalized output plus the
    softmax stats (l, m) so callers can merge the not-yet-written current
    token exactly.

    q: (B, KVH, G, Dk).  Returns (o (B,KVH,G,Dv), l (B,KVH,G), m (B,KVH,G)).
    Fully-masked rows (seq_len == 0) return l == 0, m == -1e30, o == 0.
    """
    B, KVH, G, Dk = q.shape
    NB, BT = k_pool.shape[:2]
    MB = block_tables.shape[1]
    Dv = v_dim if v_dim is not None else v_pool.shape[-1]

    tbl = jnp.maximum(block_tables, 0)
    k = _grouped_gather(k_pool, tbl, dp_groups).reshape(B, MB * BT, KVH, -1)
    v = _grouped_gather(v_pool, tbl, dp_groups
                        ).reshape(B, MB * BT, KVH, -1)[..., :Dv]
    # bf16 operands + f32 accumulation (MXU-style): avoids materializing
    # f32 copies of the gathered KV views, the largest decode tensors
    s = jnp.einsum("bhgd,bshd->bhgs", (q * scale).astype(k.dtype), k,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(MB * BT)[None, :]
    valid = pos < seq_lens[:, None]
    if window is not None:
        lo = jnp.where(window > 0, seq_lens[:, None] - window + 1,
                       jnp.full_like(seq_lens, -1)[:, None])
        valid &= pos >= lo
    validb = valid[:, None, None, :]
    s = jnp.where(validb, s, _NEG)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None]) * validb            # masked rows -> 0
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32) / \
        jnp.maximum(l, 1e-30)[..., None]
    return o, l, m


# ===================== MLA =====================
def init_mla(rng, cfg: ModelConfig) -> Tuple[Params, AxTree]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    r = jax.random.split(rng, 8)
    p: Params = {}
    ax = AxTree()
    if m.q_lora_rank:
        p["wq_a"] = dense_init(r[0], d, m.q_lora_rank, cfg.jdtype)
        p["q_a_norm"] = jnp.ones((m.q_lora_rank,), cfg.jdtype)
        p["wq_b"] = dense_init(r[1], m.q_lora_rank, H * qk, cfg.jdtype)
        ax.update(wq_a=("embed", None), q_a_norm=(None,),
                  wq_b=(None, "attn_heads"))
    else:
        p["wq"] = dense_init(r[0], d, H * qk, cfg.jdtype)
        ax["wq"] = ("embed", "attn_heads")
    # joint compressed kv + shared rope key
    p["wkv_a"] = dense_init(r[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                            cfg.jdtype)
    p["kv_a_norm"] = jnp.ones((m.kv_lora_rank,), cfg.jdtype)
    p["wk_b"] = dense_init(r[3], m.kv_lora_rank, H * m.qk_nope_head_dim,
                           cfg.jdtype)
    p["wv_b"] = dense_init(r[4], m.kv_lora_rank, H * m.v_head_dim, cfg.jdtype)
    p["wo"] = dense_init(r[5], H * m.v_head_dim, d, cfg.jdtype)
    ax.update(wkv_a=("embed", None), kv_a_norm=(None,),
              wk_b=(None, "attn_heads"), wv_b=(None, "attn_heads"),
              wo=("attn_heads", "embed"))
    return p, ax


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    B, S = x.shape[:2]
    H = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        from repro.models.common import rmsnorm
        qa = rmsnorm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
        q = (qa @ p["wq_b"]).reshape(B, S, H, qk)
    else:
        q = (x @ p["wq"]).reshape(B, S, H, qk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, positions):
    """x -> (c_kv normalized (B,S,lora), k_rope (B,S,rope))."""
    m = cfg.mla
    from repro.models.common import rmsnorm
    kv_a = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_a_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_fwd_kv(p: Params, x: jax.Array, cfg: ModelConfig, *,
               positions: jax.Array, q_chunk: int = 1024):
    """Training/prefill MLA (decompressed form). Returns y and the latent
    stream (c_kv || k_rope) for the paged cache."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)
    k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (c_kv @ p["wv_b"]).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if SH.use_ctx_parallel(H):
        q = SH.constrain(q, "batch", "ctx", None, None)
        k = SH.constrain(k, "batch", None, None, None)
        v = SH.constrain(v, "batch", None, None, None)
        o = flash_attention(q, k, v, causal=True, softcap=cfg.attn_softcap,
                            scale=scale, q_chunk=S)
        o = SH.constrain(o, "batch", "ctx", None, None)
    else:
        o = flash_attention(q, k, v, causal=True, softcap=cfg.attn_softcap,
                            scale=scale, q_chunk=q_chunk)
    y = o.reshape(B, S, -1) @ p["wo"]
    latent = jnp.concatenate([c_kv, k_rope], axis=-1)  # (B, S, latent_dim)
    return y, latent


def mla_fwd(p, x, cfg, *, positions=None, q_chunk=1024, **_):
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    y, _ = mla_fwd_kv(p, x, cfg, positions=positions, q_chunk=q_chunk)
    return y


def mla_decode(p: Params, x: jax.Array, cfg: ModelConfig,
               c_pool: jax.Array, block_tables: jax.Array,
               seq_lens: jax.Array, dp_groups: int = 1, **_):
    """Absorbed-MLA decode over the latent paged pool.

    c_pool: (NB, BT, 1, latent_dim) where latent = kv_lora || k_rope.
    Scores: q_nope^T W_kb^T c + q_rope^T k_rope  ==  q_eff . latent
    with q_eff = [W_kb^T q_nope, q_rope].  Output: (attn @ c) absorbed
    through W_vb then W_o.  Cache traffic per token: latent_dim values
    instead of H*(nope+v) -- 576 vs 4096 for deepseek-v2-lite.
    """
    m = cfg.mla
    B, _ = x.shape
    H = cfg.num_heads
    positions = seq_lens[:, None]
    q_nope, q_rope = _mla_q(p, x[:, None], cfg, positions)  # (B,1,H,*)
    # absorb W_kb: (B,H,lora)
    wk_b = p["wk_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    q_eff = jnp.concatenate([q_lat, q_rope[:, 0].astype(jnp.float32)],
                            axis=-1)[:, None]          # (B,1,H,latent)
    q_eff = q_eff.reshape(B, 1, H, m.latent_dim)

    c_new, k_rope_new = _mla_latent(p, x[:, None], cfg, positions)
    latent_new = jnp.concatenate([c_new, k_rope_new], axis=-1)[:, 0]  # (B,lat)

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    q_pr = q_eff[:, 0][:, None]                       # (B, KVH=1, G=H, lat)
    o_c, l_c, m_c = _paged_ref(q_pr, c_pool, c_pool, block_tables, seq_lens,
                               scale=scale, softcap=None, window=None,
                               v_dim=m.kv_lora_rank, dp_groups=dp_groups)
    # merge the new token (self-attention term)
    s_self = jnp.einsum("bhd,bd->bh", q_eff[:, 0].astype(jnp.float32) * scale,
                        latent_new.astype(jnp.float32))[:, None]  # (B,1,H)
    c_self = latent_new[:, : m.kv_lora_rank].astype(jnp.float32)
    o = _merge_self(o_c, l_c, m_c, s_self,
                    c_self[:, None, None, :])          # (B,1,H,lora)
    o = o.reshape(B, H, m.kv_lora_rank)
    # un-absorb through W_vb then W_o
    wv_b = p["wv_b"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o_v = jnp.einsum("bhl,lhv->bhv", o, wv_b.astype(jnp.float32))
    y = o_v.reshape(B, H * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return y, latent_new


def mla_decode_split(p: Params, x: jax.Array, cfg: ModelConfig,
                     c_pool: jax.Array, r_pool: jax.Array,
                     block_tables: jax.Array, seq_lens: jax.Array,
                     dp_groups: int = 1):
    """Latent-TP absorbed-MLA decode: the kv_lora stream (c_pool,
    (NB, BT, lora)) is shardable over 'model' on its last dim; the rope
    stream (r_pool, (NB, BT, rope)) stays replicated.  The score is the
    SUM of two contractions, so partitioning the lora contraction yields
    partial scores + one tiny psum (inserted by GSPMD).

    Returns (y, (c_new (B, lora), rope_new (B, rope))).
    """
    m = cfg.mla
    B, _ = x.shape
    H = cfg.num_heads
    positions = seq_lens[:, None]
    q_nope, q_rope = _mla_q(p, x[:, None], cfg, positions)   # (B,1,H,*)
    wk_b = p["wk_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))             # (B,H,lora)
    q_r = q_rope[:, 0].astype(jnp.float32)                   # (B,H,rope)
    c_new, rope_new = _mla_latent(p, x[:, None], cfg, positions)
    c_new, rope_new = c_new[:, 0], rope_new[:, 0]

    tbl = jnp.maximum(block_tables, 0)
    MB = tbl.shape[1]
    BT = c_pool.shape[1]
    k_lora = _grouped_gather(c_pool, tbl, dp_groups).reshape(
        B, MB * BT, m.kv_lora_rank)
    k_rope = _grouped_gather(r_pool, tbl, dp_groups).reshape(
        B, MB * BT, m.qk_rope_head_dim)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bhl,bsl->bhs", q_lat * scale,
                    k_lora.astype(jnp.float32)) +
         jnp.einsum("bhr,bsr->bhs", q_r * scale,
                    k_rope.astype(jnp.float32)))
    pos = jnp.arange(MB * BT)[None, :]
    valid = (pos < seq_lens[:, None])[:, None, :]
    s = jnp.where(valid, s, _NEG)
    mx = jnp.max(s, axis=-1)
    pr = jnp.exp(s - mx[..., None]) * valid
    l = jnp.sum(pr, axis=-1)
    o = jnp.einsum("bhs,bsl->bhl", pr, k_lora.astype(jnp.float32)) / \
        jnp.maximum(l, 1e-30)[..., None]                     # (B,H,lora)
    # merge the new (unwritten) token
    s_self = (jnp.einsum("bhl,bl->bh", q_lat * scale,
                         c_new.astype(jnp.float32)) +
              jnp.einsum("bhr,br->bh", q_r * scale,
                         rope_new.astype(jnp.float32)))
    o = _merge_self(o[:, None], l[:, None], mx[:, None], s_self[:, None],
                    c_new.astype(jnp.float32)[:, None, None, :])[:, 0]
    wv_b = p["wv_b"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o_v = jnp.einsum("bhl,lhv->bhv", o, wv_b.astype(jnp.float32))
    y = o_v.reshape(B, H * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return y, (c_new, rope_new)
