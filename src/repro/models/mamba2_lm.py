"""Pure Mamba2 (SSD) language model: stacked mamba2 blocks, no attention.

Decode state is O(1) per layer -- a conv window plus the SSD matrix
state -- so serving uses the constant-state pool discipline (one
fixed-size Arena block per sequence, zero growth) instead of paged KV:
the `ConstantStateStrategy` in ``serve/arch.py``.  Prefill masks right
padding exactly (``mamba2_fwd(lengths=...)``), so a padded batched
prefill is token-identical to per-sequence prefill.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.shardings import constrain
from repro.models import mamba2 as M2
from repro.models.common import (AxTree, Params, chunked_lm_loss,
                                 dense_init, rmsnorm)
from repro.models.lm import _stack_axes, eval_shape_with_aux


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Mamba2State:
    """Decode state: (L,B,W-1,cd) conv windows + (L,B,H,P,N) SSD state."""
    conv: jax.Array
    ssd: jax.Array

    def tree_flatten(self):
        return (self.conv, self.ssd), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


class Mamba2LM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.ssm is not None and cfg.ssm.kind == "mamba2"
        self.cfg = cfg

    def _init_layer(self, rng):
        cfg = self.cfg
        m, max_ = M2.init_mamba2(rng, cfg)
        p = {"mamba": m, "ln": jnp.zeros((cfg.d_model,), cfg.jdtype)}
        return p, AxTree(mamba=max_, ln=(None,))

    def init(self, rng) -> Tuple[Params, AxTree]:
        cfg = self.cfg
        r = jax.random.split(rng, 3)
        p: Params = {
            "embed": dense_init(r[0], cfg.vocab_size, cfg.d_model,
                                cfg.jdtype, scale=1.0),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.jdtype),
        }
        ax = AxTree(embed=("vocab", "embed"), final_norm=(None,))
        rngs = jax.random.split(r[1], cfg.num_layers)
        p["layers"] = jax.vmap(lambda rr: self._init_layer(rr)[0])(rngs)
        _, lax_ = eval_shape_with_aux(self._init_layer, jax.random.PRNGKey(0))
        ax["layers"] = _stack_axes(lax_)
        return p, ax

    def param_specs(self):
        return eval_shape_with_aux(lambda rr: self.init(rr),
                                   jax.random.PRNGKey(0))

    def forward_hidden(self, p: Params, batch: Dict[str, jax.Array], *,
                       remat: bool = False,
                       state: Optional[Mamba2State] = None,
                       lengths: Optional[jax.Array] = None, **_):
        cfg = self.cfg
        x = p["embed"][batch["tokens"]]
        x = constrain(x, "batch", None, None)

        def body(x, xs):
            if state is None:
                lp = xs
                cs = ss = None
            else:
                lp, cs, ss = xs
            h = rmsnorm(x, lp["ln"], cfg.norm_eps, gemma_style=True)
            y, (cs_o, ss_o) = M2.mamba2_fwd(lp["mamba"], h, cfg, cs, ss,
                                            lengths=lengths)
            return constrain(x + y, "batch", "seq", None), (cs_o, ss_o)

        body_fn = jax.checkpoint(body) if remat else body
        xs = (p["layers"] if state is None
              else (p["layers"], state.conv, state.ssd))
        x, (conv, ssd) = jax.lax.scan(body_fn, x, xs)
        return x, jnp.zeros((), jnp.float32), Mamba2State(conv, ssd)

    def forward(self, p, batch, **kw):
        cfg = self.cfg
        x, aux, state = self.forward_hidden(p, batch, **kw)
        logits = (rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
                  @ p["embed"].T).astype(jnp.float32)
        return logits, aux, state

    def loss(self, p, batch, *, remat: bool = False, **_):
        cfg = self.cfg
        x, _, _ = self.forward_hidden(p, batch, remat=remat)
        xn = rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
        nll, cnt = chunked_lm_loss(xn, p["embed"].T, batch["targets"])
        loss = nll / jnp.maximum(cnt, 1.0)
        return loss, {"nll": loss}

    # ---------------- serving ----------------
    def init_state(self, batch: int) -> Mamba2State:
        cfg = self.cfg
        d_inner, H, P, N, W = M2._dims(cfg)
        L = cfg.num_layers
        return Mamba2State(
            jnp.zeros((L, batch, W - 1, d_inner + 2 * N), jnp.float32),
            jnp.zeros((L, batch, H, P, N), jnp.float32))

    def state_specs(self, batch: int) -> Mamba2State:
        return jax.eval_shape(lambda: self.init_state(batch))

    def decode_state_specs(self, batch: int, max_seq: int,
                           num_blocks: Optional[int] = None,
                           dp_groups: int = 1):
        return self.state_specs(batch)

    def prefill(self, p, batch, state: Mamba2State, lengths):
        logits, _, states = self.forward(p, batch, state=state,
                                         lengths=lengths)
        idx = jnp.maximum(lengths - 1, 0)
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
        return last, states

    def decode_step(self, p: Params, tokens: jax.Array, state: Mamba2State):
        cfg = self.cfg
        x = p["embed"][tokens]

        def body(x, xs):
            lp, cs, ss = xs
            h = rmsnorm(x, lp["ln"], cfg.norm_eps, gemma_style=True)
            y, (cs, ss) = M2.mamba2_step(lp["mamba"], h, cfg, cs, ss)
            return x + y, (cs, ss)

        x, (conv, ssd) = jax.lax.scan(
            body, x, (p["layers"], state.conv, state.ssd))
        logits = (rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
                  @ p["embed"].T).astype(jnp.float32)
        return logits, Mamba2State(conv, ssd)

    # -- constant-state pool glue (serve/arch.ConstantStateStrategy) --
    @property
    def state_elems(self) -> int:
        """Float32 elements of ONE sequence's recurrent state -- the
        constant-state pool's (exact) block quantum."""
        d_inner, H, P, N, W = M2._dims(self.cfg)
        L = self.cfg.num_layers
        return L * ((W - 1) * (d_inner + 2 * N) + H * P * N)

    def state_to_rows(self, state: Mamba2State) -> jax.Array:
        """Flatten the (L, B, ...) state to (B, state_elems) rows."""
        B = state.conv.shape[1]
        c = jnp.moveaxis(state.conv, 1, 0).reshape(B, -1)
        s = jnp.moveaxis(state.ssd, 1, 0).reshape(B, -1)
        return jnp.concatenate([c, s], axis=1).astype(jnp.float32)

    def rows_to_state(self, rows: jax.Array) -> Mamba2State:
        """Inverse of ``state_to_rows``."""
        d_inner, H, P, N, W = M2._dims(self.cfg)
        L = self.cfg.num_layers
        B = rows.shape[0]
        cd = d_inner + 2 * N
        csize = L * (W - 1) * cd
        conv = jnp.moveaxis(rows[:, :csize].reshape(B, L, W - 1, cd), 0, 1)
        ssd = jnp.moveaxis(rows[:, csize:].reshape(B, L, H, P, N), 0, 1)
        return Mamba2State(conv, ssd)
