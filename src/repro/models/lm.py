"""Generic decoder-only LM covering qwen3-moe, deepseek-v2-lite, minicpm3,
gemma2/3, gemma-2b and internvl2 (LM backbone + stub image embeddings).

Layers are stacked and consumed by ``lax.scan`` (compile time flat in
depth).  Per-layer heterogeneity (local/global window, rope theta, moe
vs dense) rides along as scanned metadata arrays; MoE models with leading
dense layers run those outside the main scan.

Decode threads the PagedKVCache's per-layer pool slices through the scan
(xs in, updated ys out) -- one block-table lookup schedule shared by all
layers, which is the paper's single-arena/many-tenants design.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.paged_kv import PagedKVCache, PagedKVConfig
from repro.launch.shardings import constrain
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models.moe_sharded import moe_ffn_dispatch
from repro.models.common import (AxTree, Params, chunked_lm_loss, dense_init,
                                 init_mlp, mlp, rmsnorm, stacked)

_NEG = -1e30


def write_token_paged(pool_l, kv_new, tables, seq_lens, bt,
                      dp_groups: int = 1):
    """Scatter one token's KV into the pool at each sequence's current
    position.  Group-batched when dp_groups > 1 (see PagedKVConfig)."""
    B = tables.shape[0]
    phys = tables[jnp.arange(B), seq_lens // bt]
    off = seq_lens % bt
    val = kv_new.astype(pool_l.dtype)
    if dp_groups <= 1:
        return pool_l.at[phys, off].set(val)
    NBl = pool_l.shape[0] // dp_groups
    Bl = B // dp_groups
    pg = pool_l.reshape(dp_groups, NBl, *pool_l.shape[1:])
    out = jax.vmap(lambda pl, ph, of, vv: pl.at[ph, of].set(vv))(
        pg, phys.reshape(dp_groups, Bl), off.reshape(dp_groups, Bl),
        val.reshape(dp_groups, Bl, *val.shape[1:]))
    return out.reshape(pool_l.shape)


def _stack_axes(ax):
    return jax.tree.map(lambda t: ("layers",) + t, ax,
                        is_leaf=lambda t: isinstance(t, tuple))


def eval_shape_with_aux(fn, *args):
    """eval_shape for a function returning (params, aux) where aux is a
    non-JAX pytree (logical-axis tuples): returns (shapes, aux)."""
    cell = {}

    def wrapped(*a):
        p, ax = fn(*a)
        cell["ax"] = ax
        return p

    shapes = jax.eval_shape(wrapped, *args)
    return shapes, cell["ax"]


class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_moe = cfg.moe is not None
        self.n_dense = cfg.moe.first_dense_layers if self.is_moe else 0
        self.n_scan = cfg.num_layers - self.n_dense
        # jitted serving callables (decode_step / prefill / prefill_suffix).
        # jax.jit's signature cache keys the traces by input shape, i.e.
        # by (batch, padded seq, table width) bucket; the engine pads its
        # batches so steady-state steps always hit a warm trace.
        self._jit_cache: Dict[str, Any] = {}

    # ---------------- params ----------------
    def _init_layer(self, rng, moe_layer: bool):
        cfg = self.cfg
        r1, r2 = jax.random.split(rng)
        if cfg.attention == "mla":
            attn, attn_ax = A.init_mla(r1, cfg)
        else:
            attn, attn_ax = A.init_gqa(r1, cfg)
        if moe_layer:
            ff, ff_ax = MOE.init_moe(r2, cfg)
        else:
            ff, ff_ax = init_mlp(r2, cfg.d_model, cfg.d_ff, cfg.jdtype)
        p = {"attn": attn, "ff": ff,
             "ln1": jnp.zeros((cfg.d_model,), cfg.jdtype),
             "ln2": jnp.zeros((cfg.d_model,), cfg.jdtype)}
        ax = AxTree(attn=attn_ax, ff=ff_ax, ln1=(None,), ln2=(None,))
        if cfg.post_norms:
            p["ln1_post"] = jnp.zeros((cfg.d_model,), cfg.jdtype)
            p["ln2_post"] = jnp.zeros((cfg.d_model,), cfg.jdtype)
            ax["ln1_post"] = (None,)
            ax["ln2_post"] = (None,)
        return p, ax

    def init(self, rng) -> Tuple[Params, AxTree]:
        cfg = self.cfg
        r = jax.random.split(rng, 5)
        p: Params = {"embed": dense_init(r[0], cfg.vocab_size, cfg.d_model,
                                         cfg.jdtype, scale=1.0),
                     "final_norm": jnp.zeros((cfg.d_model,), cfg.jdtype)}
        ax = AxTree(embed=("vocab", "embed"), final_norm=(None,))
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(r[1], cfg.d_model, cfg.vocab_size,
                                      cfg.jdtype)
            ax["lm_head"] = ("embed", "vocab")
        rngs = jax.random.split(r[2], self.n_scan)
        p["layers"] = jax.vmap(
            lambda rr: self._init_layer(rr, self.is_moe)[0])(rngs)
        _, layer_ax = eval_shape_with_aux(
            lambda rr: self._init_layer(rr, self.is_moe),
            jax.random.PRNGKey(0))
        ax["layers"] = _stack_axes(layer_ax)
        if self.n_dense:
            rngs = jax.random.split(r[3], self.n_dense)
            p["dense_layers"] = jax.vmap(
                lambda rr: self._init_layer(rr, False)[0])(rngs)
            _, dax = eval_shape_with_aux(
                lambda rr: self._init_layer(rr, False), jax.random.PRNGKey(0))
            ax["dense_layers"] = _stack_axes(dax)
        if cfg.num_image_tokens:
            p["img_proj"] = dense_init(r[4], cfg.d_model, cfg.d_model,
                                       cfg.jdtype)
            ax["img_proj"] = ("embed", "embed")
        return p, ax

    def param_specs(self):
        """(ShapeDtypeStruct tree, axes tree) without allocating."""
        return eval_shape_with_aux(
            lambda rr: self.init(rr), jax.random.PRNGKey(0))

    # ---------------- per-layer metadata ----------------
    def _layer_meta(self, which: str):
        """Scanned metadata arrays for layers [n_dense:)."""
        cfg = self.cfg
        idxs = range(self.n_dense, cfg.num_layers)
        windows = jnp.asarray(
            [(cfg.local_window if cfg.layer_is_local(i) else 0) or 0
             for i in idxs], jnp.int32)
        thetas = jnp.asarray(
            [(cfg.rope_theta_local if (cfg.layer_is_local(i) and
                                       cfg.rope_theta_local) else
              cfg.rope_theta) for i in idxs], jnp.float32)
        return windows, thetas

    # ---------------- embedding / head ----------------
    def _embed(self, p, batch):
        cfg = self.cfg
        x = p["embed"][batch["tokens"]]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if cfg.num_image_tokens:
            img = batch["image_embeds"].astype(x.dtype) @ p["img_proj"]
            x = jnp.concatenate([img, x], axis=1)
        return x

    def _head(self, p, x):
        cfg = self.cfg
        w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
        logits = rmsnorm(x, p["final_norm"], cfg.norm_eps,
                         gemma_style=True) @ w
        logits = logits.astype(jnp.float32)
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits

    # ---------------- layer body (training) ----------------
    def _layer_fwd(self, lp, x, positions, window, theta, moe_layer: bool,
                   q_chunk: int, collect_kv: bool):
        cfg = self.cfg
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps, gemma_style=True)
        if cfg.attention == "mla":
            y, latent = A.mla_fwd_kv(lp["attn"], h, cfg, positions=positions,
                                     q_chunk=q_chunk)
            kv = (latent, None)       # uniform (k-like, v-like) tuple
        else:
            y, kv = A.gqa_fwd_kv(lp["attn"], h, cfg, window=window,
                                 positions=positions, q_chunk=q_chunk,
                                 rope_theta=theta)
        if cfg.post_norms:
            y = rmsnorm(y, lp["ln1_post"], cfg.norm_eps, gemma_style=True)
        x = x + y
        x = constrain(x, "batch", "seq", None)
        h = rmsnorm(x, lp["ln2"], cfg.norm_eps, gemma_style=True)
        aux = jnp.zeros((), jnp.float32)
        if moe_layer:
            y, aux = moe_ffn_dispatch(lp["ff"], h, cfg)
        else:
            y = mlp(h, lp["ff"], cfg.mlp)
        if cfg.post_norms:
            y = rmsnorm(y, lp["ln2_post"], cfg.norm_eps, gemma_style=True)
        x = x + y
        x = constrain(x, "batch", "seq", None)
        return x, aux, (kv if collect_kv else None)

    # ---------------- forward (train / prefill) ----------------
    def forward_hidden(self, p: Params, batch: Dict[str, jax.Array], *,
                       q_chunk: int = 1024, remat: bool = False,
                       collect_kv: bool = False):
        """Returns (final hidden x, aux_loss, kv_stack or None)."""
        cfg = self.cfg
        x = self._embed(p, batch)
        B, S, _ = x.shape
        positions = jnp.arange(S)[None, :]
        x = constrain(x, "batch", None, None)

        aux_total = jnp.zeros((), jnp.float32)
        dense_kv = []
        for i in range(self.n_dense):
            lp = jax.tree.map(lambda t: t[i], p["dense_layers"])
            x, aux, kv = self._layer_fwd(lp, x, positions, None, None, False,
                                         q_chunk, collect_kv)
            aux_total += aux
            dense_kv.append(kv)

        windows, thetas = self._layer_meta("scan")

        def body(carry, xs):
            x, aux_acc = carry
            lp, window, theta = xs
            x, aux, kv = self._layer_fwd(lp, x, positions, window, theta,
                                         self.is_moe, q_chunk, collect_kv)
            return (x, aux_acc + aux), kv

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux_total), kv_stack = jax.lax.scan(
            body_fn, (x, aux_total), (p["layers"], windows, thetas))
        if collect_kv and self.n_dense:
            kv_stack = (dense_kv, kv_stack)
        return x, aux_total, kv_stack

    def forward(self, p: Params, batch: Dict[str, jax.Array], *,
                q_chunk: int = 1024, remat: bool = False,
                collect_kv: bool = False):
        """Returns (logits, aux_loss, kv_stack or None)."""
        x, aux, kv = self.forward_hidden(p, batch, q_chunk=q_chunk,
                                         remat=remat, collect_kv=collect_kv)
        return self._head(p, x), aux, kv

    def loss(self, p: Params, batch: Dict[str, jax.Array], *,
             remat: bool = False, q_chunk: int = 1024):
        cfg = self.cfg
        x, aux, _ = self.forward_hidden(p, batch, remat=remat,
                                        q_chunk=q_chunk)
        if cfg.num_image_tokens:            # loss only on text positions
            x = x[:, cfg.num_image_tokens:]
        xn = rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
        w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
        nll, cnt = chunked_lm_loss(xn, w, batch["targets"],
                                   final_softcap=cfg.final_softcap)
        loss = nll / jnp.maximum(cnt, 1.0)
        if self.is_moe:
            loss = loss + cfg.moe.router_aux_coef * aux / max(1, self.n_scan)
        return loss, {"nll": loss, "aux": aux}

    # ---------------- serving ----------------
    def kv_config(self, max_seq: int, num_blocks: Optional[int] = None,
                  batch: int = 1, dp_groups: int = 1) -> PagedKVConfig:
        cfg = self.cfg
        bt = cfg.kv_block_tokens
        mbs = (max_seq + bt - 1) // bt
        latent = cfg.attention == "mla"
        split = latent and cfg.mla_latent_tp
        if latent:
            hd = cfg.mla.kv_lora_rank if split else cfg.mla.latent_dim
        else:
            hd = cfg.hd
        return PagedKVConfig(
            num_layers=cfg.num_layers,
            kv_heads=1 if latent else cfg.kv_heads,
            head_dim=hd,
            block_tokens=bt,
            num_blocks=num_blocks if num_blocks else mbs * batch,
            max_blocks_per_seq=mbs,
            latent=latent,
            latent_rope=(cfg.mla.qk_rope_head_dim if split else 0),
            dtype=jnp.dtype(cfg.dtype),
            dp_groups=dp_groups)

    def decode_state_specs(self, batch: int, max_seq: int,
                           num_blocks: Optional[int] = None,
                           dp_groups: int = 1):
        """Shape specs of the decode-time state (dry-run surface; every
        model exposes this so ``api.decode_specs`` never dispatches on
        model type)."""
        kvcfg = self.kv_config(max_seq=max_seq, num_blocks=num_blocks,
                               batch=batch, dp_groups=dp_groups)
        return PagedKVCache.specs(kvcfg, batch)

    def _write_token(self, pool_l, kv_new, tables, seq_lens, bt,
                     dp_groups: int = 1):
        return write_token_paged(pool_l, kv_new, tables, seq_lens, bt,
                                 dp_groups)

    @property
    def supports_suffix_prefill(self) -> bool:
        """Suffix-only prefill reads prefix KV through the block table --
        implemented for the GQA/MQA pool layout; MLA falls back to full
        recompute."""
        return self.cfg.attention != "mla"

    def _jitted(self, name: str, fn):
        """One jitted trace per serving entry point, shared by EVERY
        caller (engine and reference decoders alike) so token-identity
        comparisons never cross a jit/eager numerics boundary.  The
        PagedKVCache argument (position 2 in all three) is donated: its
        pool buffers are reused in place on backends that support it."""
        j = self._jit_cache.get(name)
        if j is None:
            j = jax.jit(fn, donate_argnums=(2,))
            self._jit_cache[name] = j
        return j

    def decode_step(self, p: Params, tokens: jax.Array,
                    cache: PagedKVCache):
        """tokens: (B,) -> (logits (B, V), updated cache).  Runs the
        cached jitted trace -- steady-state decode is one Python dispatch
        into a warm executable."""
        return self._jitted("decode_step", self._decode_step_impl)(
            p, tokens, cache)

    def _decode_step_impl(self, p: Params, tokens: jax.Array,
                          cache: PagedKVCache):
        cfg = self.cfg
        bt = cache.config.block_tokens
        x = p["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        x = constrain(x, "batch", None)
        tables, lens = cache.block_tables, cache.seq_lens
        dp = cache.config.dp_groups

        def layer_decode(lp, x, k_pool_l, v_pool_l, window, theta):
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps, gemma_style=True)
            if cfg.attention == "mla" and cache.config.latent_rope:
                y, (c_new, r_new) = A.mla_decode_split(
                    lp["attn"], h, cfg, k_pool_l, v_pool_l, tables, lens,
                    dp_groups=dp)
                k_pool_l = self._write_token(k_pool_l, c_new, tables, lens,
                                             bt, dp)
                v_pool_l = self._write_token(v_pool_l, r_new, tables, lens,
                                             bt, dp)
            elif cfg.attention == "mla":
                y, latent_new = A.mla_decode(lp["attn"], h, cfg, k_pool_l,
                                             tables, lens, dp_groups=dp)
                k_pool_l = self._write_token(k_pool_l, latent_new,
                                             tables, lens, bt, dp)
                v_pool_l = None
            else:
                y, (k_new, v_new) = A.gqa_decode(
                    lp["attn"], h, cfg, k_pool_l, v_pool_l, tables, lens,
                    window=window, rope_theta=theta, dp_groups=dp)
                k_pool_l = self._write_token(k_pool_l, k_new, tables, lens,
                                             bt, dp)
                v_pool_l = self._write_token(v_pool_l, v_new, tables, lens,
                                             bt, dp)
            if cfg.post_norms:
                y = rmsnorm(y, lp["ln1_post"], cfg.norm_eps, gemma_style=True)
            x = x + y
            h = rmsnorm(x, lp["ln2"], cfg.norm_eps, gemma_style=True)
            if self.is_moe and "router" in lp["ff"]:
                y, _ = moe_ffn_dispatch(lp["ff"], h[:, None], cfg)
                y = y[:, 0]
            else:
                y = mlp(h, lp["ff"], cfg.mlp)
            if cfg.post_norms:
                y = rmsnorm(y, lp["ln2_post"], cfg.norm_eps, gemma_style=True)
            x = x + y
            return constrain(x, "batch", None), k_pool_l, v_pool_l

        # leading dense layers (deepseek): unscanned
        for i in range(self.n_dense):
            lp = jax.tree.map(lambda t: t[i], p["dense_layers"])
            kp = cache.k_pool[i]
            vp = cache.v_pool[i] if cache.v_pool is not None else None
            x, kp, vp = layer_decode(lp, x, kp, vp, None, None)
            cache = dataclasses.replace(
                cache, k_pool=cache.k_pool.at[i].set(kp),
                v_pool=(cache.v_pool.at[i].set(vp)
                        if vp is not None else cache.v_pool))

        windows, thetas = self._layer_meta("scan")

        # pools thread through the scan as xs -> ys (each layer's slice
        # written once to the stacked output).  A carry-with-DUS variant
        # was tried and REFUTED: XLA copies the whole carry per
        # iteration under the read-modify-write (EXPERIMENTS.md §Perf).
        def body(x, xs):
            if cache.v_pool is None:
                lp, kp, window, theta = xs
                vp = None
            else:
                lp, kp, vp, window, theta = xs
            x, kp, vp = layer_decode(lp, x, kp, vp, window, theta)
            ys = (kp,) if vp is None else (kp, vp)
            return x, ys

        k_scan = cache.k_pool[self.n_dense:]
        if cache.v_pool is None:
            xs = (p["layers"], k_scan, windows, thetas)
        else:
            xs = (p["layers"], k_scan, cache.v_pool[self.n_dense:],
                  windows, thetas)
        x, pools = jax.lax.scan(body, x, xs)
        k_new = (cache.k_pool.at[self.n_dense:].set(pools[0])
                 if self.n_dense else pools[0])
        if cache.v_pool is None:
            v_new = None
        else:
            v_new = (cache.v_pool.at[self.n_dense:].set(pools[1])
                     if self.n_dense else pools[1])
        cache = dataclasses.replace(cache, k_pool=k_new, v_pool=v_new,
                                    seq_lens=cache.seq_lens + 1)
        logits = self._head(p, x[:, None] if x.ndim == 2 else x)
        return logits.reshape(tokens.shape[0], -1), cache

    def decode_fused(self, p: Params, tokens: jax.Array,
                     cache: PagedKVCache, upd_slots: jax.Array,
                     upd_tables: jax.Array, upd_lens: jax.Array):
        """Resident decode tail: delta-scatter + decode + argmax in ONE
        jitted, cache-donated trace.  ``upd_slots`` (W,) names the slots
        whose mapping changed since the last step (padded with
        ``slots``, dropped by the scatter); their rows/lens are spliced
        into the device-resident table before the step.  Returns
        ``(next_tokens (B,), cache)`` -- only the (B,) token array ever
        crosses to host.  W is shape-bucketed, so steady state (W = 1
        bucket or 0 dirty rows) reuses one warm executable."""
        return self._jitted("decode_fused", self._decode_fused_impl)(
            p, tokens, cache, upd_slots, upd_tables, upd_lens)

    def _decode_fused_impl(self, p: Params, tokens: jax.Array,
                           cache: PagedKVCache, upd_slots: jax.Array,
                           upd_tables: jax.Array, upd_lens: jax.Array):
        tables = cache.block_tables.at[upd_slots].set(upd_tables,
                                                      mode="drop")
        lens = cache.seq_lens.at[upd_slots].set(upd_lens, mode="drop")
        cache = dataclasses.replace(cache, block_tables=tables,
                                    seq_lens=lens)
        # Same math as decode_step: _decode_step_impl is inlined into
        # this trace, so resident vs eager token-identity is structural.
        logits, cache = self._decode_step_impl(p, tokens, cache)
        return jnp.argmax(logits, axis=-1), cache

    def prefill(self, p: Params, batch: Dict[str, jax.Array],
                cache: PagedKVCache, lengths: jax.Array):
        """Run the forward pass and write the whole prompt's KV stream.

        batch["tokens"]: (B, S) block-aligned.  Returns (last_logits,
        cache with seq_lens = lengths).  Jit-cached per (B, S) bucket.
        """
        return self._jitted("prefill", self._prefill_impl)(
            p, batch, cache, lengths)

    def _prefill_impl(self, p: Params, batch: Dict[str, jax.Array],
                      cache: PagedKVCache, lengths: jax.Array):
        cfg = self.cfg
        logits, _, kv_stack = self.forward(p, batch, collect_kv=True)
        if self.n_dense:
            dense_kv, kv_scan = kv_stack
            k_all = jnp.concatenate([kv[0][None] for kv in dense_kv]
                                    + [kv_scan[0]], axis=0)
            v_all = (None if kv_scan[1] is None else jnp.concatenate(
                [kv[1][None] for kv in dense_kv] + [kv_scan[1]], axis=0))
        else:
            k_all, v_all = kv_stack
        if cfg.attention == "mla" and cache.config.latent_rope:
            lora = cfg.mla.kv_lora_rank
            cache = cache.write_prefill(k_all[..., :lora],
                                        k_all[..., lora:], lengths)
        elif cfg.attention == "mla":
            # latent stream (L, B, S, latent); the latent pool is headless
            cache = cache.write_prefill(k_all, None, lengths)
        else:
            cache = cache.write_prefill(k_all, v_all, lengths)
        idx = jnp.maximum(lengths - 1, 0)
        last = jnp.take_along_axis(
            logits, idx[:, None, None], axis=1)[:, 0]
        return last, cache

    def prefill_suffix(self, p: Params, tokens: jax.Array,
                       cache: PagedKVCache, lengths: jax.Array,
                       starts: jax.Array, write_tables: jax.Array):
        """Suffix-only prefill: run the forward pass over just the
        un-cached tail of each prompt, attending through the block table
        to the COW-shared prefix blocks.  Jit-cached per (B, SQ) bucket.

        tokens: (B, SQ) block-aligned suffix tokens; row b's token i sits
        at absolute position starts[b] + i (starts block-aligned).
        lengths: (B,) full prompt lengths.  write_tables: (B, SQ // bt)
        physical destinations for the suffix KV (sink where the block is
        aliased from the parent).  Returns (last_logits, cache with
        seq_lens = lengths).  Requires ``supports_suffix_prefill``.
        """
        return self._jitted("prefill_suffix", self._prefill_suffix_impl)(
            p, tokens, cache, lengths, starts, write_tables)

    def _prefill_suffix_impl(self, p: Params, tokens: jax.Array,
                             cache: PagedKVCache, lengths: jax.Array,
                             starts: jax.Array, write_tables: jax.Array):
        cfg = self.cfg
        assert cfg.attention != "mla", "suffix prefill is GQA/MQA-only"
        bt = cache.config.block_tokens
        x = p["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        x = constrain(x, "batch", None, None)
        tables = cache.block_tables
        dp = cache.config.dp_groups

        def layer_suffix(lp, x, k_pool_l, v_pool_l, window, theta):
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps, gemma_style=True)
            y, (k_pool_l, v_pool_l) = A.gqa_prefill_paged(
                lp["attn"], h, cfg, k_pool_l, v_pool_l, tables, lengths,
                starts, write_tables, window=window, rope_theta=theta,
                dp_groups=dp)
            if cfg.post_norms:
                y = rmsnorm(y, lp["ln1_post"], cfg.norm_eps, gemma_style=True)
            x = x + y
            h = rmsnorm(x, lp["ln2"], cfg.norm_eps, gemma_style=True)
            if self.is_moe and "router" in lp["ff"]:
                y, _ = moe_ffn_dispatch(lp["ff"], h, cfg)
            else:
                y = mlp(h, lp["ff"], cfg.mlp)
            if cfg.post_norms:
                y = rmsnorm(y, lp["ln2_post"], cfg.norm_eps, gemma_style=True)
            x = x + y
            return constrain(x, "batch", None, None), k_pool_l, v_pool_l

        # leading dense layers (deepseek): unscanned
        for i in range(self.n_dense):
            lp = jax.tree.map(lambda t: t[i], p["dense_layers"])
            x, kp, vp = layer_suffix(lp, x, cache.k_pool[i],
                                     cache.v_pool[i], None, None)
            cache = dataclasses.replace(
                cache, k_pool=cache.k_pool.at[i].set(kp),
                v_pool=cache.v_pool.at[i].set(vp))

        windows, thetas = self._layer_meta("scan")

        # pools thread through the scan as xs -> ys, exactly like decode
        def body(x, xs):
            lp, kp, vp, window, theta = xs
            x, kp, vp = layer_suffix(lp, x, kp, vp, window, theta)
            return x, (kp, vp)

        xs = (p["layers"], cache.k_pool[self.n_dense:],
              cache.v_pool[self.n_dense:], windows, thetas)
        x, pools = jax.lax.scan(body, x, xs)
        k_new = (cache.k_pool.at[self.n_dense:].set(pools[0])
                 if self.n_dense else pools[0])
        v_new = (cache.v_pool.at[self.n_dense:].set(pools[1])
                 if self.n_dense else pools[1])
        cache = dataclasses.replace(cache, k_pool=k_new, v_pool=v_new,
                                    seq_lens=lengths)
        logits = self._head(p, x)
        idx = jnp.maximum(lengths - starts - 1, 0)
        last = jnp.take_along_axis(
            logits, idx[:, None, None], axis=1)[:, 0]
        return last, cache
