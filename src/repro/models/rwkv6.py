"""RWKV6 ("Finch"): attention-free time mixing with data-dependent
per-channel decay, in chunked-parallel form.

Per head (dk = dv = head_dim), with r/k/v/w from data-dependent token
shift (ddlerp):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Chunked evaluation (chunk C, all log-space, every exponent <= 0 so no
overflow is possible):

    c_t      = sum_{i<=t} log w_i        (chunk-local inclusive cumsum)
    o_inter  = (r . exp(c_prev)) @ S_in
    M[t,s]   = sum_d r_td k_sd exp(c_prev[t,d] - c[s,d])   (s < t)
    o_intra  = M @ v + (r . u . k summed) v                 (diagonal)
    S_out    = exp(c_last) . S_in + (k . exp(c_last - c))^T @ v

The intra term uses the direct (C, C, dk) contraction -- exact and
stable; the factored two-matmul form overflows for fast-decay channels
(see tests/test_rwkv_numerics.py).  C defaults to 32 to bound the
(C, C, dk) working set; §Perf evaluates the subchunked factored variant.

The paper's technique does not apply to the O(1) recurrent state (no
large arrays to page) -- see DESIGN.md §5 -- but the block-quantum
discipline is used for the state *checkpoints* in training.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import AxTree, Params, dense_init, rmsnorm

_MIX_NAMES = ("r", "k", "v", "w", "g")


def init_rwkv6_mix(rng, cfg: ModelConfig) -> Tuple[Params, AxTree]:
    d, dt = cfg.d_model, cfg.jdtype
    s = cfg.ssm
    r = jax.random.split(rng, 12)
    p: Params = {
        "mu_x": 0.5 * jnp.ones((d,), dt),
        "mu": 0.5 * jnp.ones((5, d), dt),                   # r,k,v,w,g
        "mix_w1": dense_init(r[0], d, 5 * s.mix_lora, dt, scale=0.01),
        "mix_w2": 0.01 * dense_init(r[1], 5 * s.mix_lora, d, dt
                                    ).reshape(5, s.mix_lora, d),
        "wr": dense_init(r[2], d, d, dt),
        "wk": dense_init(r[3], d, d, dt),
        "wv": dense_init(r[4], d, d, dt),
        "wg": dense_init(r[5], d, d, dt),
        "wo": dense_init(r[6], d, d, dt),
        "w0": -6.0 + 5.0 * jax.random.uniform(r[7], (d,), jnp.float32),
        "decay_w1": dense_init(r[8], d, s.decay_lora, dt, scale=0.01),
        "decay_w2": 0.01 * dense_init(r[9], s.decay_lora, d, dt),
        "u": 0.5 * jax.random.normal(r[10], (d,), jnp.float32),
        "ln_x": jnp.ones((d,), dt),                          # group norm
    }
    ax = AxTree({k: tuple(None for _ in v.shape) for k, v in p.items()})
    for k in ("wr", "wk", "wv", "wg"):
        ax[k] = ("embed", "heads")
    ax["wo"] = ("heads", "embed")
    return p, ax


def _ddlerp(p: Params, x: jax.Array, xx: jax.Array):
    """Data-dependent token-shift interpolation -> per-channel mixes."""
    base = x + (xx - x) * p["mu_x"]
    lora = jnp.tanh(base @ p["mix_w1"])                      # (B,S,5*lora)
    lora = lora.reshape(*lora.shape[:-1], 5, -1)
    off = jnp.einsum("bsfl,fld->bsfd", lora, p["mix_w2"])   # (B,S,5,d)
    mix = p["mu"] + off
    vals = x[..., None, :] + (xx - x)[..., None, :] * mix    # (B,S,5,d)
    return tuple(vals[..., i, :] for i in range(5))


def _decay(p: Params, xw: jax.Array) -> jax.Array:
    """log w_t in (-inf, 0): w = exp(-exp(w0 + lora(x)))."""
    lw = p["w0"] + (jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
                    ).astype(jnp.float32)
    return -jnp.exp(lw)                                       # log-decay <= 0


def _heads(x, H):
    return x.reshape(*x.shape[:-1], H, -1)


def rwkv6_mix_fwd(p: Params, x: jax.Array, cfg: ModelConfig,
                  prev_x: Optional[jax.Array] = None,
                  state_in: Optional[jax.Array] = None,
                  lengths: Optional[jax.Array] = None):
    """Full-sequence chunked time mixing.

    x: (B, S, d).  Returns (y, (last_x, S_out)) so training can stream
    and decode can continue.  state_in: (B, H, dk, dv).  ``lengths``
    (B,) masks right padding out of the recurrence EXACTLY: padded
    positions contribute nothing to the state (k = 0 kills the rank-1
    update, log w = 0 freezes the decay at 1), so S_out and last_x
    equal a per-sequence unpadded run -- the length-masked prefill the
    serving engine's padded batched prefill relies on.
    """
    B, S, d = x.shape
    H = cfg.num_heads
    dk = d // H
    C = min(cfg.ssm.chunk, S)
    assert S % C == 0, (S, C)
    xx = jnp.concatenate(
        [prev_x[:, None] if prev_x is not None else jnp.zeros((B, 1, d), x.dtype),
         x[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, xx)
    r = _heads(xr @ p["wr"], H).astype(jnp.float32)
    k = _heads(xk @ p["wk"], H).astype(jnp.float32)
    v = _heads(xv @ p["wv"], H).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    logw = _heads(_decay(p, xw), H)                          # (B,S,H,dk)
    if lengths is not None:
        valid = (jnp.arange(S)[None, :]
                 < lengths[:, None])[:, :, None, None]       # (B,S,1,1)
        k = jnp.where(valid, k, 0.0)
        logw = jnp.where(valid, logw, 0.0)
    u = p["u"].reshape(H, dk)

    # chunk: (B, nc, C, H, dk) -> scan over nc
    def chunkify(t):
        return t.reshape(B, S // C, C, H, dk).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = map(chunkify, (r, k, v, logw))          # (nc,B,H,C,dk)

    S0 = (state_in.astype(jnp.float32) if state_in is not None
          else jnp.zeros((B, H, dk, dk), jnp.float32))

    sub = cfg.ssm.subchunk if (cfg.ssm.subchunk and
                               cfg.ssm.subchunk < C) else C

    intra_dt = jnp.dtype(cfg.ssm.intra_dtype)

    def tile(S_in, rb, kb, vb, wb, n):
        """One (B,H,n,dk) tile: direct intra + inter via S_in."""
        c = jnp.cumsum(wb, axis=2)                           # inclusive
        cprev = c - wb                                       # exclusive
        o_inter = jnp.einsum("bhtd,bhdv->bhtv", rb * jnp.exp(cprev), S_in)
        # direct intra contraction (exact, stable); the (n,n,dk) decay
        # tensor optionally in bf16 (halves the dominant traffic)
        dmat = jnp.exp(jnp.clip(cprev[:, :, :, None, :] - c[:, :, None, :, :],
                                -30.0, 0.0)).astype(intra_dt)  # (B,H,n,n,dk)
        M = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rb.astype(intra_dt),
                       kb.astype(intra_dt), dmat,
                       preferred_element_type=jnp.float32)
        mask = jnp.tril(jnp.ones((n, n), bool), k=-1)
        M = jnp.where(mask, M, 0.0)
        o_intra = jnp.einsum("bhts,bhsv->bhtv", M, vb)
        diag = jnp.sum(rb * u[None, :, None, :] * kb, axis=-1)  # (B,H,n)
        o = o_inter + o_intra + diag[..., None] * vb
        clast = c[:, :, -1:, :]                              # (B,H,1,dk)
        S_out = (jnp.exp(clast[:, :, 0, :, None]) * S_in +
                 jnp.einsum("bhtd,bhtv->bhdv", kb * jnp.exp(clast - c), vb))
        return S_out, o

    def body(S_in, xs):
        rb, kb, vb, wb = xs                                  # (B,H,C,dk)
        if sub == C:
            return tile(S_in, rb, kb, vb, wb, C)
        # unrolled subchunk tiles: the (n,n,dk) decay tensor shrinks by
        # C/sub and cross-tile terms ride the state recursion with NO
        # extra while-loop trips (python unroll)
        S = S_in
        outs = []
        for j in range(C // sub):
            sl = slice(j * sub, (j + 1) * sub)
            S, o = tile(S, rb[:, :, sl], kb[:, :, sl], vb[:, :, sl],
                        wb[:, :, sl], sub)
            outs.append(o)
        return S, jnp.concatenate(outs, axis=2)

    # checkpoint the chunk body: backward recomputes the (C, C, dk)
    # decay tensor per chunk instead of saving nc of them
    S_fin, oc = jax.lax.scan(jax.checkpoint(body), S0, (rc, kc, vc, wc))
    o = oc.transpose(1, 0, 3, 2, 4).reshape(B, S, d)
    o = rmsnorm(o.astype(x.dtype), p["ln_x"], cfg.norm_eps) * g
    y = o @ p["wo"]
    if lengths is not None:
        idx = (lengths.astype(jnp.int32) - 1)[:, None, None]
        last_x = jnp.take_along_axis(x, idx, axis=1)[:, 0]
    else:
        last_x = x[:, -1]
    return y, (last_x, S_fin)


def rwkv6_mix_step(p: Params, x: jax.Array, cfg: ModelConfig,
                   prev_x: jax.Array, state: jax.Array):
    """Single-token recurrence.  x, prev_x: (B, d); state: (B,H,dk,dk)."""
    B, d = x.shape
    H = cfg.num_heads
    dk = d // H
    xr, xk, xv, xw, xg = _ddlerp(p, x[:, None], prev_x[:, None])
    r = _heads(xr[:, 0] @ p["wr"], H).astype(jnp.float32)    # (B,H,dk)
    k = _heads(xk[:, 0] @ p["wk"], H).astype(jnp.float32)
    v = _heads(xv[:, 0] @ p["wv"], H).astype(jnp.float32)
    g = jax.nn.silu(xg[:, 0] @ p["wg"])
    w = jnp.exp(_heads(_decay(p, xw[:, 0]), H))              # (B,H,dk)
    u = p["u"].reshape(H, dk)
    kv = jnp.einsum("bhd,bhv->bhdv", k, v)
    o = jnp.einsum("bhd,bhdv->bhv", r, state.astype(jnp.float32)
                   + u[None, :, :, None] * kv)
    state = w[..., None] * state.astype(jnp.float32) + kv
    o = rmsnorm(o.reshape(B, d).astype(x.dtype), p["ln_x"], cfg.norm_eps) * g
    return o @ p["wo"], (x, state)


def rwkv6_mix_ref(p: Params, x: jax.Array, cfg: ModelConfig):
    """Pure sequential oracle (scan over single steps) for tests."""
    B, S, d = x.shape
    H = cfg.num_heads
    dk = d // H

    def body(carry, xt):
        prev_x, state = carry
        y, (px, st) = rwkv6_mix_step(p, xt, cfg, prev_x, state)
        return (px, st), y

    init = (jnp.zeros((B, d), x.dtype), jnp.zeros((B, H, dk, dk), jnp.float32))
    _, ys = jax.lax.scan(body, init, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2)


# -- channel mixing (RWKV ffn) ---------------------------------------------
def init_rwkv6_ffn(rng, cfg: ModelConfig) -> Tuple[Params, AxTree]:
    d, dt = cfg.d_model, cfg.jdtype
    r = jax.random.split(rng, 3)
    p = {"mu_k": 0.5 * jnp.ones((d,), dt),
         "mu_r": 0.5 * jnp.ones((d,), dt),
         "wk": dense_init(r[0], d, cfg.d_ff, dt),
         "wv": dense_init(r[1], cfg.d_ff, d, dt),
         "wr": dense_init(r[2], d, d, dt)}
    ax = AxTree(mu_k=(None,), mu_r=(None,), wk=("embed", "heads"),
                wv=("heads", "embed"), wr=("embed", "embed"))
    return p, ax


def rwkv6_ffn(p: Params, x: jax.Array, xx: jax.Array):
    """x: (..., d); xx: token-shifted x of the same shape."""
    xk = x + (xx - x) * p["mu_k"]
    xr = x + (xx - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
