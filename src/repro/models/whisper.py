"""Whisper-tiny backbone: encoder-decoder transformer.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, F, d).  Encoder: sinusoidal
positions + bidirectional layers.  Decoder: learned positions, causal
self-attention (paged KV at decode) + cross-attention over the encoder
output (static length -> its KV is computed once at prefill and stored
densely; only the *growing* self-attn stream needs the paper's block
pool).

Note: whisper's published max_target_positions is 448; the assigned
train_4k/decode_32k shapes exceed that, so the learned position table is
sized to the requested sequence (documented deviation, DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.paged_kv import PagedKVCache, PagedKVConfig
from repro.launch.shardings import constrain
from repro.models import attention as A
from repro.models.common import (AxTree, Params, chunked_lm_loss, dense_init,
                                 flash_attention, init_mlp, mlp, rmsnorm,
                                 sinusoidal_positions)
from repro.models.lm import (_stack_axes, eval_shape_with_aux,
                             write_token_paged)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WhisperState:
    self_kv: PagedKVCache            # decoder self-attn, L = num_layers
    cross_k: jax.Array               # (L, B, F, KVH, hd)
    cross_v: jax.Array

    def tree_flatten(self):
        return (self.self_kv, self.cross_k, self.cross_v), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


class WhisperModel:
    def __init__(self, cfg: ModelConfig, max_positions: int = 4096):
        self.cfg = cfg
        self.max_positions = max_positions

    def _init_enc_layer(self, rng):
        cfg = self.cfg
        r1, r2 = jax.random.split(rng)
        attn, attn_ax = A.init_gqa(r1, cfg)
        ff, ff_ax = init_mlp(r2, cfg.d_model, cfg.d_ff, cfg.jdtype)
        p = {"attn": attn, "ff": ff,
             "ln1": jnp.zeros((cfg.d_model,), cfg.jdtype),
             "ln2": jnp.zeros((cfg.d_model,), cfg.jdtype)}
        return p, AxTree(attn=attn_ax, ff=ff_ax, ln1=(None,), ln2=(None,))

    def _init_dec_layer(self, rng):
        cfg = self.cfg
        r1, r2, r3 = jax.random.split(rng, 3)
        attn, attn_ax = A.init_gqa(r1, cfg)
        xattn, xattn_ax = A.init_gqa(r2, cfg)
        ff, ff_ax = init_mlp(r3, cfg.d_model, cfg.d_ff, cfg.jdtype)
        p = {"attn": attn, "xattn": xattn, "ff": ff,
             "ln1": jnp.zeros((cfg.d_model,), cfg.jdtype),
             "lnx": jnp.zeros((cfg.d_model,), cfg.jdtype),
             "ln2": jnp.zeros((cfg.d_model,), cfg.jdtype)}
        return p, AxTree(attn=attn_ax, xattn=xattn_ax, ff=ff_ax,
                         ln1=(None,), lnx=(None,), ln2=(None,))

    def init(self, rng) -> Tuple[Params, AxTree]:
        cfg = self.cfg
        r = jax.random.split(rng, 5)
        p: Params = {
            "embed": dense_init(r[0], cfg.vocab_size, cfg.d_model,
                                cfg.jdtype, scale=1.0),
            "pos": 0.01 * jax.random.normal(
                r[1], (self.max_positions, cfg.d_model)).astype(cfg.jdtype),
            "enc_norm": jnp.zeros((cfg.d_model,), cfg.jdtype),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.jdtype),
        }
        ax = AxTree(embed=("vocab", "embed"), pos=(None, "embed"),
                    enc_norm=(None,), final_norm=(None,))
        rngs = jax.random.split(r[2], cfg.encoder.num_layers)
        p["enc_layers"] = jax.vmap(lambda rr: self._init_enc_layer(rr)[0])(rngs)
        _, eax = eval_shape_with_aux(self._init_enc_layer,
                                     jax.random.PRNGKey(0))
        ax["enc_layers"] = _stack_axes(eax)
        rngs = jax.random.split(r[3], cfg.num_layers)
        p["dec_layers"] = jax.vmap(lambda rr: self._init_dec_layer(rr)[0])(rngs)
        _, dax = eval_shape_with_aux(self._init_dec_layer,
                                     jax.random.PRNGKey(0))
        ax["dec_layers"] = _stack_axes(dax)
        return p, ax

    def param_specs(self):
        return eval_shape_with_aux(lambda rr: self.init(rr),
                                   jax.random.PRNGKey(0))

    # ---------------- encoder ----------------
    def encode(self, p: Params, frames: jax.Array):
        """frames: (B, F, d) stub embeddings -> (B, F, d)."""
        cfg = self.cfg
        B, F, d = frames.shape
        x = frames.astype(cfg.jdtype) + sinusoidal_positions(F, d).astype(
            cfg.jdtype)[None]
        x = constrain(x, "batch", None, None)
        positions = jnp.arange(F)[None, :]

        def body(x, lp):
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps, gemma_style=True)
            y = A.gqa_fwd(lp["attn"], h, cfg, causal=False,
                          positions=positions, q_chunk=min(1024, F))
            x = constrain(x + y, "batch", "seq", None)
            h = rmsnorm(x, lp["ln2"], cfg.norm_eps, gemma_style=True)
            return constrain(x + mlp(h, lp["ff"], cfg.mlp),
                             "batch", "seq", None), None

        x, _ = jax.lax.scan(body, x, p["enc_layers"])
        return rmsnorm(x, p["enc_norm"], cfg.norm_eps, gemma_style=True)

    # ---------------- decoder (train / prefill) ----------------
    def forward_hidden(self, p: Params, batch: Dict[str, jax.Array], *,
                       remat: bool = False, collect_kv: bool = False, **_):
        cfg = self.cfg
        enc = self.encode(p, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = p["embed"][tokens] + p["pos"][:S][None]
        x = constrain(x, "batch", None, None)
        positions = jnp.arange(S)[None, :]
        enc_pos = jnp.arange(enc.shape[1])[None, :]

        def body(x, lp):
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps, gemma_style=True)
            y, kv = A.gqa_fwd_kv(lp["attn"], h, cfg, window=None,
                                 positions=positions,
                                 q_chunk=min(1024, S))
            x = constrain(x + y, "batch", "seq", None)
            # cross attention (not causal): q from x, kv from encoder
            h = rmsnorm(x, lp["lnx"], cfg.norm_eps, gemma_style=True)
            qx, kx, vx = A._gqa_qkv(lp["xattn"], h, cfg, positions)
            _, ke, ve = A._gqa_qkv(lp["xattn"], enc, cfg, enc_pos)
            o = flash_attention(qx, ke, ve, causal=False,
                                scale=cfg.query_scale,
                                q_chunk=min(1024, S))
            y = o.reshape(B, S, -1) @ lp["xattn"]["wo"]
            x = constrain(x + y, "batch", "seq", None)
            h = rmsnorm(x, lp["ln2"], cfg.norm_eps, gemma_style=True)
            x = constrain(x + mlp(h, lp["ff"], cfg.mlp), "batch", "seq", None)
            return x, (kv, (ke, ve))

        body_fn = jax.checkpoint(body) if remat else body
        x, kv_stack = jax.lax.scan(body_fn, x, p["dec_layers"])
        return x, jnp.zeros((), jnp.float32), kv_stack

    def forward(self, p, batch, **kw):
        cfg = self.cfg
        x, aux, kv = self.forward_hidden(p, batch, **kw)
        logits = (rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
                  @ p["embed"].T).astype(jnp.float32)
        return logits, aux, kv

    def loss(self, p, batch, *, remat: bool = False, **_):
        cfg = self.cfg
        x, _, _ = self.forward_hidden(p, batch, remat=remat)
        xn = rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
        nll, cnt = chunked_lm_loss(xn, p["embed"].T, batch["targets"])
        loss = nll / jnp.maximum(cnt, 1.0)
        return loss, {"nll": loss}

    # ---------------- serving ----------------
    def kv_config(self, max_seq: int, num_blocks: Optional[int] = None,
                  batch: int = 1, dp_groups: int = 1) -> PagedKVConfig:
        cfg = self.cfg
        bt = cfg.kv_block_tokens
        mbs = (max_seq + bt - 1) // bt
        return PagedKVConfig(
            num_layers=cfg.num_layers, kv_heads=cfg.kv_heads, head_dim=cfg.hd,
            block_tokens=bt, num_blocks=num_blocks or mbs * batch,
            max_blocks_per_seq=mbs, dtype=jnp.dtype(cfg.dtype),
            dp_groups=dp_groups)

    def init_state(self, batch: int, max_seq: int,
                   num_blocks: Optional[int] = None,
                   dp_groups: int = 1) -> WhisperState:
        cfg = self.cfg
        F = cfg.encoder.num_frames
        kv = PagedKVCache.create(
            self.kv_config(max_seq, num_blocks, batch, dp_groups), batch)
        z = jnp.zeros((cfg.num_layers, batch, F, cfg.kv_heads, cfg.hd),
                      cfg.jdtype)
        return WhisperState(kv, z, z)

    def decode_state_specs(self, batch: int, max_seq: int,
                           num_blocks: Optional[int] = None,
                           dp_groups: int = 1):
        """Shape specs of the decode-time state (dry-run surface)."""
        return jax.eval_shape(
            lambda: self.init_state(batch, max_seq, num_blocks, dp_groups))

    def prefill(self, p, batch, state: WhisperState, lengths):
        logits, _, kv_stack = self.forward(p, batch, collect_kv=True)
        (k_self, v_self), (ke, ve) = kv_stack
        kv = state.self_kv.write_prefill(k_self, v_self, lengths)
        idx = jnp.maximum(lengths - 1, 0)
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
        return last, WhisperState(kv, ke, ve)

    def decode_step(self, p: Params, tokens: jax.Array,
                    state: WhisperState):
        cfg = self.cfg
        cache = state.self_kv
        tables, lens = cache.block_tables, cache.seq_lens
        bt = cache.config.block_tokens
        dp = cache.config.dp_groups
        B = tokens.shape[0]
        x = p["embed"][tokens] + p["pos"][lens]
        F = state.cross_k.shape[2]
        enc_pos_dummy = lens[:, None]  # rope disabled (theta=0)

        def body(x, xs):
            lp, kp, vp, ck, cv = xs
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps, gemma_style=True)
            y, (k_new, v_new) = A.gqa_decode(lp["attn"], h, cfg, kp, vp,
                                             tables, lens, dp_groups=dp)
            kp = write_token_paged(kp, k_new, tables, lens, bt, dp)
            vp = write_token_paged(vp, v_new, tables, lens, bt, dp)
            x = x + y
            # cross attention over static encoder KV
            h = rmsnorm(x, lp["lnx"], cfg.norm_eps, gemma_style=True)
            qx, _, _ = A._gqa_qkv(lp["xattn"], h[:, None], cfg,
                                  enc_pos_dummy)
            o = flash_attention(qx, ck, cv, causal=False,
                                scale=cfg.query_scale, q_chunk=1)
            x = x + (o.reshape(B, -1) @ lp["xattn"]["wo"])
            h = rmsnorm(x, lp["ln2"], cfg.norm_eps, gemma_style=True)
            x = x + mlp(h, lp["ff"], cfg.mlp)
            return x, (kp, vp)

        x, (kps, vps) = jax.lax.scan(
            body, x, (p["dec_layers"], cache.k_pool, cache.v_pool,
                      state.cross_k, state.cross_v))
        cache = dataclasses.replace(cache, k_pool=kps, v_pool=vps,
                                    seq_lens=lens + 1)
        logits = (rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
                  @ p["embed"].T).astype(jnp.float32)
        return logits, WhisperState(cache, state.cross_k, state.cross_v)
