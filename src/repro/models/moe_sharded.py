"""Mesh-aware MoE dispatch.

Sorting tokens by expert must be a *per-shard* operation: under plain
GSPMD, a single argsort over the token axis has global semantics and XLA
would all-gather every token to honor it.  So when a mesh is active the
MoE layer runs inside ``shard_map``: each device routes and sorts its
local tokens and hits the experts with ``ragged_dot``.

Two expert layouts:
  * ``tp`` (baseline): all experts on every device, hidden dim (d_ff)
    sharded over "model"; one psum after the down-projection (same
    collective bill as a dense Megatron MLP).
  * ``ep``: experts sharded over "model" with an all_to_all exchange
    (tokens travel to their experts' owners and back).  Collective bytes
    scale with top_k * d_model instead of d_model per token -- cheaper
    than TP's full-activation psum when top_k < model_parallelism; the
    §Perf hillclimb quantifies this on qwen3-moe.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.launch import shardings as SH
from repro.models import moe as MOE


def moe_ffn_dispatch(lp, x3d: jax.Array, cfg: ModelConfig):
    """x3d: (B, S, d) -> (y, aux).  Picks local vs shard_map execution."""
    B, S, d = x3d.shape
    ctx_mesh = SH.active_mesh()
    if ctx_mesh is None:
        y, aux = MOE.moe_ffn(lp, x3d.reshape(B * S, d), cfg)
        return y.reshape(B, S, d), aux
    mode = getattr(cfg.moe, "parallel_mode", "tp")
    fn = _moe_ep_shardmap if mode == "ep" else _moe_tp_shardmap
    return fn(lp, x3d, cfg, ctx_mesh)


def _batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _moe_tp_shardmap(lp, x3d, cfg, mesh):
    """Experts replicated, d_ff_expert sharded over 'model'."""
    bax = _batch_axes(mesh)
    wspec = {k: P(None, None, "model") if k in ("wi", "wg")
             else P(None, "model", None) if k == "wo"
             else P(None, "model") if k in ("shared_wi", "shared_wg")
             else P("model", None) if k == "shared_wo"
             else P(*(None,) * lp[k].ndim) for k in lp}

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(wspec, P(bax, None, None)),
        out_specs=(P(bax, None, None), P()),
        check_vma=False)
    def run(w, x):
        B, S, d = x.shape
        y, aux = MOE.moe_ffn(w, x.reshape(B * S, d), cfg)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, bax + ("model",))
        return y.reshape(B, S, d), aux

    return run(lp, x3d)


def _moe_ep_shardmap(lp, x3d, cfg, mesh):
    """Experts sharded over 'model'; all_to_all token exchange.

    Capacity-based: each device sends up to C tokens per expert shard
    (C = local_tokens * top_k * cap / E_local, rounded up), so the a2a
    has a static shape.  Overflow drops (capacity_factor controls risk),
    matching standard EP implementations.
    """
    e = cfg.moe
    bax = _batch_axes(mesh)
    ep = mesh.shape["model"]
    assert e.num_experts % ep == 0
    e_loc = e.num_experts // ep
    wspec = {k: P("model", None, None) if k in ("wi", "wg", "wo")
             else P(None, "model") if k in ("shared_wi", "shared_wg")
             else P("model", None) if k == "shared_wo"
             else P(*(None,) * lp[k].ndim) for k in lp}

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(wspec, P(bax, None, None)),
        out_specs=(P(bax, None, None), P()),
        check_vma=False)
    def run(w, x):
        B, S, d = x.shape
        T = B * S
        xt = x.reshape(T, d)
        weights, experts, aux = MOE.route(w["router"], xt, e)
        cap_f = e.capacity_factor if e.capacity_factor > 0 else 1.25
        C = int(T * e.top_k * cap_f) // e.num_experts + 1
        # slot each (token, k) into its expert's capacity buffer
        flat_e = experts.reshape(-1)                      # (T*k,)
        order = jnp.argsort(flat_e)
        tok = order // e.top_k
        sorted_e = flat_e[order]
        pos_in_e = jnp.arange(T * e.top_k) - jnp.searchsorted(
            sorted_e, sorted_e, side="left")              # rank within expert
        keep = pos_in_e < C
        slot = sorted_e * C + pos_in_e                    # global slot id
        buf = jnp.zeros((e.num_experts * C, d), xt.dtype)
        buf = buf.at[jnp.where(keep, slot, e.num_experts * C)].set(
            xt[tok], mode="drop")
        # a2a: (E, C, d) -> exchange expert shards across 'model'
        buf = buf.reshape(ep, e_loc * C, d)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                                  tiled=False)            # (ep, e_loc*C, d)
        ys = recv.reshape(ep, e_loc, C, d).transpose(1, 0, 2, 3) \
                 .reshape(e_loc, ep * C, d)
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", ys, w["wg"])) *
             jnp.einsum("ecd,edf->ecf", ys, w["wi"]))
        out = jnp.einsum("ecf,efd->ecd", h, w["wo"])
        out = out.reshape(e_loc, ep, C, d).transpose(1, 0, 2, 3) \
                 .reshape(ep, e_loc * C, d)
        back = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0,
                                  tiled=False).reshape(e.num_experts * C, d)
        # gather back to tokens, weighted
        w_sorted = weights.reshape(-1)[order] * keep
        contrib = back[jnp.minimum(slot, e.num_experts * C - 1)] * \
            w_sorted[:, None].astype(back.dtype)
        y = jnp.zeros((T, d), back.dtype).at[tok].add(contrib)
        if e.num_shared_experts:
            hs = jax.nn.silu(xt @ w["shared_wg"]) * (xt @ w["shared_wi"])
            y = y + jax.lax.psum(hs @ w["shared_wo"], "model")
        aux = jax.lax.pmean(aux, bax + ("model",))
        return y.reshape(B, S, d).astype(x.dtype), aux

    return run(lp, x3d)
