"""Mixture-of-Experts layer: dropless token-choice with sort + ragged_dot.

Routing: softmax router, top-k.  Tokens are sorted by assigned expert and
hit their experts through ``jax.lax.ragged_dot`` (group-sizes per expert),
so nothing is dropped and no (T, E, C) dispatch one-hot is materialized.

Distribution modes (see DESIGN.md §4):
  * ``tp``  (baseline): every device holds all experts, sharded on the
    hidden (d_ff_expert) dim over "model" -- TP-in-expert, collective
    cost identical to a dense MLP (one psum after down-proj).
  * ``ep``  (hillclimb): experts sharded over "model"; tokens routed with
    an all_to_all inside shard_map.  Implemented in
    ``repro.launch.shardmoe`` and toggled per-config.

This module is mesh-agnostic: it computes on whatever token shard it is
handed (works single-device in smoke tests and inside shard_map/pjit).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import AxTree, Params, dense_init

# ---------------------------------------------------------------------------
# Blocked grouped matmul ("megablox-lite").
#
# ``jax.lax.ragged_dot`` has no grouped kernel on the CPU backend: it
# lowers to a DENSE (tokens, E*d) x (E*d, f) contraction -- 550 GB
# intermediates and ~20x phantom FLOPs for qwen3, which would poison the
# dry-run roofline.  Instead we pad each expert's token run to a multiple
# of ``block`` rows inside a fixed (Tk + E*block) buffer and run ONE
# batched (nb, m, d) x (nb, d, f) matmul with per-block expert weights --
# the same schedule a TPU grouped-matmul kernel (megablox) executes, so
# FLOPs/bytes in the compiled HLO are honest (padding waste <= E*block
# tokens, ~6% at qwen3 scale).  Plain autodiff gives the right backward
# (scatter-add into the expert weights).
# ---------------------------------------------------------------------------


def _group_layout(group_sizes: jax.Array, Tk: int, block: int):
    """Returns (pos (Tk,), block_expert (nb,)) for sorted tokens."""
    E = group_sizes.shape[0]
    m = block
    padded = ((group_sizes + m - 1) // m) * m
    ends = jnp.cumsum(group_sizes)
    pends = jnp.cumsum(padded)
    starts = ends - group_sizes
    pstarts = pends - padded
    j = jnp.arange(Tk, dtype=jnp.int32)
    e_of = jnp.searchsorted(ends, j, side="right").astype(jnp.int32)
    e_of = jnp.minimum(e_of, E - 1)
    pos = pstarts[e_of] + (j - starts[e_of])
    nb = (Tk + E * m) // m
    blk_expert = jnp.searchsorted(pends, jnp.arange(nb, dtype=jnp.int32) * m,
                                  side="right").astype(jnp.int32)
    return pos, jnp.minimum(blk_expert, E - 1)


def scatter_to_blocks(x: jax.Array, pos: jax.Array, block: int, E: int):
    """x: (Tk, d) sorted -> (nb, m, d) block-padded buffer."""
    Tk, d = x.shape
    buf = jnp.zeros((Tk + E * block, d), x.dtype).at[pos].set(x)
    return buf.reshape(-1, block, d)


def blocks_matmul(buf: jax.Array, w: jax.Array, blk_expert: jax.Array):
    """(nb, m, d) x w[blk_expert] -> (nb, m, f)."""
    return jnp.einsum("bmd,bdf->bmf", buf, w[blk_expert])


def gather_from_blocks(buf: jax.Array, pos: jax.Array) -> jax.Array:
    nb, m, f = buf.shape
    return buf.reshape(nb * m, f)[pos]


def grouped_matmul(x, w, group_sizes, *, block: int = 256):
    """x: (Tk, d) sorted by group; w: (E, d, f) -> (Tk, f)."""
    E = w.shape[0]
    block = min(block, max(1, x.shape[0]))
    pos, blk_e = _group_layout(group_sizes, x.shape[0], block)
    buf = scatter_to_blocks(x, pos, block, E)
    return gather_from_blocks(blocks_matmul(buf, w, blk_e), pos)


def init_moe(rng, cfg: ModelConfig) -> Tuple[Params, AxTree]:
    e = cfg.moe
    d, dtype = cfg.d_model, cfg.jdtype
    r = jax.random.split(rng, 6)
    p: Params = {
        "router": dense_init(r[0], d, e.num_experts, jnp.float32, scale=0.02),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "wi": dense_init(r[1], d, e.num_experts * e.d_ff_expert, dtype
                         ).reshape(d, e.num_experts, e.d_ff_expert).transpose(1, 0, 2),
        "wg": dense_init(r[2], d, e.num_experts * e.d_ff_expert, dtype
                         ).reshape(d, e.num_experts, e.d_ff_expert).transpose(1, 0, 2),
        "wo": dense_init(r[3], e.d_ff_expert, e.num_experts * d, dtype
                         ).reshape(e.d_ff_expert, e.num_experts, d).transpose(1, 0, 2),
    }
    ax = AxTree(router=(None, None),
                wi=("expert", "embed", "heads"),
                wg=("expert", "embed", "heads"),
                wo=("expert", "heads", "embed"))
    if e.num_shared_experts:
        p["shared_wi"] = dense_init(r[4], d, e.d_ff_shared, dtype)
        p["shared_wg"] = dense_init(r[5], d, e.d_ff_shared, dtype)
        p["shared_wo"] = dense_init(r[4], e.d_ff_shared, d, dtype)
        ax.update(shared_wi=("embed", "heads"), shared_wg=("embed", "heads"),
                  shared_wo=("heads", "embed"))
    return p, ax


def route(router_w: jax.Array, x: jax.Array, e: MoEConfig):
    """x: (T, d) -> (weights (T, k), experts (T, k) int32, aux_loss)."""
    logits = x.astype(jnp.float32) @ router_w            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, e.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    T = x.shape[0]
    counts = jnp.zeros(e.num_experts, jnp.float32).at[experts.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(T * e.top_k, 1)
    pbar = probs.mean(axis=0)
    aux = e.num_experts * jnp.sum(f * pbar)
    return weights, experts, aux


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig):
    """Dropless MoE over a token shard.  x: (T, d) -> (y (T, d), aux)."""
    e = cfg.moe
    T, d = x.shape
    weights, experts, aux = route(p["router"], x, e)

    # sort token-replicas by expert
    flat_expert = experts.reshape(-1)                    # (T*k,)
    order = jnp.argsort(flat_expert)
    token_of = order // e.top_k                          # source token
    xs = x[token_of]                                     # (T*k, d) sorted
    group_sizes = jnp.zeros(e.num_experts, jnp.int32).at[flat_expert].add(1)

    # one block layout + scatter shared by all three expert matmuls
    block = min(256, max(1, xs.shape[0]))
    pos, blk_e = _group_layout(group_sizes, xs.shape[0], block)
    buf = scatter_to_blocks(xs, pos, block, e.num_experts)
    h = (jax.nn.silu(blocks_matmul(buf, p["wg"], blk_e)) *
         blocks_matmul(buf, p["wi"], blk_e))
    ys = gather_from_blocks(blocks_matmul(h, p["wo"], blk_e), pos)

    # un-sort and combine with routing weights
    w_sorted = weights.reshape(-1)[order]
    y = jnp.zeros((T, d), ys.dtype).at[token_of].add(
        ys * w_sorted[:, None].astype(ys.dtype))

    if e.num_shared_experts:
        h = jax.nn.silu(x @ p["shared_wg"]) * (x @ p["shared_wi"])
        y = y + h @ p["shared_wo"]
    return y.astype(x.dtype), aux
