"""Unified model API: ``build_model(cfg)`` + input/state spec builders.

Every model exposes:
  init(rng) -> (params, axes)        param_specs() -> (shapes, axes)
  forward(params, batch, remat=...)  loss(params, batch, remat=...)
  prefill(params, batch, state, lengths)   decode_step(params, tokens, state)

``input_specs`` / ``decode_specs`` return weak-type-correct
ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.configs.shapes import InputShape
from repro.core.paged_kv import PagedKVCache
from repro.models.lm import DecoderLM
from repro.models.mamba2_lm import Mamba2LM
from repro.models.rwkv_lm import RWKVLM
from repro.models.whisper import WhisperModel
from repro.models.zamba2 import Zamba2LM


def build_model(cfg: ModelConfig, max_positions: int = 4096):
    if cfg.family == "audio":
        return WhisperModel(cfg, max_positions=max_positions)
    if cfg.family == "hybrid":
        return Zamba2LM(cfg)
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return RWKVLM(cfg)
    if cfg.ssm is not None and cfg.ssm.kind == "mamba2":
        return Mamba2LM(cfg)
    return DecoderLM(cfg)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Training/prefill batch as ShapeDtypeStructs."""
    sds = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    toks = S
    batch: Dict[str, Any] = {}
    if cfg.family == "audio":
        batch["frames"] = sds((B, cfg.encoder.num_frames, cfg.d_model),
                              jnp.bfloat16)
    if cfg.num_image_tokens:
        toks = S - cfg.num_image_tokens
        batch["image_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model),
                                    jnp.bfloat16)
    batch["tokens"] = sds((B, toks), jnp.int32)
    batch["targets"] = sds((B, toks), jnp.int32)
    return batch


def decode_specs(cfg: ModelConfig, shape: InputShape,
                 model=None, dp_groups: int = 1) -> Tuple[Any, Any]:
    """(tokens, state) ShapeDtypeStructs for serve_step lowering.

    The state is sized for a KV context of ``shape.seq_len`` with the
    paged pool exactly covering global_batch sequences.
    """
    model = model or build_model(cfg)
    sds = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    tokens = sds((B,), jnp.int32)
    # every model describes its own decode state (no isinstance
    # dispatch: the strategy registry in serve/arch.py relies on the
    # same per-model surface)
    state = model.decode_state_specs(B, S, num_blocks=_nb(cfg, S, B),
                                     dp_groups=dp_groups)
    return tokens, state


def _nb(cfg: ModelConfig, S: int, B: int) -> int:
    bt = cfg.kv_block_tokens
    return ((S + bt - 1) // bt) * B


def make_concrete_batch(cfg: ModelConfig, B: int, S: int, seed: int = 0):
    """Small concrete batch for smoke tests."""
    rng = jax.random.PRNGKey(seed)
    r1, r2, r3 = jax.random.split(rng, 3)
    toks = S
    batch: Dict[str, Any] = {}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            r3, (B, cfg.encoder.num_frames, cfg.d_model), jnp.float32)
    if cfg.num_image_tokens:
        toks = S - cfg.num_image_tokens
        batch["image_embeds"] = 0.1 * jax.random.normal(
            r3, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    batch["tokens"] = jax.random.randint(r1, (B, toks), 0, cfg.vocab_size)
    batch["targets"] = jax.random.randint(r2, (B, toks), 0, cfg.vocab_size)
    return batch
