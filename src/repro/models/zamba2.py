"""Zamba2 hybrid: Mamba2 backbone + one SHARED attention/MLP block applied
every ``shared_attn_every`` layers with per-invocation LoRA adapters.

Structure: ``num_layers`` Mamba2 layers in G = L / every groups; after
each group the shared transformer block runs (weights shared across all
G invocations; a small per-group LoRA on wq/wk/wv differentiates them --
the Zamba2 paper's design point: attention quality at ~1/G the weight
memory, which pairs naturally with the paper's block-pool thesis: the
shared block's KV cache is G paged streams in one arena).

Decode state: conv (G,per,B,W-1,cd) + ssd (G,per,B,H,P,N) + a PagedKVCache
with num_layers = G.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.paged_kv import PagedKVCache, PagedKVConfig
from repro.launch.shardings import constrain
from repro.models import attention as A
from repro.models import mamba2 as M2
from repro.models.common import (AxTree, Params, chunked_lm_loss, dense_init,
                                 init_mlp, mlp, rmsnorm)
from repro.models.lm import (_stack_axes, eval_shape_with_aux,
                             write_token_paged)

_NEG = -1e30


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ZambaState:
    conv: jax.Array          # (G, per, B, W-1, conv_dim)
    ssd: jax.Array           # (G, per, B, H, P, N)
    kv: PagedKVCache         # L = G streams

    def tree_flatten(self):
        return (self.conv, self.ssd, self.kv), None

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch)


class Zamba2LM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.shared_attn_every > 0
        assert cfg.num_layers % cfg.shared_attn_every == 0
        self.cfg = cfg
        self.groups = cfg.num_layers // cfg.shared_attn_every
        self.per = cfg.shared_attn_every

    def _init_mamba_layer(self, rng):
        cfg = self.cfg
        m, max_ = M2.init_mamba2(rng, cfg)
        p = {"mamba": m, "ln": jnp.zeros((cfg.d_model,), cfg.jdtype)}
        return p, AxTree(mamba=max_, ln=(None,))

    def _init_lora(self, rng):
        cfg = self.cfg
        rk = cfg.shared_attn_lora
        d = cfg.d_model
        r = jax.random.split(rng, 6)
        p = {}
        ax = AxTree()
        for i, nm in enumerate(("q", "k", "v")):
            p[f"{nm}_a"] = dense_init(r[2 * i], d, rk, cfg.jdtype, scale=0.01)
            p[f"{nm}_b"] = jnp.zeros((rk, d), cfg.jdtype)
            ax[f"{nm}_a"] = ("embed", None)
            ax[f"{nm}_b"] = (None, "embed")
        return p, ax

    def init(self, rng) -> Tuple[Params, AxTree]:
        cfg = self.cfg
        r = jax.random.split(rng, 6)
        p: Params = {
            "embed": dense_init(r[0], cfg.vocab_size, cfg.d_model,
                                cfg.jdtype, scale=1.0),
            "final_norm": jnp.zeros((cfg.d_model,), cfg.jdtype),
        }
        ax = AxTree(embed=("vocab", "embed"), final_norm=(None,))
        # mamba stack, grouped (G, per, ...)
        rngs = jax.random.split(r[1], cfg.num_layers)
        flat = jax.vmap(lambda rr: self._init_mamba_layer(rr)[0])(rngs)
        p["mamba_layers"] = jax.tree.map(
            lambda t: t.reshape(self.groups, self.per, *t.shape[1:]), flat)
        _, max_ = eval_shape_with_aux(self._init_mamba_layer,
                                      jax.random.PRNGKey(0))
        ax["mamba_layers"] = jax.tree.map(
            lambda t: ("layers", "layers") + t, max_,
            is_leaf=lambda t: isinstance(t, tuple))
        # shared attention + mlp block (single copy)
        attn, attn_ax = A.init_gqa(r[2], cfg)
        ff, ff_ax = init_mlp(r[3], cfg.d_model, cfg.d_ff, cfg.jdtype)
        p["shared"] = {"attn": attn, "ff": ff,
                       "ln1": jnp.zeros((cfg.d_model,), cfg.jdtype),
                       "ln2": jnp.zeros((cfg.d_model,), cfg.jdtype)}
        ax["shared"] = AxTree(attn=attn_ax, ff=ff_ax, ln1=(None,),
                              ln2=(None,))
        # per-group LoRA on shared qkv
        rngs = jax.random.split(r[4], self.groups)
        p["lora"] = jax.vmap(lambda rr: self._init_lora(rr)[0])(rngs)
        _, lax_ = eval_shape_with_aux(self._init_lora, jax.random.PRNGKey(0))
        ax["lora"] = _stack_axes(lax_)
        return p, ax

    def param_specs(self):
        return eval_shape_with_aux(lambda rr: self.init(rr),
                                   jax.random.PRNGKey(0))

    # ---------------- shared block ----------------
    def _shared_params(self, p, lora):
        """Apply the group's LoRA to the shared attention weights."""
        sp = dict(p["shared"])
        attn = dict(sp["attn"])
        for nm, w in (("q", "wq"), ("k", "wk"), ("v", "wv")):
            attn[w] = attn[w] + lora[f"{nm}_a"] @ lora[f"{nm}_b"]
        sp["attn"] = attn
        return sp

    def _shared_fwd(self, sp, x, positions):
        cfg = self.cfg
        h = rmsnorm(x, sp["ln1"], cfg.norm_eps, gemma_style=True)
        y, kv = A.gqa_fwd_kv(sp["attn"], h, cfg, window=None,
                             positions=positions)
        x = constrain(x + y, "batch", "seq", None)
        h = rmsnorm(x, sp["ln2"], cfg.norm_eps, gemma_style=True)
        x = constrain(x + mlp(h, sp["ff"], cfg.mlp), "batch", "seq", None)
        return x, kv

    # ---------------- forward ----------------
    def forward_hidden(self, p: Params, batch: Dict[str, jax.Array], *,
                       remat: bool = False, state: Optional[ZambaState] = None,
                       collect_kv: bool = False,
                       lengths: Optional[jax.Array] = None, **_):
        cfg = self.cfg
        x = p["embed"][batch["tokens"]]
        x = constrain(x, "batch", None, None)
        B, S, _ = x.shape
        offs = (state.kv.seq_lens if state is not None
                else jnp.zeros((B,), jnp.int32))
        positions = offs[:, None] + jnp.arange(S)[None, :]

        def mamba_body(x, xs):
            if state is None:
                lp = xs
                cs = ss = None
            else:
                lp, cs, ss = xs
            h = rmsnorm(x, lp["ln"], cfg.norm_eps, gemma_style=True)
            # lengths masks right padding out of the SSM scan (padded
            # attention outputs are already causal-safe; the recurrent
            # state is what padding would otherwise pollute)
            y, (cs_o, ss_o) = M2.mamba2_fwd(lp["mamba"], h, cfg, cs, ss,
                                            lengths=lengths)
            return constrain(x + y, "batch", "seq", None), (cs_o, ss_o)

        def group_body(x, xs):
            if state is None:
                glp, lora = xs
                mx = glp
            else:
                glp, lora, cs_g, ss_g = xs
                mx = (glp, cs_g, ss_g)
            x, states = jax.lax.scan(mamba_body, x, mx)
            sp = self._shared_params(p, lora)
            x, kv = self._shared_fwd(sp, x, positions)
            ys = (states, kv) if collect_kv else (states, None)
            return x, ys

        gb = jax.checkpoint(group_body) if remat else group_body
        if state is None:
            xs = (p["mamba_layers"], p["lora"])
        else:
            xs = (p["mamba_layers"], p["lora"], state.conv, state.ssd)
        x, (states, kvs) = jax.lax.scan(gb, x, xs)
        return x, jnp.zeros((), jnp.float32), (states, kvs)

    def forward(self, p, batch, **kw):
        cfg = self.cfg
        x, aux, sk = self.forward_hidden(p, batch, **kw)
        logits = (rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
                  @ p["embed"].T).astype(jnp.float32)
        return logits, aux, sk

    def loss(self, p, batch, *, remat: bool = False, **_):
        cfg = self.cfg
        x, _, _ = self.forward_hidden(p, batch, remat=remat)
        xn = rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
        nll, cnt = chunked_lm_loss(xn, p["embed"].T, batch["targets"])
        loss = nll / jnp.maximum(cnt, 1.0)
        return loss, {"nll": loss}

    # ---------------- serving ----------------
    def kv_config(self, max_seq: int, num_blocks: Optional[int] = None,
                  batch: int = 1, dp_groups: int = 1) -> PagedKVConfig:
        cfg = self.cfg
        bt = cfg.kv_block_tokens
        mbs = (max_seq + bt - 1) // bt
        return PagedKVConfig(
            num_layers=self.groups, kv_heads=cfg.kv_heads, head_dim=cfg.hd,
            block_tokens=bt, num_blocks=num_blocks or mbs * batch,
            max_blocks_per_seq=mbs, dtype=jnp.dtype(cfg.dtype),
            dp_groups=dp_groups)

    def init_recurrent(self, batch: int):
        """Zero (conv, ssd) recurrent state WITHOUT allocating a KV
        pool -- serving composes these with an externally owned
        ``PagedKVCache`` view (serve/arch.CompositeStrategy)."""
        cfg = self.cfg
        d_inner, H, P, N, W = M2._dims(cfg)
        conv = jnp.zeros((self.groups, self.per, batch, W - 1,
                          d_inner + 2 * N), jnp.float32)
        ssd = jnp.zeros((self.groups, self.per, batch, H, P, N), jnp.float32)
        return conv, ssd

    def init_state(self, batch: int, max_seq: int,
                   num_blocks: Optional[int] = None,
                   dp_groups: int = 1) -> ZambaState:
        conv, ssd = self.init_recurrent(batch)
        kv = PagedKVCache.create(
            self.kv_config(max_seq, num_blocks, batch, dp_groups), batch)
        return ZambaState(conv, ssd, kv)

    def prefill(self, p, batch, state: ZambaState, lengths):
        logits, _, (states, kvs) = self.forward(p, batch, state=state,
                                                collect_kv=True,
                                                lengths=lengths)
        kv = state.kv.write_prefill(kvs[0], kvs[1], lengths)
        idx = jnp.maximum(lengths - 1, 0)
        last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
        return last, ZambaState(states[0], states[1], kv)

    # -- constant-state pool glue (serve/arch.ConstantStateStrategy) --
    @property
    def state_elems(self) -> int:
        """Float32 elements of ONE sequence's recurrent state -- the
        constant-state pool's (exact) block quantum."""
        d_inner, H, P, N, W = M2._dims(self.cfg)
        per_layer = (W - 1) * (d_inner + 2 * N) + H * P * N
        return self.groups * self.per * per_layer

    def state_to_rows(self, conv: jax.Array, ssd: jax.Array) -> jax.Array:
        """Flatten (G, per, B, ...) recurrent state to per-sequence
        (B, state_elems) rows -- one pool block per sequence."""
        B = conv.shape[2]
        c = jnp.moveaxis(conv, 2, 0).reshape(B, -1)
        s = jnp.moveaxis(ssd, 2, 0).reshape(B, -1)
        return jnp.concatenate([c, s], axis=1).astype(jnp.float32)

    def rows_to_state(self, rows: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Inverse of ``state_to_rows``."""
        d_inner, H, P, N, W = M2._dims(self.cfg)
        G, per = self.groups, self.per
        B = rows.shape[0]
        cd = d_inner + 2 * N
        csize = G * per * (W - 1) * cd
        conv = jnp.moveaxis(
            rows[:, :csize].reshape(B, G, per, W - 1, cd), 0, 2)
        ssd = jnp.moveaxis(
            rows[:, csize:].reshape(B, G, per, H, P, N), 0, 2)
        return conv, ssd

    def decode_state_specs(self, batch: int, max_seq: int,
                           num_blocks: Optional[int] = None,
                           dp_groups: int = 1):
        """Shape specs of the decode-time state (dry-run surface)."""
        return jax.eval_shape(
            lambda: self.init_state(batch, max_seq, num_blocks, dp_groups))

    def decode_step(self, p: Params, tokens: jax.Array, state: ZambaState):
        cfg = self.cfg
        x = p["embed"][tokens]
        cache = state.kv
        tables, lens = cache.block_tables, cache.seq_lens
        bt = cache.config.block_tokens

        def mamba_step_body(x, xs):
            lp, cs, ss = xs
            h = rmsnorm(x, lp["ln"], cfg.norm_eps, gemma_style=True)
            y, (cs, ss) = M2.mamba2_step(lp["mamba"], h, cfg, cs, ss)
            return x + y, (cs, ss)

        dp = cache.config.dp_groups

        def group_body(x, xs):
            glp, lora, cs_g, ss_g, kp, vp = xs
            x, states = jax.lax.scan(mamba_step_body, x, (glp, cs_g, ss_g))
            sp = self._shared_params(p, lora)
            h = rmsnorm(x, sp["ln1"], cfg.norm_eps, gemma_style=True)
            y, (k_new, v_new) = A.gqa_decode(sp["attn"], h, cfg, kp, vp,
                                             tables, lens, dp_groups=dp)
            kp = write_token_paged(kp, k_new, tables, lens, bt, dp)
            vp = write_token_paged(vp, v_new, tables, lens, bt, dp)
            x = x + y
            h = rmsnorm(x, sp["ln2"], cfg.norm_eps, gemma_style=True)
            x = x + mlp(h, sp["ff"], cfg.mlp)
            return x, (states, kp, vp)

        x, (states, kps, vps) = jax.lax.scan(
            group_body, x, (p["mamba_layers"], p["lora"], state.conv,
                            state.ssd, cache.k_pool, cache.v_pool))
        cache = dataclasses.replace(cache, k_pool=kps, v_pool=vps,
                                    seq_lens=cache.seq_lens + 1)
        logits = (rmsnorm(x, p["final_norm"], cfg.norm_eps, gemma_style=True)
                  @ p["embed"].T).astype(jnp.float32)
        return logits, ZambaState(states[0], states[1], cache)
