"""Mamba2 (SSD) block, chunked-parallel, for the Zamba2 hybrid.

Per head (P = head_dim, N = state_dim), scalar decay a_t = exp(dt_t * A_h):

    S_t = a_t S_{t-1} + (dt_t x_t) B_t^T          S: (P, N)
    y_t = S_t C_t + D_h x_t

Chunked form (chunk C) with inclusive log-decay cumsum c_t (all exponents
<= 0 -- stable):

    y_inter[t] = exp(c_t) * (S_in C_t)
    M[t,s]     = exp(c_t - c_s) (C_t . B_s) dt_s     (s <= t)
    y_intra    = M @ x
    S_out      = exp(c_last) S_in
                 + sum_s exp(c_last - c_s) (dt_s x_s) B_s^T

Input path: in_proj -> (z, xBC, dt); causal conv1d (width 4) + silu on
xBC; gated RMSNorm before out_proj (Mamba2 paper).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import AxTree, Params, dense_init, rmsnorm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    return d_inner, nheads, s.head_dim, s.state_dim, s.conv_width


def init_mamba2(rng, cfg: ModelConfig) -> Tuple[Params, AxTree]:
    d, dt = cfg.d_model, cfg.jdtype
    d_inner, H, P, N, W = _dims(cfg)
    conv_dim = d_inner + 2 * N
    r = jax.random.split(rng, 5)
    p: Params = {
        "in_proj": dense_init(r[0], d, 2 * d_inner + 2 * N + H, dt),
        "conv_w": 0.1 * jax.random.normal(r[1], (W, conv_dim), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),         # per-head A
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(r[2], (H,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(r[3], d_inner, d, dt),
    }
    ax = AxTree(in_proj=("embed", "heads"), conv_w=(None, "heads"),
                conv_b=("heads",), A_log=(None,), dt_bias=(None,), D=(None,),
                norm=("heads",), out_proj=("heads", "embed"))
    return p, ax


def _split_proj(p, x, cfg):
    d_inner, H, P, N, W = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: 2 * d_inner + 2 * N]
    dt = jax.nn.softplus(zxbcdt[..., -H:].astype(jnp.float32) + p["dt_bias"])
    return z, xBC, dt


def _conv(p, xBC, conv_state, lengths=None):
    """Causal conv1d over (B, S, conv_dim) given (B, W-1, conv_dim) state.

    With ``lengths``, the returned state is the window ending at each
    row's LAST VALID token (padded tail excluded); ``lengths[b] == S``
    reduces exactly to the unmasked tail window.
    """
    W = p["conv_w"].shape[0]
    full = jnp.concatenate([conv_state, xBC.astype(jnp.float32)], axis=1)
    out = sum(full[:, i: full.shape[1] - (W - 1 - i)] * p["conv_w"][i]
              for i in range(W))
    if lengths is None:
        state = full[:, -(W - 1):]
    else:
        idx = lengths[:, None] + jnp.arange(W - 1)[None, :]      # (B, W-1)
        state = jnp.take_along_axis(full, idx[:, :, None], axis=1)
    return jax.nn.silu(out + p["conv_b"]), state


def mamba2_fwd(p: Params, x: jax.Array, cfg: ModelConfig,
               conv_state: Optional[jax.Array] = None,
               ssd_state: Optional[jax.Array] = None,
               lengths: Optional[jax.Array] = None):
    """x: (B, S, d) -> (y, (conv_state, ssd_state)).

    ``lengths`` (B,) masks a right-padded batch EXACTLY: padded
    positions get dt = 0, so their decay is exp(0) = 1 and their state
    contribution 0 -- the scan carries each row's state past its tail
    unchanged, and the conv state is read at the last valid token.
    Outputs at padded positions are garbage; callers index by length.
    """
    B, S, d = x.shape
    d_inner, H, P, N, W = _dims(cfg)
    C_len = min(cfg.ssm.chunk, S)
    assert S % C_len == 0
    z, xBC, dt = _split_proj(p, x, cfg)
    if lengths is not None:
        valid = jnp.arange(S)[None, :] < lengths[:, None]        # (B, S)
        dt = dt * valid[..., None]
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, d_inner + 2 * N), jnp.float32)
    xBC, conv_out_state = _conv(p, xBC, conv_state, lengths=lengths)
    xs = xBC[..., :d_inner].reshape(B, S, H, P)
    Bm = xBC[..., d_inner: d_inner + N]                      # (B,S,N)
    Cm = xBC[..., d_inner + N:]                              # (B,S,N)
    A = -jnp.exp(p["A_log"])                                 # (H,) < 0
    logdecay = dt * A                                        # (B,S,H) <= 0

    nc = S // C_len

    def chunk(t, trailing):
        return t.reshape(B, nc, C_len, *trailing).swapaxes(0, 1)
    xs_c = chunk(xs, (H, P))
    B_c, C_c = chunk(Bm, (N,)), chunk(Cm, (N,))
    dt_c, ld_c = chunk(dt, (H,)), chunk(logdecay, (H,))

    S0 = (ssd_state.astype(jnp.float32) if ssd_state is not None
          else jnp.zeros((B, H, P, N), jnp.float32))

    def body(S_in, xsb):
        xb, Bb, Cb, dtb, ldb = xsb           # (B,C,H,P) (B,C,N) (B,C,H)
        c = jnp.cumsum(ldb, axis=1)          # (B,C,H) inclusive
        y_inter = jnp.einsum("bth,bhpn,btn->bthp", jnp.exp(c),
                             S_in, Cb.astype(jnp.float32))
        cb = Cb.astype(jnp.float32) @ Bb.astype(jnp.float32).swapaxes(1, 2)
        decay = jnp.exp(jnp.clip(c[:, :, None, :] - c[:, None, :, :],
                                 -60.0, 0.0))                # (B,t,s,H)
        mask = jnp.tril(jnp.ones((C_len, C_len), bool))
        M = cb[:, :, :, None] * decay * dtb[:, None, :, :]   # (B,t,s,H)
        M = jnp.where(mask[None, :, :, None], M, 0.0)
        y_intra = jnp.einsum("btsh,bshp->bthp", M, xb.astype(jnp.float32))
        clast = c[:, -1:, :]                                 # (B,1,H)
        w = jnp.exp(clast - c) * dtb                         # (B,C,H)
        S_out = (jnp.exp(clast)[:, 0, :, None, None] * S_in +
                 jnp.einsum("bth,bthp,btn->bhpn", w,
                            xb.astype(jnp.float32), Bb.astype(jnp.float32)))
        return S_out, y_inter + y_intra

    S_fin, yc = jax.lax.scan(jax.checkpoint(body), S0,
                             (xs_c, B_c, C_c, dt_c, ld_c))
    y = yc.swapaxes(0, 1).reshape(B, S, H, P)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], (conv_out_state, S_fin)


def mamba2_step(p: Params, x: jax.Array, cfg: ModelConfig,
                conv_state: jax.Array, ssd_state: jax.Array):
    """Single-token recurrence.  x: (B, d)."""
    B, d = x.shape
    d_inner, H, P, N, W = _dims(cfg)
    z, xBC, dt = _split_proj(p, x[:, None], cfg)
    xBC, conv_state = _conv(p, xBC, conv_state)
    xs = xBC[:, 0, :d_inner].reshape(B, H, P)
    Bm = xBC[:, 0, d_inner: d_inner + N]
    Cm = xBC[:, 0, d_inner + N:]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[:, 0] * A)                                # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xs.astype(jnp.float32),
                     Bm.astype(jnp.float32))
    S_new = a[:, :, None, None] * ssd_state.astype(jnp.float32) + upd
    y = jnp.einsum("bhpn,bn->bhp", S_new, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z[:, 0]), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], (conv_state, S_new)


def mamba2_ref(p: Params, x: jax.Array, cfg: ModelConfig):
    """Sequential oracle."""
    B, S, d = x.shape
    d_inner, H, P, N, W = _dims(cfg)

    def body(carry, xt):
        cs, ss = carry
        y, (cs, ss) = mamba2_step(p, xt, cfg, cs, ss)
        return (cs, ss), y

    init = (jnp.zeros((B, W - 1, d_inner + 2 * N), jnp.float32),
            jnp.zeros((B, H, P, N), jnp.float32))
    _, ys = jax.lax.scan(body, init, x.swapaxes(0, 1))
    return ys.swapaxes(0, 1)
