"""Shared building blocks: init helpers, norms, RoPE, MLPs, flash attention.

Parameters are plain nested dicts of arrays; every initializer also
declares *logical sharding axes* (a parallel pytree of tuples) that
``repro.launch.shardings`` maps onto the physical mesh.  Layer stacks are
built stacked (leading L axis) and consumed by ``lax.scan`` so HLO size
and compile time are depth-independent.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]

# -- logical axis names (mapped to mesh axes in launch/shardings.py) -------
#   "embed"  : d_model        -> replicated (or fsdp'd over data)
#   "heads"  : attention heads / d_ff / experts' hidden -> "model"
#   "vocab"  : vocabulary      -> "model"
#   "layers" : stacked layers  -> replicated (scan axis)
#   "expert" : expert index    -> replicated in baseline, "model" under EP


# A pytree of logical-axis tuples mirroring a params tree.  Plain dict:
# jax.tree_util does not traverse dict *subclasses*.
AxTree = dict


def _init(rng, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(rng, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


def dense_init(rng, d_in: int, d_out: int, dtype, *, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return _init(rng, (d_in, d_out), scale, dtype)


def stacked(init_fn: Callable, rng, num: int, *args, **kw):
    """vmap an initializer over a leading stack axis (layers)."""
    rngs = jax.random.split(rng, num)
    return jax.vmap(lambda r: init_fn(r, *args, **kw))(rngs)


# -- norms ------------------------------------------------------------------
def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
            *, gemma_style: bool = False) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    w = weight.astype(jnp.float32)
    out = x * (1.0 + w) if gemma_style else x * w
    return out.astype(dt)


def head_rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (qwen3/gemma3): x (..., H, hd), weight (hd,)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


# -- rotary embeddings ----------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) or (..., H, hd) single-pos; positions (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                       # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num: int, dim: int) -> jax.Array:
    """Whisper-style sinusoids."""
    inv = 1.0 / (10000 ** (np.arange(dim // 2) / max(1, dim // 2 - 1)))
    pos = np.arange(num)[:, None] * inv[None, :]
    return jnp.asarray(np.concatenate([np.sin(pos), np.cos(pos)], axis=1),
                       jnp.float32)


# -- MLPs -------------------------------------------------------------------
def init_mlp(rng, d_model: int, d_ff: int, dtype) -> Tuple[Params, AxTree]:
    r1, r2, r3 = jax.random.split(rng, 3)
    p = {"wi": dense_init(r1, d_model, d_ff, dtype),
         "wg": dense_init(r2, d_model, d_ff, dtype),
         "wo": dense_init(r3, d_ff, d_model, dtype)}
    ax = AxTree(wi=("embed", "heads"), wg=("embed", "heads"),
                wo=("heads", "embed"))
    return p, ax


def mlp(x: jax.Array, p: Params, kind: str = "swiglu") -> jax.Array:
    act = jax.nn.gelu if kind == "geglu" else jax.nn.silu
    h = act(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


def _loss_chunk(S: int, target: int = 512) -> int:
    """Largest divisor of S that is <= target."""
    c = min(S, target)
    while S % c:
        c -= 1
    return c


def chunked_lm_loss(x: jax.Array, head_w: jax.Array, targets: jax.Array, *,
                    final_softcap: Optional[float] = None,
                    chunk: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy without materializing (B, S, V) f32 logits.

    x: (B, S, d) FINAL-NORMED hidden; head_w: (d, V); targets: (B, S)
    with -1 = masked.  Scans over sequence chunks; each chunk's logits
    are rematerialized in the backward pass (jax.checkpoint), so peak
    memory holds one (B, chunk, V) slab instead of the full logits.
    Returns (nll_sum, token_count).
    """
    B, S, d = x.shape
    c = _loss_chunk(S, chunk)
    xc = x.reshape(B, S // c, c, d).swapaxes(0, 1)        # (nc, B, c, d)
    tc = targets.reshape(B, S // c, c).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        xx, tt = xs
        logits = (xx @ head_w).astype(jnp.float32)
        if final_softcap is not None:
            logits = final_softcap * jnp.tanh(logits / final_softcap)
        mask = (tt >= 0).astype(jnp.float32)
        tgt = jnp.maximum(tt, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        nll, cnt = carry
        return (nll + ((lse - gold) * mask).sum(), cnt + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, tc))
    return nll, cnt


# -- exact blockwise (flash-style) attention for training/prefill ---------
_NEG = -1e30


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    q_chunk: int = 1024,
                    q_offset: int = 0) -> jax.Array:
    """Exact attention, scanned over query chunks to bound memory.

    q: (B, Sq, H, Dk); k: (B, Sk, KVH, Dk); v: (B, Sk, KVH, Dv).
    GQA handled by reshaping q to (B, Sq, KVH, G, Dk).  ``q_offset`` is
    the absolute position of q[0] (prefill continuation).
    Memory: O(B * H * q_chunk * Sk) instead of O(B * H * Sq * Sk).
    """
    B, Sq, H, Dk = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    if scale is None:
        scale = Dk ** -0.5
    qc = min(q_chunk, Sq)
    assert Sq % qc == 0, (Sq, qc)

    qr = (q.reshape(B, Sq // qc, qc, KVH, G, Dk)
          .transpose(1, 0, 3, 4, 2, 5))              # (nc, B, KVH, G, qc, Dk)
    kT = k.transpose(0, 2, 3, 1)                     # (B, KVH, Dk, Sk)
    vT = v.transpose(0, 2, 1, 3)                     # (B, KVH, Sk, Dv)
    kpos = jnp.arange(Sk)

    def chunk_fn(ci, qch):
        # qch: (B, KVH, G, qc, Dk).  Operands stay in the model dtype
        # (bf16 for full configs) with f32 ACCUMULATION -- halves the
        # score-matmul input traffic, the dominant train-time memory term
        # (EXPERIMENTS.md §Perf), and is exact for f32 test configs.
        s = jnp.einsum("bhgqd,bhds->bhgqs", (qch * scale).astype(kT.dtype),
                       kT, preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_offset + ci * qc + jnp.arange(qc)
        valid = jnp.ones((qc, Sk), bool)
        if causal:
            valid &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            # traced-friendly: window <= 0 means "no window" so local and
            # global layers can share one scanned body
            in_win = kpos[None, :] > qpos[:, None] - window
            valid &= jnp.logical_or(
                jnp.asarray(window) <= 0, in_win)
        s = jnp.where(valid[None, None, None], s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqs,bhsv->bhgqv", p.astype(vT.dtype), vT,
                       preferred_element_type=jnp.float32)
        return o                                     # (B, KVH, G, qc, Dv)

    out = jax.lax.map(lambda args: chunk_fn(*args),
                      (jnp.arange(Sq // qc), qr))    # (nc, B, KVH, G, qc, Dv)
    Dv = v.shape[-1]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)
