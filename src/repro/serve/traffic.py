"""Arrival traces for the continuous-batching request plane.

Requests no longer pre-load a static batch: a ``RequestSource`` feeds
``Engine.serve`` arrivals keyed on the engine's STEP clock (a
deterministic virtual time -- one decode step is one tick), so a trace
is fully replayable: the same seed produces the same prompts at the
same virtual instants, and two runs decode token-identical outputs
regardless of how wall-clock-adaptive policy (the auto prefill budget)
reshuffles admission timing.

``make_trace`` generates the paper-motivated workloads -- datacenter
colocation means many tenants sharing one machine, so the shapes that
stress software admission are:

* ``poisson``   -- memoryless arrivals (exponential inter-arrival gaps),
                   the steady-state load model.
* ``bursty``    -- arrivals land in clusters with idle gaps between
                   them; stresses admission headroom and preemption.
* ``heavytail`` -- Pareto inter-arrival gaps: long quiet stretches and
                   sudden pile-ups (the "elephants and mice" shape).
* ``static``    -- everything arrives at t=0 (the legacy pre-loaded
                   batch, for equivalence pins).
* ``prefixheavy`` -- poisson arrivals where nearly every request forks
                   a shared base prompt (chatbot system prompts /
                   few-shot headers): the target shape for COW prefix
                   sharing plus suffix-only prefill.

Tenants are assigned round-robin; ``shared_frac`` mixes in a cohort
that shares block-aligned base prompts (exercising COW prefix sharing
under live traffic); ``deadline_slack`` attaches per-request SLOs for
the deadline-cost preemption policy.
"""

from __future__ import annotations

import threading
from typing import List, Optional, Sequence

import numpy as np

from repro.serve.scheduler import Request

__all__ = ["RequestSource", "ThreadedRequestSource", "make_trace"]

TRACE_KINDS = ("static", "poisson", "bursty", "heavytail", "prefixheavy")


class RequestSource:
    """Replayable arrival stream over a fixed trace.

    ``poll(now)`` hands out every request whose ``arrival_time`` is due
    at virtual time ``now``, in arrival order (ties by rid).  The
    engine polls once per step; a source is exhausted when
    ``has_more`` goes False.
    """

    def __init__(self, requests: Sequence[Request]):
        self._trace: List[Request] = sorted(
            requests, key=lambda r: (r.arrival_time, r.rid))
        self._idx = 0

    @property
    def has_more(self) -> bool:
        return self._idx < len(self._trace)

    def __len__(self) -> int:
        return len(self._trace) - self._idx

    def poll(self, now: float) -> List[Request]:
        out: List[Request] = []
        while (self._idx < len(self._trace)
               and self._trace[self._idx].arrival_time <= now):
            out.append(self._trace[self._idx])
            self._idx += 1
        return out


class ThreadedRequestSource:
    """Thread-fed async arrival source for ``Engine.serve``.

    A producer thread calls ``submit()`` while the engine's step loop
    polls from its own thread: the submit side is the only shared
    state, guarded by one lock, so arrivals can be generated online
    (an RPC front-end, a replay thread pacing wall-clock arrivals)
    instead of from a pre-built trace.  Requests whose
    ``arrival_time`` is in the future are held back until the engine's
    virtual clock reaches them; everything else is due at the next
    poll, in ``(arrival_time, rid)`` order for determinism.

    ``has_more`` stays True until ``close()`` -- an open source keeps
    ``serve()`` ticking idle steps while it waits for the producer, so
    the producer MUST ``close()`` (or the loop runs to ``max_steps``).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._pending: List[Request] = []
        self._closed = False

    def submit(self, req: Request) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("submit() after close()")
            self._pending.append(req)

    def close(self) -> None:
        with self._lock:
            self._closed = True

    @property
    def has_more(self) -> bool:
        with self._lock:
            return bool(self._pending) or not self._closed

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)

    def poll(self, now: float) -> List[Request]:
        with self._lock:
            due = [r for r in self._pending if r.arrival_time <= now]
            self._pending = [r for r in self._pending
                             if r.arrival_time > now]
        return sorted(due, key=lambda r: (r.arrival_time, r.rid))


def _gaps(kind: str, n: int, mean_gap: float,
          rng: np.random.RandomState) -> np.ndarray:
    """Inter-arrival gaps in virtual steps, mean roughly ``mean_gap``."""
    if kind == "static":
        return np.zeros(n)
    if kind in ("poisson", "prefixheavy"):
        return rng.exponential(mean_gap, size=n)
    if kind == "bursty":
        # arrivals cluster: every burst lands together, then the lane
        # goes quiet long enough to keep the same mean rate
        gaps = np.zeros(n)
        i = 0
        while i < n:
            burst = int(rng.randint(2, 5))
            gaps[i] = rng.exponential(mean_gap) * burst
            i += burst
        return gaps
    if kind == "heavytail":
        # Pareto(alpha=1.5): finite mean (= 2 for the standard form),
        # infinite variance -- long lulls punctured by pile-ups
        return rng.pareto(1.5, size=n) * mean_gap / 2.0
    raise ValueError(f"unknown trace kind {kind!r}; "
                     f"expected one of {TRACE_KINDS}")


def make_trace(kind: str, n: int, vocab: int, *, seed: int = 0,
               mean_gap: float = 2.0, tenants: int = 1,
               max_new: int = 8, prompt_cap: int = 24,
               shared_frac: float = 0.0, n_bases: int = 2,
               deadline_slack: Optional[float] = None,
               priority_classes: Optional[Sequence[int]] = None
               ) -> RequestSource:
    """Seeded, replayable arrival trace (see module docstring).

    ``deadline_slack`` (in decode-steps per owed token) sets
    ``deadline = arrival + slack * max_new``; ``priority_classes``
    cycles the given classes across requests.  Same seed, same trace --
    byte-for-byte.
    """
    if kind == "prefixheavy" and shared_frac <= 0.0:
        # nearly every request rides a shared base unless the caller
        # pinned an explicit mix; still seeded and fully replayable
        shared_frac = 0.85
    rng = np.random.RandomState(seed)
    gaps = _gaps(kind, n, mean_gap, rng)
    bases = [rng.randint(2, vocab, size=int(rng.randint(
        max(4, prompt_cap // 2), prompt_cap))) for _ in range(n_bases)]
    reqs: List[Request] = []
    t = 0.0
    for i in range(n):
        t += float(gaps[i])
        if shared_frac > 0.0 and rng.rand() < shared_frac:
            base = bases[int(rng.randint(len(bases)))]
            extra = int(rng.randint(0, 6))
            prompt = (np.concatenate([base, rng.randint(2, vocab,
                                                        size=extra)])
                      if extra else base.copy())
        else:
            prompt = rng.randint(2, vocab,
                                 size=int(rng.randint(4, prompt_cap)))
        reqs.append(Request(
            rid=i, prompt=prompt, max_new=max_new,
            tenant=f"tenant{i % max(1, tenants)}",
            arrival_time=round(t, 6),
            deadline=(None if deadline_slack is None
                      else round(t + deadline_slack * max_new, 6)),
            priority_class=(0 if not priority_classes
                            else int(priority_classes[
                                i % len(priority_classes)]))))
    return RequestSource(reqs)
