"""Serving policy layer: WHAT runs next, never HOW it runs.

This module is deliberately device-free (no jax imports): it decides
admissions, resumes and preemption victims from block-count arithmetic
only, and the engine executes those decisions against the pool.  The
split mirrors the paper's architecture -- a tiny software memory manager
making policy over fixed-size blocks, with mechanism (DMA, scatter,
prefill) kept elsewhere.

Policies implemented:

* **Pluggable admission order** -- the queue of not-yet-admitted
  requests is an ``AdmissionPolicy``.  The pinned default,
  ``FCFSAdmission``, serves strictly in submission order within each
  ``priority_class`` (lower class value = more urgent; everything
  defaults to class 0, which makes the default policy decision-
  identical to the pre-request-plane FCFS queue).  ``FairAdmission``
  adds per-tenant token-rate fairness via deficit round-robin: each
  backlogged tenant accrues whole quanta of token credit until some
  head-of-line request is affordable, the richest affordable tenant is
  served and charged its worst-case tokens -- a flooding tenant can
  only consume its share while another tenant is backlogged, yet a
  lone tenant is never throttled (work-conserving crediting).
* **FCFS admission with a free-block watermark** -- queued requests are
  admitted in arrival order, and only while admission leaves at least
  ``watermark`` blocks free (headroom for the per-``block_tokens``-steps
  growth of already-running sequences).  The watermark is ADAPTIVE by
  default: an EWMA of observed allocation per step (growth + COW copy
  targets, reported by the engine via ``observe_growth``) times a small
  lookahead horizon, so headroom tracks the workload instead of a
  hand-tuned constant; passing ``watermark=<int>`` overrides the
  adaptive path with the static knob.  A request is only ever admitted
  when its WORST-CASE footprint (prompt + max_new tokens) currently
  fits: blocks are handed out lazily as the sequence grows, but the
  up-front check plus LIFO preemption guarantees the oldest running
  sequence can always reclaim enough blocks to finish.
* **Deadline-cost preemption with a LIFO fallback** -- the victim is
  the running request whose eviction does the least SLO damage: the
  one with the MOST deadline slack (``deadline - now - remaining
  decode steps``), ties broken by the most recent admission.  Requests
  without a deadline have infinite slack, so with no deadlines
  configured the choice degenerates EXACTLY to the existing LIFO rule
  -- the most recently *admitted* request (``admit_order``, a
  monotonic counter stamped on every admission including resumes --
  NOT the request id, which is submission order).  Newest-first
  eviction is what makes the progress argument above work; the
  engine advances ``Scheduler.now`` (its step counter, a deterministic
  virtual clock) so deadline arithmetic never reads the wall clock.
* **Chunked/batched prefill budgeting** -- each step admits at most
  ``prefill_budget`` prompt tokens (the engine prefills all of a step's
  admissions in ONE padded batched call), bounding per-step latency
  spikes.  The budget never blocks the first admission of an otherwise
  idle engine.  ``prefill_budget="auto"`` derives the budget from an
  EWMA of MEASURED prefill latency (the same adapt-with-knob-override
  pattern as the watermark): the engine reports seconds-per-prefill-
  token and seconds-per-decode-step (``observe_prefill`` /
  ``observe_decode``), and the budget is sized so one step's prefill
  takes at most ``prefill_slack`` decode-steps' worth of wall time.
  ``"auto"`` is the DEFAULT (the adapt-by-default flip the ROADMAP
  carried since the knob landed): wall-clock-derived policy is not
  deterministic across runs, so schedule-equivalence pins compare
  per-request tokens (never step counts) and pass an explicit
  ``prefill_budget=None`` where they need the unthrottled schedule;
  the integer knob remains the static override.

Resumed requests are preferred over new ones and pop LIFO off a
``BlockStack`` (the paper's split stack backing a runtime structure).
They carry their saved KV payload, so they cost no prefill budget --
and ``resume_candidates()`` exposes the LIFO head to the engine so the
transfer plane can PREFETCH its swap-in on the background h2d lane
while decode runs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.stack import BlockStack
from repro.mem import Arena


class PoolGroupMismatchError(RuntimeError):
    """A fork's parent lives in a different dp pool group than the child.

    With ``dp_groups > 1`` block tables hold GROUP-LOCAL ids: aliasing a
    parent block from another group would silently address a different
    physical block in the child's pool range, corrupting both tables.
    Admission rejects the fork loudly instead (ROADMAP 'dp_groups > 1
    serving' seam).
    """


def slot_group(slot: int, slots: int, dp_groups: int) -> int:
    """Pool group of a batch slot: slots split into dp_groups contiguous
    ranges, co-sharded with the pool's block dim (see PagedKVConfig)."""
    return slot * dp_groups // slots


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,)
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    state: str = "queued"              # queued|running|preempted|done
    slot: int = -1
    admit_order: int = -1              # monotonic admission stamp (LIFO key)
    pending_tok: int = -1              # next input token saved at preemption
    # ---- request plane (multi-tenant streaming admission) ----
    tenant: str = "default"            # FairAdmission's fairness domain
    arrival_time: float = 0.0          # virtual (engine-step) arrival clock
    deadline: Optional[float] = None   # SLO, same clock; None = best effort
    priority_class: int = 0            # lower = more urgent (0 = default)
    # wall-clock latency telemetry (perf_counter seconds; stamped by the
    # engine, never read by policy -- policy clocks are virtual)
    t_submit: float = -1.0
    t_first: float = -1.0              # first token available (prefill done)
    t_done: float = -1.0

    @property
    def tokens_held(self) -> int:
        return len(self.prompt) + len(self.generated)

    @property
    def max_tokens(self) -> int:
        """Worst-case footprint in tokens (prompt + full generation)."""
        return len(self.prompt) + self.max_new

    def slack(self, now: float) -> float:
        """Deadline headroom at virtual time ``now``: time left minus
        the decode steps still owed.  Infinite without a deadline, so
        no-deadline workloads sort purely by the LIFO stamp."""
        if self.deadline is None:
            return float("inf")
        return self.deadline - now - (self.max_new - len(self.generated))


class AdmissionPolicy:
    """Order over queued (never-yet-admitted) requests.

    The scheduler only ever looks at the head (``peek``) and consumes
    it (``pop``); a policy is free to reorder between calls but must
    return from ``pop`` exactly what ``peek`` showed, with no state
    change on ``peek`` -- ``plan_admissions`` peeks to negotiate block
    leases and pops only when the candidate actually fits.
    """

    def push(self, req: Request) -> None:
        raise NotImplementedError

    def peek(self) -> Optional[Request]:
        raise NotImplementedError

    def pop(self) -> Request:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def snapshot(self) -> List[Request]:
        """All queued requests, in the policy's current service order
        (introspection only -- compat surface for ``Scheduler.queue``)."""
        raise NotImplementedError


class FCFSAdmission(AdmissionPolicy):
    """Priority-bucketed FCFS: strict submission order within each
    ``priority_class``, lower class first.  With every request in the
    default class 0 this is EXACTLY the pre-request-plane FIFO list --
    the pinned default policy."""

    def __init__(self):
        self._queue: List[Request] = []          # submission order

    def push(self, req: Request) -> None:
        self._queue.append(req)

    @staticmethod
    def _key(req: Request, i: int) -> Tuple:
        # EDF within each priority class: (class, deadline, submission
        # index).  A request without a deadline sorts at +inf, so an
        # all-best-effort queue reduces EXACTLY to (class, index) -- the
        # pre-EDF order, and with all-zero classes to index 0, the
        # original queue[0].
        return (req.priority_class,
                req.deadline if req.deadline is not None else float("inf"),
                i)

    def _head_idx(self) -> int:
        return min(range(len(self._queue)),
                   key=lambda i: self._key(self._queue[i], i))

    def peek(self) -> Optional[Request]:
        return self._queue[self._head_idx()] if self._queue else None

    def pop(self) -> Request:
        return self._queue.pop(self._head_idx())

    def __len__(self) -> int:
        return len(self._queue)

    def snapshot(self) -> List[Request]:
        idx = sorted(range(len(self._queue)),
                     key=lambda i: self._key(self._queue[i], i))
        return [self._queue[i] for i in idx]


class FairAdmission(AdmissionPolicy):
    """Per-tenant token-rate fairness via deficit round-robin.

    Every tenant owns a FIFO queue and a token-deficit counter.  When a
    candidate is needed, all BACKLOGGED tenants are credited the least
    number of whole ``quantum``-token rounds that makes some head
    request affordable (work conservation: a lone tenant is never
    throttled, and credit only accrues while competing work exists);
    the affordable tenant with the largest resulting deficit is served
    and charged the request's WORST-CASE tokens (``max_tokens`` -- the
    same currency the admission block gate reasons in).  Ties break by
    tenant registration order, so the schedule is deterministic.  A
    tenant's deficit resets when its queue empties -- saved-up credit
    must not buy a later flood.
    """

    def __init__(self, quantum: int = 32):
        if quantum <= 0:
            raise ValueError("quantum must be positive")
        self.quantum = quantum
        self._tenants: Dict[str, List[Request]] = {}   # registration order
        self.deficit: Dict[str, float] = {}

    def push(self, req: Request) -> None:
        self._tenants.setdefault(req.tenant, [])
        self.deficit.setdefault(req.tenant, 0.0)
        self._tenants[req.tenant].append(req)

    @staticmethod
    def _cost(req: Request) -> int:
        return max(1, req.max_tokens)

    def _select(self) -> Optional[Tuple[str, int]]:
        """(tenant to serve, quanta to credit) -- pure, no mutation."""
        backlogged = [t for t, q in self._tenants.items() if q]
        if not backlogged:
            return None
        rounds = min(
            max(0, -(-int(self._cost(self._tenants[t][0])
                          - self.deficit[t]) // self.quantum))
            for t in backlogged)
        order = {t: i for i, t in enumerate(self._tenants)}
        afford = [t for t in backlogged
                  if self.deficit[t] + rounds * self.quantum
                  >= self._cost(self._tenants[t][0])]
        best = max(afford, key=lambda t: (self.deficit[t]
                                          + rounds * self.quantum,
                                          -order[t]))
        return best, rounds

    def peek(self) -> Optional[Request]:
        sel = self._select()
        return self._tenants[sel[0]][0] if sel else None

    def pop(self) -> Request:
        tenant, rounds = self._select()
        if rounds:
            for t, q in self._tenants.items():
                if q:
                    self.deficit[t] += rounds * self.quantum
        req = self._tenants[tenant].pop(0)
        self.deficit[tenant] -= self._cost(req)
        if not self._tenants[tenant]:
            self.deficit[tenant] = 0.0
        return req

    def __len__(self) -> int:
        return sum(len(q) for q in self._tenants.values())

    def snapshot(self) -> List[Request]:
        return [r for q in self._tenants.values() for r in q]


@dataclasses.dataclass
class StepPlan:
    """One step's admission decisions, in execution order."""
    resume: List[Request] = dataclasses.field(default_factory=list)
    admit: List[Request] = dataclasses.field(default_factory=list)
    #: popped candidates whose tenant is over its block quota -- never
    #: admitted; the engine finishes them with state="rejected"
    reject: List[Request] = dataclasses.field(default_factory=list)

    def __bool__(self) -> bool:
        return bool(self.resume or self.admit)


class Scheduler:
    """Policy-only continuous-batching scheduler (see module docstring)."""

    #: pool class for the scheduler's own runtime structures (the
    #: preempted-LIFO BlockStack) when it shares the engine's Arena
    META_CLASS = "sched-meta"

    def __init__(self, *, watermark: Optional[int] = None,
                 prefill_budget="auto",
                 policy: Optional[AdmissionPolicy] = None,
                 arena: Optional[Arena] = None,
                 growth_alpha: float = 0.25, growth_horizon: int = 4,
                 latency_alpha: float = 0.25, prefill_slack: int = 4):
        if watermark is not None and watermark < 0:
            raise ValueError("watermark must be >= 0")
        if not (prefill_budget is None or prefill_budget == "auto"):
            if not isinstance(prefill_budget, int) or prefill_budget <= 0:
                raise ValueError(
                    "prefill_budget must be a positive int, 'auto', or "
                    "None")
        if not 0.0 < growth_alpha <= 1.0:
            raise ValueError("growth_alpha must be in (0, 1]")
        if not 0.0 < latency_alpha <= 1.0:
            raise ValueError("latency_alpha must be in (0, 1]")
        if prefill_slack <= 0:
            raise ValueError("prefill_slack must be positive")
        #: static override; None selects the adaptive EWMA watermark
        self.watermark_override = watermark
        self.growth_alpha = growth_alpha
        self.growth_horizon = growth_horizon
        self._growth_ewma = 0.0
        #: int = static budget, "auto" = derived from measured latency,
        #: None = unlimited
        self.prefill_budget_override = (prefill_budget
                                        if isinstance(prefill_budget, int)
                                        else None)
        self.prefill_auto = prefill_budget == "auto"
        self.latency_alpha = latency_alpha
        self.prefill_slack = prefill_slack
        self._prefill_spt_ewma = 0.0   # seconds per prefill token
        self._decode_s_ewma = 0.0      # seconds per decode step
        #: admission order over queued arrivals (FCFS pinned default)
        self.policy = policy if policy is not None else FCFSAdmission()
        #: virtual clock for deadline arithmetic -- the engine writes
        #: its step counter here; policy never reads the wall clock
        self.now = 0.0
        #: optional hook: Request -> prefill tokens actually computed.
        #: The engine points this at its suffix-prefill cost (a forked
        #: child bills only its un-cached suffix against the budget);
        #: None bills the whole prompt.
        self.prefill_cost_fn = None
        if arena is not None:
            # scheduler scratch rides the same address space as the KV
            # pool -- NOTHING in the runtime asks for contiguous memory
            arena.register_class(self.META_CLASS, num_blocks=4096,
                                 block_nbytes=256 * 8)
            self.preempted = BlockStack(block_size=256, arena=arena,
                                        pool_class=self.META_CLASS,
                                        owner="scheduler.preempted")
        else:
            self.preempted = BlockStack(block_size=256)  # LIFO resume order
        self._admit_counter = 0

    # ---------------- adaptive watermark ----------------
    @property
    def watermark(self) -> int:
        """Free-block headroom demanded beyond each admission.

        Static when the constructor knob was given; otherwise derived
        from the observed allocation rate: ``ceil(EWMA(blocks/step) *
        growth_horizon)`` -- enough free blocks for the running set to
        keep growing for ``growth_horizon`` steps while the next
        admission's worst case is reserved.
        """
        if self.watermark_override is not None:
            return self.watermark_override
        return int(np.ceil(self._growth_ewma * self.growth_horizon))

    def observe_growth(self, blocks: int) -> None:
        """Engine feedback: blocks allocated for growth + COW targets
        this step (drives the adaptive watermark)."""
        a = self.growth_alpha
        self._growth_ewma = (1 - a) * self._growth_ewma + a * max(0, blocks)

    # ---------------- adaptive prefill budget ----------------
    @property
    def prefill_budget(self) -> Optional[int]:
        """Per-step prompt-token budget (None = unlimited).

        Static when the constructor knob was an int; with ``"auto"``,
        derived from measured latency: enough tokens that one step's
        prefill costs at most ``prefill_slack`` decode-steps of wall
        time (``prefill_slack * EWMA(s/decode-step) /
        EWMA(s/prefill-token)``).  Unlimited until both EWMAs have
        observations -- the first admission is never blocked.
        """
        if not self.prefill_auto:
            return self.prefill_budget_override
        if self._prefill_spt_ewma <= 0.0 or self._decode_s_ewma <= 0.0:
            return None
        return max(1, int(self.prefill_slack * self._decode_s_ewma
                          / self._prefill_spt_ewma))

    def observe_prefill(self, tokens: int, seconds: float) -> None:
        """Engine feedback: one batched prefill of ``tokens`` prompt
        tokens took ``seconds`` (drives the auto prefill budget)."""
        if tokens <= 0 or seconds <= 0.0:
            return
        a = self.latency_alpha
        spt = seconds / tokens
        self._prefill_spt_ewma = ((1 - a) * self._prefill_spt_ewma + a * spt
                                  if self._prefill_spt_ewma > 0.0 else spt)

    def observe_decode(self, seconds: float) -> None:
        """Engine feedback: one decode step took ``seconds``."""
        if seconds <= 0.0:
            return
        a = self.latency_alpha
        self._decode_s_ewma = ((1 - a) * self._decode_s_ewma + a * seconds
                               if self._decode_s_ewma > 0.0 else seconds)

    # ---------------- intake ----------------
    def submit(self, req: Request) -> None:
        req.state = "queued"
        self.policy.push(req)

    def on_preempt(self, req: Request) -> None:
        req.state = "preempted"
        self.preempted.push(req)

    @property
    def queue(self) -> List[Request]:
        """Queued (never admitted) requests in service order -- a
        snapshot view over the admission policy (compat surface)."""
        return self.policy.snapshot()

    @property
    def has_work(self) -> bool:
        return len(self.policy) > 0 or len(self.preempted) > 0

    #: speculative resume window: how deep into the preempted stack the
    #: prefetcher may look (top-k, most-likely-next first)
    prefetch_window = 2

    def resume_candidates(self) -> List[Request]:
        """The LIFO resume candidates, most-likely-next first (top-k
        window, ``prefetch_window`` deep).

        This is the policy surface the speculative prefetch rides: the
        head of the preempted stack is the next sequence a freed slot
        will resume and the second entry follows it, so the engine can
        enqueue their swap-ins on the background h2d lane WHILE decode
        runs and commit (or cancel) them when the admission decision
        actually lands.  The ordering doubles as the cancellation
        likelihood ranking under pressure: entries deeper in the window
        are withdrawn first.  Peeking never changes scheduling state.
        """
        return self.preempted.peek_n(self.prefetch_window)

    # ---------------- admission ----------------
    def _stamp(self, req: Request) -> Request:
        req.admit_order = self._admit_counter
        self._admit_counter += 1
        return req

    def plan_admissions(self, free_slots: int, mem,
                        num_running: int) -> StepPlan:
        """Pop as many candidates as policy allows this step.

        ``mem`` is the lease-negotiation view (PagedKVManager or
        anything with ``blocks_needed(tokens)`` and ``free_blocks`` --
        the number of leases the shared Arena can grant right now;
        legacy stubs exposing ``allocator.num_free`` still work).
        Candidates are considered strictly in order (resumes LIFO first,
        then the FCFS queue head); the first one that does not fit ends
        admission -- no queue jumping, so admission order equals
        completion-pressure order.

        A strategy view exposing ``footprint(req)`` (per-pool-class
        block dict) takes the VECTOR path instead: the same loop over a
        dict of per-class free counts, the watermark applied only to
        classes the strategy declares growing (a constant-state class's
        footprint is exact, so no headroom is reserved for it), plus
        per-tenant quota enforcement -- over-quota candidates are popped
        onto ``StepPlan.reject`` instead of blocking the head of line.
        """
        if hasattr(mem, "footprint"):
            return self._plan_admissions_vector(free_slots, mem,
                                                num_running)
        plan = StepPlan()
        free = getattr(mem, "free_blocks", None)
        if free is None:                     # legacy accounting stubs
            free = mem.allocator.num_free
        budget = self.prefill_budget
        while free_slots > 0:
            from_preempted = len(self.preempted) > 0
            cand: Request = (self.preempted.peek() if from_preempted
                             else self.policy.peek())
            if cand is None:
                break
            need = mem.blocks_needed(cand.max_tokens)
            busy = num_running > 0 or bool(plan)
            if need > free:
                break                    # worst-case footprint must fit
            if busy and free - need < self.watermark:
                break                    # keep growth headroom
            # suffix-only prefill: the cost hook bills just the tokens
            # the engine will actually compute (a forked child's
            # un-cached suffix).  Plan-time lookup runs BEFORE this
            # step's other admissions register their prefixes, so the
            # estimate can only err high -- never over-admits.
            cost = (0 if from_preempted
                    else self.prefill_cost_fn(cand) if self.prefill_cost_fn
                    else cand.tokens_held)
            if busy and budget is not None and cost > budget:
                break                    # prefill chunking
            if from_preempted:
                self.preempted.pop()
                plan.resume.append(self._stamp(cand))
            else:
                self.policy.pop()
                plan.admit.append(self._stamp(cand))
            free -= need
            if budget is not None:
                budget = max(0, budget - cost)
            free_slots -= 1
        return plan

    def _plan_admissions_vector(self, free_slots: int, mem,
                                num_running: int) -> StepPlan:
        """Per-pool-class admission against a strategy view (see
        ``plan_admissions``).  Byte-for-byte the scalar loop when the
        strategy has one growing class and no quotas."""
        plan = StepPlan()
        free = {c: int(n) for c, n in mem.free_by_class().items()}
        growing = frozenset(getattr(mem, "growing_classes", free))
        budget = self.prefill_budget
        planned: Dict[Tuple[str, str], int] = {}
        while free_slots > 0:
            from_preempted = len(self.preempted) > 0
            cand: Request = (self.preempted.peek() if from_preempted
                             else self.policy.peek())
            if cand is None:
                break
            need = mem.footprint(cand)
            if not from_preempted and hasattr(mem, "quota_headroom"):
                room = mem.quota_headroom(cand.tenant)
                if any(room.get(c, float("inf"))
                       - planned.get((cand.tenant, c), 0) < n
                       for c, n in need.items()):
                    # over-quota: reject rather than stall the queue --
                    # a quota violation never resolves by waiting
                    self.policy.pop()
                    plan.reject.append(cand)
                    continue
            busy = num_running > 0 or bool(plan)
            if any(n > free.get(c, 0) for c, n in need.items()):
                break                    # worst-case footprint must fit
            if busy and any(c in growing
                            and free.get(c, 0) - n < self.watermark
                            for c, n in need.items()):
                break                    # growth headroom (growing only)
            cost = (0 if from_preempted
                    else self.prefill_cost_fn(cand) if self.prefill_cost_fn
                    else cand.tokens_held)
            if busy and budget is not None and cost > budget:
                break                    # prefill chunking
            if from_preempted:
                self.preempted.pop()
                plan.resume.append(self._stamp(cand))
            else:
                self.policy.pop()
                plan.admit.append(self._stamp(cand))
            for c, n in need.items():
                free[c] = free.get(c, 0) - n
                key = (cand.tenant, c)
                planned[key] = planned.get(key, 0) + n
            if budget is not None:
                budget = max(0, budget - cost)
            free_slots -= 1
        return plan

    # ---------------- preemption ----------------
    def pick_victim(self, running: Dict[int, Request]) -> int:
        """Slot whose eviction does the least SLO damage.

        Deadline-cost rule: evict the request with the MOST deadline
        slack at the current virtual time (``Request.slack`` -- time
        left minus decode steps owed), ties broken by the most recent
        admission.  Requests without deadlines have infinite slack, so
        with no deadlines configured this is EXACTLY the original LIFO
        rule -- the max ``admit_order`` -- which keeps every PR 2-5
        schedule pin decision-identical.  Keyed on ``admit_order``, not
        rid: a resumed request that was submitted early but re-admitted
        late is still evicted before older residents.
        """
        if not running:
            raise ValueError("no running requests to preempt")
        return max(running, key=lambda s: (running[s].slack(self.now),
                                           running[s].admit_order))

    # ---------------- fork admission (dp pool groups) ----------------
    @staticmethod
    def validate_fork(parent_slot: int, child_slot: int, slots: int,
                      dp_groups: int) -> None:
        """Admission gate for COW forks under data-parallel pool groups.

        Block tables hold group-local ids when ``dp_groups > 1``, so a
        child may only alias a parent resident in ITS OWN pool group;
        anything else must fail loudly (silent aliasing across groups
        corrupts both tables).  No-op for the common dp_groups == 1.
        """
        if dp_groups <= 1:
            return
        pg = slot_group(parent_slot, slots, dp_groups)
        cg = slot_group(child_slot, slots, dp_groups)
        if pg != cg:
            raise PoolGroupMismatchError(
                f"fork parent in pool group {pg} (slot {parent_slot}) "
                f"but child in group {cg} (slot {child_slot}); "
                f"cross-group aliasing of group-local block ids would "
                f"corrupt both tables")
