"""Layered serving stack: policy (scheduler) / host store (swap) /
mechanism (engine).  See serve/README.md for the layering contract."""

from repro.serve.engine import Engine
from repro.serve.scheduler import Request, Scheduler, StepPlan
from repro.serve.swap import HostBlockStore, SwapStats

__all__ = ["Engine", "Request", "Scheduler", "StepPlan",
           "HostBlockStore", "SwapStats"]
