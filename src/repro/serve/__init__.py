"""Layered serving stack: policy (scheduler) / host store (swap) /
mechanism (engine) / arrivals (traffic).  See serve/README.md for the
layering contract."""

from repro.serve.engine import Engine
from repro.serve.scheduler import (AdmissionPolicy, FairAdmission,
                                   FCFSAdmission, Request, Scheduler,
                                   StepPlan)
from repro.serve.swap import HostBlockStore, SwapStats
from repro.serve.traffic import RequestSource, make_trace

__all__ = ["Engine", "Request", "Scheduler", "StepPlan",
           "AdmissionPolicy", "FCFSAdmission", "FairAdmission",
           "HostBlockStore", "SwapStats", "RequestSource", "make_trace"]
