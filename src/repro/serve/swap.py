"""Host swap ledger: block-granular device<->host (paper 'Swapping').

Since the transfer-plane redesign, NOTHING here moves bytes.  Swap-out
and swap-in are ``TransferPlan``s produced by ``Mapping.migrate`` and
executed by the Arena's ``TransferQueue`` (``mem/transfer.py`` -- the
only module allowed to touch the block-copy kernels or the host tier's
payload verbs; a grep-enforced test pins that rule).  This module is the
serving stack's *ledger and view* over that plane:

  * ``SwapStats`` accumulates the byte ledger from completed plans (the
    store registers itself as a queue observer), preserving the
    regression surface: every swap-out moves exactly

        blocks_held * config.swap_nbytes_per_block()

    bytes -- proportional to what the sequence holds and INDEPENDENT of
    pool size.  The naive alternative (materialising the whole pool on
    host and slicing there) moves ``num_blocks / blocks_held`` times
    more; tests pin this ratio out of existence, the same way the cost
    model pins pool-size-independent byte bills.
  * ``__contains__`` / ``__len__`` are the engine-invariant views:
    residency lives in the Arena's host tier, and a sequence mid-swap
    (payload still in a dispatched-but-unfenced d2h plan) is IN TRANSIT,
    which ``Engine.check_consistency`` accounts for explicitly.

Because payload transfers ride the queue, swap-out device gathers
dispatch at step N and their host copies land at the step N+1 fence --
the double-buffering the ROADMAP asked for -- while ``queue.drain()``
remains the synchronous fallback with byte-identical traffic
(asserted by ``bench_serve --smoke``).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.mem import Arena
from repro.mem.transfer import D2H, H2D, TransferPlan


@dataclasses.dataclass
class SwapStats:
    swap_outs: int = 0
    swap_ins: int = 0
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0
    last_swap_out_bytes: int = 0
    # (seq_id, blocks_moved, bytes_moved) per swap-out, completion order
    out_log: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)


class HostBlockStore:
    """Byte ledger + residency view for preempted sequences' payloads.

    Standalone construction (no arena) creates a private Arena so the
    class keeps working as a self-contained store; serving passes the
    engine's shared arena + pool class so host-tier residency, payloads
    and ``ArenaStats`` placement counts all live in ONE address space.
    The ledger updates when plans COMPLETE (at the fence), so bytes
    reported are bytes actually moved.
    """

    def __init__(self, arena: Optional[Arena] = None,
                 pool_class: str = "kv"):
        self.arena = arena if arena is not None else Arena()
        self.pool_class = pool_class
        self.stats = SwapStats()
        self.arena.transfers.add_observer(self._on_complete,
                                          key=f"swap-ledger:{pool_class}")

    def _on_complete(self, plan: TransferPlan) -> None:
        if plan.pool_class != self.pool_class:
            return
        st = self.stats
        if plan.direction == D2H and plan.kind == "swap-out":
            st.swap_outs += 1
            st.swap_out_bytes += plan.nbytes
            st.last_swap_out_bytes = plan.nbytes
            st.out_log.append((plan.owner, int(plan.src.size), plan.nbytes))
        elif plan.direction == H2D and plan.kind == "swap-in":
            st.swap_ins += 1
            st.swap_in_bytes += plan.nbytes

    # ---------------- residency views ----------------
    def __contains__(self, seq_id: int) -> bool:
        return self.arena.host_contains(self.pool_class, seq_id)

    def __len__(self) -> int:
        return self.arena.host_len(self.pool_class)

    def in_transit(self, seq_id: int) -> bool:
        """Swap-out enqueued/dispatched but its host copy not fenced yet."""
        return seq_id in self.arena.transfers.in_transit(self.pool_class)

    # NOTE: cancelling a sequence while preempted goes through
    # ``PagedKVManager.release`` (``Mapping.free``), which settles any
    # in-transit plan and tears down host residency AND payload together
    # -- a store-level drop would desync the two views the engine's
    # check_consistency pins.
