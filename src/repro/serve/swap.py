"""Host swap transfers: block-granular device<->host (paper 'Swapping').

The mechanism half of preemption, now a thin TRANSFER layer over the
``repro.mem.Arena`` host tier: residency (who lives host-side, how many
blocks) is Arena state written by ``Mapping.migrate``; this module only
moves payloads and keeps the byte ledger.  Swap-out first runs a COMPACT
gather on device (``kernels.block_copy.gather_blocks`` -- only the
preempted sequence's blocks, ``k_pool[:, idx]``), then moves that one
small array host-side and deposits it in the arena
(``Arena.host_deposit``); swap-in takes the payload back
(``Arena.host_take``) and scatters it into freshly allocated blocks.
Bytes moved are therefore exactly

    blocks_held * config.swap_nbytes_per_block()

per swap -- proportional to what the sequence holds and INDEPENDENT of
pool size.  The naive alternative (materialising the whole pool on host
and slicing there) moves ``num_blocks / blocks_held`` times more; the
regression tests pin this ratio out of existence, the same way the cost
model pins pool-size-independent byte bills.

Every transfer is logged in ``SwapStats`` so the serving benchmark can
report swap traffic per step and tests can assert the proportionality.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.paged_kv import PagedKVCache
from repro.kernels import ops
from repro.mem import Arena


@dataclasses.dataclass
class SwapStats:
    swap_outs: int = 0
    swap_ins: int = 0
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0
    last_swap_out_bytes: int = 0
    # (seq_id, blocks_moved, bytes_moved) per swap-out, oldest first
    out_log: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)


class HostBlockStore:
    """Transfer layer for preempted sequences' KV payloads.

    Standalone construction (no arena) creates a private Arena so the
    class keeps working as a self-contained store; serving passes the
    engine's shared arena + pool class so host-tier residency, payloads
    and ``ArenaStats`` placement counts all live in ONE address space.
    """

    def __init__(self, arena: Optional[Arena] = None,
                 pool_class: str = "kv"):
        self.arena = arena if arena is not None else Arena()
        self.pool_class = pool_class
        self.stats = SwapStats()

    def __contains__(self, seq_id: int) -> bool:
        return self.arena.host_contains(self.pool_class, seq_id)

    def __len__(self) -> int:
        return self.arena.host_len(self.pool_class)

    # ---------------- device -> host ----------------
    def swap_out(self, seq_id: int, cache: PagedKVCache,
                 block_ids: List[int]) -> None:
        """Gather ``block_ids`` on device, then one transfer per stream.

        Must be called while the blocks still hold the sequence's data
        (i.e. BEFORE the pool positions are rewritten); the manager may
        free the ids immediately after -- the gather reads the current
        functional snapshot.
        """
        idx = jnp.asarray(np.asarray(block_ids, np.int32))
        k_host = np.asarray(ops.gather_blocks(cache.k_pool, idx))
        v_host = None
        if cache.v_pool is not None:
            v_host = np.asarray(ops.gather_blocks(cache.v_pool, idx))
        moved = k_host.nbytes + (0 if v_host is None else v_host.nbytes)
        self.arena.host_deposit(self.pool_class, seq_id, (k_host, v_host),
                                moved)
        st = self.stats
        st.swap_outs += 1
        st.swap_out_bytes += moved
        st.last_swap_out_bytes = moved
        st.out_log.append((seq_id, len(block_ids), moved))

    # ---------------- host -> device ----------------
    def swap_in(self, seq_id: int, cache: PagedKVCache,
                new_ids: List[int]) -> PagedKVCache:
        """Scatter the saved payload into ``new_ids`` (any physical
        blocks -- the table absorbs relocation) and return the updated
        cache."""
        k_host, v_host = self.arena.host_take(self.pool_class, seq_id)
        if len(new_ids) != k_host.shape[1]:
            raise ValueError(
                f"swap-in of {k_host.shape[1]} saved blocks into "
                f"{len(new_ids)} fresh ids")
        idx = jnp.asarray(np.asarray(new_ids, np.int32))
        k_pool = cache.k_pool.at[:, idx].set(jnp.asarray(k_host))
        v_pool = cache.v_pool
        if v_host is not None:
            v_pool = cache.v_pool.at[:, idx].set(jnp.asarray(v_host))
        st = self.stats
        st.swap_ins += 1
        st.swap_in_bytes += k_host.nbytes + (
            0 if v_host is None else v_host.nbytes)
        return dataclasses.replace(cache, k_pool=k_pool, v_pool=v_pool)

    # NOTE: cancelling a sequence while preempted goes through
    # ``PagedKVManager.release`` (``Mapping.free``), which tears down
    # host residency AND payload together -- a store-level drop would
    # desync the two views the engine's check_consistency pins.
