"""Host swap ledger: block-granular device<->host (paper 'Swapping').

Since the transfer-plane redesign, NOTHING here moves bytes.  Swap-out
and swap-in are ``TransferPlan``s produced by ``Mapping.migrate`` (or
``Mapping.prefetch``) and executed by the Arena's per-direction
``TransferEngine``s (``mem/transfer.py`` -- the only module allowed to
touch the block-copy kernels or the host tier's payload verbs; a
grep-enforced test pins that rule).  This module is the serving stack's
*ledger and view* over that plane, KEYED BY ENGINE:

  * ``SwapStats`` accumulates the byte ledger from completed plans (the
    store registers itself as a queue observer), split per engine/lane
    (``by_engine``): d2h swap-outs, urgent-lane h2d swap-ins, and
    background-lane speculative prefetches each have their own row, so
    prefetch traffic is never conflated with demand swap traffic.  The
    regression surface is preserved: every swap-out moves exactly

        blocks_held * config.swap_nbytes_per_block()

    bytes -- proportional to what the sequence holds and INDEPENDENT of
    pool size.  The naive alternative (materialising the whole pool on
    host and slicing there) moves ``num_blocks / blocks_held`` times
    more; tests pin this ratio out of existence, the same way the cost
    model pins pool-size-independent byte bills.
  * **speculative accounting is two-phase**: a completed prefetch
    scatter parks its bytes in ``pending_prefetch`` (moved, but not yet
    a swap-in -- the host copy is still authoritative); the engine's
    ``commit_prefetch`` folds them into ``swap_ins``/``swap_in_bytes``
    when the resume actually lands, and ``cancel_prefetch`` writes them
    off as ``prefetch_wasted_bytes``.  The demand-swap ledger is
    therefore byte-identical between the prefetching schedule and the
    ``drain()`` fallback (asserted by ``bench_serve --smoke``), while
    the speculation's true cost stays visible.
  * ``__contains__`` / ``__len__`` are the engine-invariant views:
    residency lives in the Arena's host tier, and a sequence mid-swap
    (payload still in a dispatched-but-unfenced d2h plan) is IN
    TRANSIT, which ``Engine.check_consistency`` accounts for
    explicitly.

Because payload transfers ride the queues, swap-out device gathers
dispatch at step N and their host copies land at the step N+1 fence --
the double-buffering the ROADMAP asked for -- while ``queue.drain()``
remains the synchronous fallback with byte-identical demand traffic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.mem import Arena
from repro.mem.transfer import D2H, H2D, TransferPlan


def _engine_rows() -> Dict[str, Dict[str, int]]:
    return {"d2h": {"plans": 0, "bytes": 0},
            "h2d": {"plans": 0, "bytes": 0},
            "h2d-prefetch": {"plans": 0, "bytes": 0}}


@dataclasses.dataclass
class SwapStats:
    swap_outs: int = 0
    swap_ins: int = 0
    swap_out_bytes: int = 0
    swap_in_bytes: int = 0
    last_swap_out_bytes: int = 0
    #: per-engine/lane plan+byte ledger (d2h / h2d / h2d-prefetch)
    by_engine: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=_engine_rows)
    prefetch_commits: int = 0      # resumes folded in from speculation
    prefetch_cancels: int = 0      # executed prefetches written off
    prefetch_wasted_bytes: int = 0
    # (seq_id, blocks_moved, bytes_moved) per swap-out, completion order
    out_log: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)


class HostBlockStore:
    """Byte ledger + residency view for preempted sequences' payloads.

    Standalone construction (no arena) creates a private Arena so the
    class keeps working as a self-contained store; serving passes the
    engine's shared arena + pool class so host-tier residency, payloads
    and ``ArenaStats`` placement counts all live in ONE address space.
    The ledger updates when plans COMPLETE (at the fence), so bytes
    reported are bytes actually moved -- except speculative prefetches,
    which park in ``_pending_prefetch`` until the engine commits or
    cancels them (see module docstring).
    """

    def __init__(self, arena: Optional[Arena] = None,
                 pool_class: str = "kv"):
        self.arena = arena if arena is not None else Arena()
        self.pool_class = pool_class
        self.stats = SwapStats()
        self._pending_prefetch: Dict[object, int] = {}   # owner -> nbytes
        self.arena.transfers.add_observer(self._on_complete,
                                          key=f"swap-ledger:{pool_class}")

    def _on_complete(self, plan: TransferPlan) -> None:
        if plan.pool_class != self.pool_class:
            return
        st = self.stats
        if plan.direction == D2H and plan.kind == "swap-out":
            st.swap_outs += 1
            st.swap_out_bytes += plan.nbytes
            st.last_swap_out_bytes = plan.nbytes
            st.by_engine["d2h"]["plans"] += 1
            st.by_engine["d2h"]["bytes"] += plan.nbytes
            st.out_log.append((plan.owner, int(plan.src.size), plan.nbytes))
        elif plan.direction == H2D and plan.kind == "swap-in":
            if plan.speculative:
                # the transfer plane re-notifies the SAME plan on
                # commit/abandon (Mapping.commit_prefetch /
                # cancel_prefetch -- whoever the caller was, serving
                # engine or a direct migrate("device")), so the
                # two-phase accounting needs no engine-side glue
                if plan.committed:
                    self._commit_prefetch(plan.owner)
                elif plan.abandoned:
                    self._cancel_prefetch(plan.owner)
                else:
                    # moved, but not yet a resume: park until
                    # commit/cancel
                    st.by_engine["h2d-prefetch"]["plans"] += 1
                    st.by_engine["h2d-prefetch"]["bytes"] += plan.nbytes
                    self._pending_prefetch[plan.owner] = plan.nbytes
            else:
                st.swap_ins += 1
                st.swap_in_bytes += plan.nbytes
                st.by_engine["h2d"]["plans"] += 1
                st.by_engine["h2d"]["bytes"] += plan.nbytes

    # ---------------- speculative two-phase accounting ----------------
    def _commit_prefetch(self, seq_id) -> None:
        """A resume was served from the speculative swap-in: fold the
        parked bytes into the demand ledger.  No-op when the prefetch
        had not completed at commit (the promoted plan then completes
        as a normal swap-in and is counted by the observer)."""
        nbytes = self._pending_prefetch.pop(seq_id, None)
        if nbytes is None:
            return
        st = self.stats
        st.swap_ins += 1
        st.swap_in_bytes += nbytes
        st.prefetch_commits += 1

    def _cancel_prefetch(self, seq_id) -> None:
        """The speculation was withdrawn after its scatter ran: write
        the parked bytes off as waste (they never became a resume)."""
        nbytes = self._pending_prefetch.pop(seq_id, None)
        if nbytes is None:
            return
        self.stats.prefetch_cancels += 1
        self.stats.prefetch_wasted_bytes += nbytes

    # ---------------- residency views ----------------
    def __contains__(self, seq_id: int) -> bool:
        return self.arena.host_contains(self.pool_class, seq_id)

    def __len__(self) -> int:
        return self.arena.host_len(self.pool_class)

    def in_transit(self, seq_id: int) -> bool:
        """Swap-out enqueued/dispatched but its host copy not fenced yet."""
        return seq_id in self.arena.transfers.in_transit(self.pool_class)

    # NOTE: cancelling a sequence while preempted goes through
    # ``PagedKVManager.release`` (``Mapping.free``), which settles any
    # in-transit plan, withdraws any parked prefetch, and tears down
    # host residency AND payload together -- a store-level drop would
    # desync the views the engine's check_consistency pins.
