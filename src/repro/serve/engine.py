"""Continuous-batching serving engine over the paged KV pool.

The paper's memory manager as an inference server:
  * admission control by FREE BLOCK COUNT (never by sequence count) --
    a request is admitted iff its prompt's blocks fit the pool;
  * per-step table growth: one fresh block per sequence each
    ``block_tokens`` decode steps (the split-stack 'check on push');
  * preemption by block swap-out to a host-side store and later
    swap-in to *different* physical blocks (relocation through the
    table, paper Table 1 rows 'Relocation' and 'Swapping');
  * COW prefix sharing for requests that fork a common prompt.

The engine runs decode for a fixed slot count B (padding empty slots),
which is how a TPU serving binary keeps one compiled shape.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockpool import OutOfBlocksError
from repro.core.paged_kv import PagedKVCache, PagedKVManager
from repro.core.stack import BlockStack


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,)
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    state: str = "queued"              # queued|running|preempted|done
    slot: int = -1

    @property
    def tokens_held(self) -> int:
        return len(self.prompt) + len(self.generated)


class Engine:
    """Slot-based continuous batching.

    model must expose prefill(params, batch, cache, lengths) and
    decode_step(params, tokens, cache); cache is a PagedKVCache (plain
    decoder LMs).  greedy sampling.
    """

    def __init__(self, model, params, *, slots: int, max_seq: int,
                 num_blocks: int, eos_id: int = 1):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos = eos_id
        kvcfg = model.kv_config(max_seq=max_seq, num_blocks=num_blocks,
                                batch=slots)
        self.cache = PagedKVCache.create(kvcfg, slots)
        self.mgr = PagedKVManager(kvcfg)
        self.queue: List[Request] = []
        self.running: Dict[int, Request] = {}   # slot -> req
        self.preempted = BlockStack(block_size=256)  # LIFO resume order
        self.done: List[Request] = []
        self._next_tok = np.zeros(slots, np.int64)
        self.steps = 0

    # ---------------- host-side bookkeeping ----------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for s in range(self.slots):
            if s not in self.running:
                return s
        return None

    def _sync_tables(self):
        tables = np.stack([
            self.mgr.device_table(self.running[s].rid) if s in self.running
            else np.full(self.cache.config.max_blocks_per_seq, -1, np.int32)
            for s in range(self.slots)])
        self.cache = dataclasses.replace(
            self.cache, block_tables=jnp.asarray(tables))

    def _admit_one(self) -> bool:
        cand = None
        if len(self.preempted):
            cand = self.preempted.pop()       # resume preempted first
        elif self.queue:
            cand = self.queue.pop(0)
        if cand is None:
            return False
        slot = self._free_slot()
        need = cand.tokens_held + cand.max_new - len(cand.generated)
        if slot is None or not self.mgr.can_admit(need):
            # put back where it came from
            if cand.state == "preempted":
                self.preempted.push(cand)
            else:
                self.queue.insert(0, cand)
            return False
        if cand.state == "preempted":
            new_ids, k_save, v_save = self.mgr.swap_in(cand.rid)
            idx = jnp.asarray(np.asarray(new_ids, np.int32))
            k_pool = self.cache.k_pool.at[:, idx].set(jnp.asarray(k_save))
            v_pool = self.cache.v_pool
            if v_save is not None:
                v_pool = self.cache.v_pool.at[:, idx].set(jnp.asarray(v_save))
            self.cache = dataclasses.replace(self.cache, k_pool=k_pool,
                                             v_pool=v_pool)
            self._resume_prefill(cand, slot, reuse=True)
        else:
            self.mgr.admit(cand.rid, need)
            self._resume_prefill(cand, slot, reuse=False)
        cand.state = "running"
        cand.slot = slot
        self.running[slot] = cand
        return True

    def _resume_prefill(self, req: Request, slot: int, *, reuse: bool):
        """Prefill req's full history into its blocks (single-sequence)."""
        toks = np.concatenate([req.prompt, np.asarray(req.generated,
                                                      np.int64)])
        bt = self.cache.config.block_tokens
        pad = (-len(toks)) % bt
        padded = np.pad(toks, (0, pad))
        tbl = self.mgr.device_table(req.rid)
        seq = jnp.asarray(padded)[None]
        # single-sequence prefill via a temp 1-slot cache view
        one = PagedKVCache(self.cache.k_pool, self.cache.v_pool,
                           jnp.asarray(tbl)[None],
                           jnp.zeros((1,), jnp.int32), self.cache.config)
        last, one = self.model.prefill(
            self.params, {"tokens": seq}, one,
            jnp.asarray([len(toks)], jnp.int32))
        self.cache = dataclasses.replace(
            self.cache, k_pool=one.k_pool, v_pool=one.v_pool)
        self._next_tok[slot] = int(jnp.argmax(last[0]))
        lens = np.array(self.cache.seq_lens)
        lens[slot] = len(toks)
        self.cache = dataclasses.replace(self.cache,
                                         seq_lens=jnp.asarray(lens))

    def preempt_lowest(self):
        """Swap out the most recently admitted request (LIFO)."""
        if not self.running:
            return
        slot = max(self.running, key=lambda s: self.running[s].rid)
        req = self.running.pop(slot)
        self.mgr.swap_out(req.rid, np.asarray(self.cache.k_pool),
                          None if self.cache.v_pool is None
                          else np.asarray(self.cache.v_pool))
        req.state = "preempted"
        self.preempted.push(req)
        lens = np.array(self.cache.seq_lens)
        lens[slot] = 0
        self.cache = dataclasses.replace(self.cache,
                                         seq_lens=jnp.asarray(lens))

    # ---------------- main loop ----------------
    def step(self):
        """Admit what fits, grow tables, run one decode step."""
        while self._admit_one():
            pass
        if not self.running:
            return
        # ensure capacity for the token each running seq is about to write
        for slot, req in list(self.running.items()):
            try:
                self.mgr.extend(req.rid, req.tokens_held + 1)
            except OutOfBlocksError:
                self.preempt_lowest()
        self._sync_tables()
        tokens = jnp.asarray(self._next_tok)
        logits, self.cache = self.model.decode_step(self.params, tokens,
                                                    self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        lens = np.array(self.cache.seq_lens)
        for slot, req in list(self.running.items()):
            req.generated.append(int(tokens[slot]))
            self._next_tok[slot] = nxt[slot]
            if len(req.generated) >= req.max_new or nxt[slot] == self.eos:
                req.state = "done"
                self.done.append(req)
                self.mgr.release(req.rid)
                del self.running[slot]
                lens[slot] = 0
        # idle slots must not advance
        for s in range(self.slots):
            if s not in self.running:
                lens[s] = 0
        self.cache = dataclasses.replace(self.cache,
                                         seq_lens=jnp.asarray(lens))
        self.steps += 1

    def run(self, max_steps: int = 10_000):
        while (self.queue or self.running or len(self.preempted)) and \
                self.steps < max_steps:
            self.step()
        return self.done
