"""Serving mechanism layer: executes scheduler decisions on the device.

The serving stack is four layers over one address space
(see ``serve/README.md`` and ``src/repro/mem/README.md``):

  * ``scheduler.py`` -- POLICY: pluggable admission order (FCFS with
    priority classes and earliest-deadline-first within a class pinned
    default; per-tenant deficit round-robin fairness) negotiated
    against the strategy's per-pool-class grantable leases, per-tenant
    block quotas, deadline-cost victim choice falling back to LIFO,
    per-step prefill budgeting, an adaptive free-block watermark fed by
    observed growth (growing classes only), dp-pool-group fork gating.
    No jax.
  * ``arch.py`` -- DISCIPLINE: the architecture registry.  What a
    model family's decode-time state IS (growing paged KV, a constant
    recurrent state block, or both) and which Arena pool classes back
    it.  The engine holds exactly ONE ``CacheStrategy`` and never
    inspects the model; ``resolve(model)`` is the only dispatch point.
  * ``swap.py`` -- LEDGER: the byte ledger and residency views over the
    transfer plane; swap cost scales with blocks held, never pool size.
  * ``repro.mem`` -- ADDRESS SPACE + TRANSFER PLANE: allocation,
    refcounts, the COW write barrier, pressure-time reclaim (this
    engine registers its LIFO preemption as the reclaimer for each of
    its strategy's pool classes), ``compact()``, and the
    ``TransferQueue`` every payload move rides (``mem/transfer.py`` is
    the only module that touches the block-copy kernels).
  * this module -- MECHANISM: one decode step for a fixed slot count B
    (padding empty slots, how a TPU serving binary keeps one compiled
    shape), ONE padded batched prefill for all of a step's admissions,
    COW prefix sharing (when the strategy supports it), and the
    SCHEDULE of the per-engine transfer queues: the step loop fences
    step N-1's d2h host copies, produces this step's plans (compaction,
    swap-in, growth preemptions, COW), dispatches every engine's URGENT
    lane, then speculatively prefetches the scheduler's LIFO resume
    candidate on the BACKGROUND h2d lane, then decodes -- so swap-out
    host copies AND the prefetch scatter overlap the decode (dispatch
    at N, fence at N+1).  A prefetched resume commits bookkeeping
    instead of swapping in synchronously; pressure cancels speculation
    before preempting anyone, which keeps every scheduling decision
    identical to the non-speculative schedule.
    ``overlap_transfers=False`` selects the synchronous ``drain()``
    fallback (prefetch off), which is token-identical and
    byte-identical by construction (pinned in tests and
    ``bench_serve --smoke``).

COW prefix sharing end-to-end (paged strategies): every admitted prompt
registers its block-aligned prefixes in a hash map; a later prompt that
matches forks instead of re-allocating, aliasing whole blocks --
including a partially-filled tail block when the new prompt is an exact
prefix of (or equal to) the parent's.  The first divergent write into a
shared block hits the ``ensure_writable`` barrier, which fulfils the
copy (``fork_for_write`` + one device block copy).  Relocation,
swapping and COW are exactly the paper's Table 1 rows, re-created in
software over a paged pool -- and the constant-state discipline shows
the same verbs serving a state no virtual-memory design anticipated.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.mem import BACKGROUND, URGENT, Arena, LeaseRevokedError
from repro.serve.arch import build_strategy
from repro.serve.scheduler import Request, Scheduler

__all__ = ["Engine", "Request"]


class Engine:
    """Slot-based continuous batching over one cache strategy.

    The model's family selects the discipline through the architecture
    registry (``serve/arch.py``): plain decoder LMs expose
    prefill/decode_step over a ``PagedKVCache``; SSMs over a recurrent
    state with ``state_to_rows``/``rows_to_state`` glue; hybrids over
    both.  Greedy sampling.

    All block bookkeeping lives in ONE ``repro.mem.Arena`` -- possibly
    SHARED between engines of different families (``pool_prefix``
    namespaces each engine's classes).  The engine registers itself as
    the reclaimer for its strategy's pool classes: when any allocation
    (table growth, COW copy target, state admission) exhausts a pool,
    the Arena calls back into LIFO preemption instead of failing --
    ``LeaseRevokedError`` surfaces only when the requester itself was
    the victim.
    """

    def __init__(self, model, params, *, slots: int, max_seq: int,
                 num_blocks: int, eos_id: int = 1,
                 watermark: Optional[int] = None,
                 prefill_budget="auto",
                 admission_policy=None,
                 share_prefixes: bool = True,
                 arena: Optional[Arena] = None, dp_groups: int = 1,
                 auto_compact: bool = True,
                 compact_free_frac: float = 0.5,
                 compact_frag_threshold: float = 0.5,
                 overlap_transfers: bool = True,
                 prefetch: bool = True,
                 suffix_prefill: bool = True,
                 resident_tables: bool = True,
                 pool_prefix: str = "",
                 state_blocks: Optional[int] = None):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos = eos_id
        self.dp_groups = dp_groups
        if dp_groups > 1:
            # group-batched caches read table entries as group-LOCAL ids
            # but the Arena still hands out GLOBAL ids -- running would
            # silently corrupt the pool.  Fail loudly until allocation
            # is group-partitioned (ROADMAP 'multi-pool dp_groups');
            # Scheduler.validate_fork already gates cross-group fork
            # admission for that future path.
            raise NotImplementedError(
                "dp_groups > 1 serving needs group-partitioned block "
                "allocation; refusing to run with group-oblivious ids")
        self.arena = arena if arena is not None else Arena()
        # the registry hands back the model family's cache discipline;
        # the strategy owns pool classes, device streams, managers and
        # transfer-plane executors.  pool_prefix namespaces the classes
        # so engines of DIFFERENT geometries can share one arena.
        self.strategy = build_strategy(
            model, arena=self.arena, slots=slots, max_seq=max_seq,
            num_blocks=num_blocks, dp_groups=dp_groups,
            pool_prefix=pool_prefix, state_blocks=state_blocks)
        self.sched = Scheduler(watermark=watermark,
                               prefill_budget=prefill_budget,
                               policy=admission_policy,
                               arena=self.arena)
        # admission/chunking bills suffix tokens only for forked children
        self.sched.prefill_cost_fn = self._prefill_cost
        # pressure ownership is per pool class: on a shared arena each
        # engine reclaims only for the classes it serves
        for cls in self.strategy.pool_classes:
            self.arena.set_reclaimer(self._reclaim_for_pressure,
                                     pool_class=cls)
        self.transfers = self.arena.transfers
        self.transfers.eager = not overlap_transfers
        self.auto_compact = auto_compact
        self.compact_free_frac = compact_free_frac
        self.compact_frag_threshold = compact_frag_threshold
        # speculative swap-in of the scheduler's LIFO resume candidate:
        # enqueued on the background h2d lane while decode runs, so the
        # real resume skips the synchronous swap-in.  Only meaningful
        # on the overlapped schedule -- the eager fallback would
        # serialize the speculation anyway.
        self.prefetch_enabled = prefetch and overlap_transfers
        # prefix sharing and suffix-only prefill require the strategy's
        # consent: a recurrent state depends on the ENTIRE prefix, so
        # constant/composite disciplines refuse both
        self.share_prefixes = (share_prefixes
                               and self.strategy.supports_prefix_sharing)
        self.suffix_prefill = (suffix_prefill
                               and self.strategy.supports_suffix_prefill)
        # resident decode path: device tables/rows are incrementally
        # maintained (delta scatter of dirty slots only) and the step
        # tail runs as ONE jitted, buffer-donated callable with the
        # next-token vector latched on device.  ``resident_tables=False``
        # is the pinned full-rebuild fallback, mirroring the
        # ``overlap_transfers``/``drain()`` pattern.
        self.resident_tables = resident_tables
        self.strategy.resident = resident_tables
        self._tok_dev = None           # device-latched next-token vector
        self._tok_dirty = True         # host wrote _next_tok -> re-upload
        self.host_uploads = 0          # step tails with any h2d upload
        self.table_sync_bytes = 0
        self.table_rows_updated = 0
        self.phase_time = {"dispatch": 0.0, "sync": 0.0, "decode": 0.0,
                           "retire": 0.0}
        self.running: Dict[int, Request] = {}   # slot -> req
        self.done: List[Request] = []
        self._prefix_map: Dict[Tuple[int, bytes], List[int]] = {}
        self._live_prompts: Dict[int, np.ndarray] = {}
        self._next_tok = np.zeros(slots, np.int64)
        self.steps = 0
        self.prefix_hits = 0
        self.cow_copies = 0
        self.preemptions = 0
        self.rejections = 0        # over-quota admissions refused
        self.prefill_tokens = 0
        self.prefill_tokens_saved = 0  # prefix tokens NOT recomputed
        self.decode_tokens = 0
        self.prefetches = 0        # speculative swap-ins launched
        self.prefetch_hits = 0     # resumes served from a COMPLETED prefetch
        self.prefetch_cancels = 0  # speculations withdrawn (pressure/free)

    # ---------------- strategy views (compat surface) ----------------
    @property
    def mgr(self):
        """The strategy's primary block manager (paged KV for
        transformers and hybrids, the constant-state manager for SSMs).
        """
        return self.strategy.mgr

    @property
    def cache(self):
        """The paged KV device cache, when the discipline has one."""
        return getattr(self.strategy, "cache", None)

    @cache.setter
    def cache(self, value) -> None:
        self.strategy.cache = value

    @property
    def store(self):
        """Primary pool class's host-tier swap ledger."""
        return self.strategy.store

    @property
    def sink(self) -> int:
        """Current physical id of the pinned write-sink block."""
        return self.strategy.sink

    def sync_transfers(self) -> None:
        """Fence everything: drain the transfer plane to completion
        (the synchronous fallback, also used by tests that inspect the
        byte ledger right after a forced preemption)."""
        self.transfers.drain()

    def release_arena(self) -> None:
        """Detach this engine from a SHARED arena so the arena stops
        retaining it (executor/observer closures hold the engine, and
        with it params and the device pools).  Drains outstanding
        plans, then unbinds reclaimers, executors and swap ledgers; the
        arena can be handed to a new engine afterwards.  Engines owning
        a private arena never need this -- both die together.
        """
        self.transfers.drain()
        for cls in self.strategy.pool_classes:
            if self.arena._reclaimers.get(cls) is self._reclaim_for_pressure:
                self.arena.set_reclaimer(None, pool_class=cls)
        self.strategy.release_arena()

    # ---------------- intake / compat views ----------------
    def submit(self, req: Request) -> None:
        if req.t_submit < 0:
            req.t_submit = time.perf_counter()
        self.sched.submit(req)

    @property
    def queue(self) -> List[Request]:
        return self.sched.queue

    @property
    def preempted(self):
        return self.sched.preempted

    # ---------------- prefix sharing (COW) ----------------
    def _register_prefix(self, req: Request) -> None:
        if not self.share_prefixes:
            return
        pr = np.ascontiguousarray(np.asarray(req.prompt, np.int64))
        bt = self.strategy.block_tokens
        for k in range(1, len(pr) // bt + 1):
            rids = self._prefix_map.setdefault((k, pr[: k * bt].tobytes()),
                                               [])
            if req.rid not in rids:
                rids.append(req.rid)
        self._live_prompts[req.rid] = pr

    def _deregister_prefix(self, req: Request) -> None:
        pr = self._live_prompts.pop(req.rid, None)
        if pr is None:
            return
        bt = self.strategy.block_tokens
        for k in range(1, len(pr) // bt + 1):      # only this rid's keys
            key = (k, pr[: k * bt].tobytes())
            rids = self._prefix_map.get(key)
            if rids is None:
                continue
            if req.rid in rids:
                rids.remove(req.rid)
            if not rids:
                del self._prefix_map[key]

    def _find_parent(self, req: Request) -> Tuple[Optional[int], int]:
        """Longest live shared prefix: (parent rid, shareable tokens).

        Shares whole blocks of the common prefix; additionally shares
        the parent's partially-filled tail block when the new prompt is
        entirely contained in the parent's (divergent writes into it are
        COW-resolved later).
        """
        if not self.share_prefixes:
            return None, 0
        pr = np.ascontiguousarray(np.asarray(req.prompt, np.int64))
        bt = self.strategy.block_tokens
        for k in range(len(pr) // bt, 0, -1):
            for rid in self._prefix_map.get((k, pr[: k * bt].tobytes()), []):
                if rid == req.rid or not self.strategy.has_seq(rid) \
                        or rid not in self._live_prompts:
                    continue
                parent = self._live_prompts[rid]
                n = min(len(pr), len(parent))
                neq = np.nonzero(pr[:n] != parent[:n])[0]
                common = int(neq[0]) if len(neq) else n
                shared = (common if common == len(pr)
                          else (common // bt) * bt)
                if shared > 0:
                    return rid, shared
        return None, 0

    def _prefill_cost(self, req: Request) -> int:
        """Prefill tokens this request will actually compute: the whole
        prompt, or only the un-cached suffix when a live parent shares
        its prefix (suffix-only prefill).  Used by the scheduler's
        admission budget; the plan-time parent lookup predates the same
        step's other placements, so it can only overestimate."""
        if not self.suffix_prefill:
            return req.tokens_held
        parent, shared = self._find_parent(req)
        if parent is None or shared <= 0:
            return req.tokens_held
        bt = self.strategy.block_tokens
        start = (shared if shared < req.tokens_held
                 else ((req.tokens_held - 1) // bt) * bt)
        return req.tokens_held - start

    # ---------------- admission ----------------
    def _free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self.running]

    def _admit(self) -> None:
        free = self._free_slots()
        # the strategy IS the admission view: per-pool-class footprints,
        # grantable leases (speculative blocks credited as free, so the
        # prefetch schedule stays decision-identical to drain()),
        # growing classes for the watermark, per-tenant quota headroom
        plan = self.sched.plan_admissions(len(free), self.strategy,
                                          num_running=len(self.running))
        for req in plan.reject:
            # over-quota: refused outright, not re-queued -- the tenant
            # must release blocks (or its quota must be raised) first
            req.state = "rejected"
            req.t_done = time.perf_counter()
            self.done.append(req)
            self.rejections += 1
        for req in plan.resume:
            slot = free.pop(0)
            if self.strategy.is_prefetched(req.rid):
                # the background h2d lane already reallocated (and maybe
                # scattered) this candidate: committing skips the
                # synchronous swap-in entirely.  A completed prefetch is
                # a HIT (resume latency fully hidden); a still-pending
                # one is promoted to the urgent lane and rides this
                # step's normal dispatch.  The byte ledger syncs through
                # the queue's commit re-notification, not engine glue.
                _, completed = self.strategy.commit_prefetch(req.rid)
                if completed:
                    self.prefetch_hits += 1
            else:
                # migrate("device") reallocates AND enqueues the h2d
                # scatter plan; the payload lands when the step loop
                # dispatches the queue (before any decode read)
                self.strategy.swap_in(req.rid)
            self._next_tok[slot] = req.pending_tok
            self._place(req, slot)
        batch: List[Tuple[int, Request, int]] = []
        suffix: List[Tuple[int, Request, int]] = []
        for req in plan.admit:
            slot = free.pop(0)
            parent, shared = self._find_parent(req)
            if parent is not None:
                # dp pool groups: a fork may only alias a parent in its
                # own group -- fail loudly, never corrupt tables
                self.sched.validate_fork(self._slot_of(parent), slot,
                                         self.slots, self.dp_groups)
                self.strategy.fork(parent, req.rid, shared, req.tenant)
                self.strategy.extend(req.rid, len(req.prompt))
                self.prefix_hits += 1
            else:
                self.strategy.admit(req.rid, len(req.prompt), req.tenant)
                shared = 0
            self._place(req, slot)
            # forked children with a cached prefix take the suffix-only
            # path (attend through the shared blocks, recompute nothing)
            if shared > 0 and self.suffix_prefill:
                suffix.append((slot, req, shared))
            else:
                batch.append((slot, req, shared))
        if batch:
            self._batched_prefill(batch)
        if suffix:
            self._suffix_prefill(suffix)

    def _slot_of(self, rid: int) -> int:
        for slot, req in self.running.items():
            if req.rid == rid:
                return slot
        raise KeyError(f"rid {rid} not running")

    def _place(self, req: Request, slot: int) -> None:
        req.state = "running"
        req.slot = slot
        self.running[slot] = req
        self._register_prefix(req)
        # every placement path (admit, resume, fork, swap-in commit,
        # disaggregation adopt, migration restore) lands here: the
        # slot's device rows and its host-written next token are stale
        self.strategy.mark_dirty(slot)
        self._tok_dirty = True

    def _batched_prefill(self, batch: List[Tuple[int, Request, int]]) -> None:
        """ONE padded prefill call for all of this step's admissions.

        The strategy owns padding, table/row construction and the KV or
        state writes; the engine owns the clock and the billing: the
        scheduler's admission budget EWMA sees the tokens the strategy
        actually computed, and TTFT ends at the prefill's argmax."""
        t0 = time.perf_counter()
        nxt, billed = self.strategy.prefill(self.params, batch)
        t1 = time.perf_counter()
        self.sched.observe_prefill(billed, t1 - t0)
        for row, (slot, req, _) in enumerate(batch):
            self._next_tok[slot] = nxt[row]
            if req.t_first < 0:
                # the first token IS the prefill's argmax: TTFT ends here
                req.t_first = t1
        self.prefill_tokens += billed

    def _suffix_prefill(self, batch: List[Tuple[int, Request, int]]) -> None:
        """ONE padded suffix-only prefill call for this step's forked
        admissions.  Bills ONLY the suffix: the admission budget's EWMA
        and the token counters see the work actually done, and the
        skipped prefix is the headline savings metric."""
        t0 = time.perf_counter()
        nxt, suffix_tokens, saved = self.strategy.prefill_suffix(
            self.params, batch)
        t1 = time.perf_counter()
        self.sched.observe_prefill(suffix_tokens, t1 - t0)
        for row, (slot, req, _) in enumerate(batch):
            self._next_tok[slot] = nxt[row]
            if req.t_first < 0:
                req.t_first = t1
        self.prefill_tokens += suffix_tokens
        self.prefill_tokens_saved += saved

    # ---------------- preemption / swap-out ----------------
    def _preempt_slot(self, slot: int) -> None:
        req = self.running.pop(slot)
        req.pending_tok = int(self._next_tok[slot])
        # migrate("host") frees the ids and enqueues the d2h plan; the
        # allocator HOLDS the vacated ids until the gather is
        # dispatched, so reuse cannot clobber the payload mid-flight,
        # and the host copy overlaps the next decode (fence at N+1).
        # Composite strategies move EVERY pool class here in one call.
        self.strategy.swap_out(req.rid)
        self._deregister_prefix(req)
        req.slot = -1
        self.sched.on_preempt(req)
        self.preemptions += 1

    def preempt_latest(self) -> None:
        """Swap out the most recently ADMITTED running request (LIFO).

        The victim is keyed on ``admit_order`` -- the scheduler's
        monotonic admission stamp -- not on ``rid`` (submission order):
        a request submitted first but resumed last is still the first
        evicted.  The swap-out gather dispatches immediately (we are
        between steps); its host copy lands at the next step's fence,
        overlapping whatever decodes in between.
        """
        if not self.running:
            return
        self._preempt_slot(self.sched.pick_victim(self.running))
        self.transfers.dispatch()

    def _reclaim_for_pressure(self, requester) -> Optional[int]:
        """Arena reclaimer: cancel speculation first, then evict the
        LIFO victim; returns the reclaimed owner id.

        Called by ``Arena._alloc_ids`` when a lease request cannot be
        granted; the Arena keeps asking until the request fits or the
        victim IS the requester (surfaced to the caller as
        ``LeaseRevokedError``).  Uncommitted prefetches are the
        CHEAPEST victims -- cancelling one frees its blocks without
        moving a byte (the host payload is still authoritative), and it
        restores exactly the free-block state the no-speculation
        schedule would have had, so pressure behavior stays
        decision-identical to the ``drain()`` fallback.
        """
        spec = self.strategy.prefetched_ids()
        if spec:
            # likelihood-ordered: the scheduler's resume window ranks
            # candidates by resume order, so cancel the LEAST likely
            # speculation first -- the top-of-window prefetch (the next
            # actual resume) is the last to be withdrawn
            order = {req.rid: i
                     for i, req in enumerate(self.sched.resume_candidates())}
            rid = max(spec, key=lambda r: order.get(r, len(order)))
            self.strategy.cancel_prefetch(rid)
            self.prefetch_cancels += 1
            return rid
        if not self.running:
            return None
        slot = self.sched.pick_victim(self.running)
        rid = self.running[slot].rid
        self._preempt_slot(slot)
        return rid

    # ---------------- device-state sync ----------------
    def _sync_device_state(self) -> None:
        """Derive the strategy's device tables/rows from host truth each
        step.  This is the READ BARRIER: the decode gathers every table
        or row entry, so every running mapping must be settled (no lease
        still the target of an unfenced transfer) -- the strategy's
        ``assert_settled`` raises ``UnfencedReadError`` if the dispatch
        phase was skipped."""
        self.strategy.sync_device_state(self.running)

    # ---------------- main loop ----------------
    def _grow_for_next_token(self) -> int:
        """Ensure every running seq can write this step's token; returns
        blocks allocated (the adaptive watermark's growth signal).

        Growth allocates under Arena pressure: exhaustion triggers the
        registered reclaimer (LIFO preemption) inside the Arena; only
        when the writer ITSELF was the victim does ``LeaseRevokedError``
        surface here, and then the write is moot -- its blocks are
        already on the host tier.  Constant-state disciplines return []
        unconditionally: their footprint never grows.
        """
        grown = 0
        for slot in sorted(self.running):
            if slot not in self.running:
                continue
            req = self.running[slot]
            try:
                new = self.strategy.extend(req.rid, req.tokens_held + 1)
            except LeaseRevokedError:
                continue
            if new:
                grown += len(new)
                self.strategy.mark_dirty(slot)
        return grown

    def _cow_barrier(self) -> int:
        """Private-block guarantee for every position written this step;
        returns the number of fulfilment copies enqueued.

        The copy-target block is a DEFERRED claim the admission check
        could not reserve (a forked child is charged its worst case but
        allocates nothing while sharing).  The barrier is Arena policy
        (``Mapping.ensure_writable`` allocates the target under
        pressure, falling back to LIFO preemption inside the Arena, and
        ENQUEUES the fulfilment copy on the transfer plane); the queue
        preserves enqueue order, so a preemption gather later in the
        same pass reads settled blocks once dispatched.  Disciplines
        that never share return None unconditionally.
        """
        copies = 0
        for slot in sorted(self.running):
            if slot not in self.running:
                continue
            req = self.running[slot]
            try:
                plan = self.strategy.ensure_writable(req.rid,
                                                     req.tokens_held)
            except LeaseRevokedError:
                continue            # the writer itself was reclaimed
            if plan is not None:
                self.cow_copies += 1
                copies += 1
                # fulfilment swapped a fresh private block under the
                # shared position -- the slot's table row changed
                self.strategy.mark_dirty(slot)
        return copies

    # ---------------- compaction (Arena defrag) ----------------
    def compact_now(self) -> int:
        """One Arena ``compact()`` cycle over every pool class the
        strategy serves: move live blocks to the dense prefix; the copy
        plans ride the transfer plane and are dispatched IMMEDIATELY
        (they would launch before the decode anyway, and their holds on
        the vacated sources must not leak into this step's admission
        arithmetic -- the eager fallback releases them inside the
        enqueue's drain, so the overlapped schedule must match or the
        two diverge on marginal admissions).

        Safe between steps (no writes in flight); every table built
        afterwards reads the rewritten leases, so decoding is
        token-identical across the relocation -- the paper's
        'Relocation / Migration' row.  Returns blocks moved.
        """
        moved = self.strategy.compact_now()
        self.transfers.dispatch(lanes=(URGENT,))
        return moved

    def _maybe_compact(self) -> None:
        """ROADMAP defrag pass: run when free blocks are plentiful but
        table locality has degraded (Arena policy).  Group-local id
        spaces (dp_groups > 1) are skipped -- a dense prefix would cross
        group ranges."""
        if not self.auto_compact or self.dp_groups > 1:
            return
        if self.strategy.should_compact(
                min_free_frac=self.compact_free_frac,
                frag_threshold=self.compact_frag_threshold):
            self.compact_now()

    def _maybe_prefetch(self) -> None:
        """Speculative swap-in of the scheduler's LIFO resume candidate
        on the BACKGROUND h2d lane, launched just before decode so the
        scatter overlaps it -- the candidate's next resume then commits
        bookkeeping instead of waiting on a synchronous swap-in.

        The strategy guards viability (never while the candidate's
        swap-out is still in transit, never under pressure -- headroom
        must cover the watermark, and the reclaimer cancels speculation
        FIRST anyway -- never twice for the same candidate, and never
        at all for composite disciplines, where a half-arrived sequence
        is unusable).
        """
        if not self.prefetch_enabled:
            return
        for req in self.sched.resume_candidates():
            if not self.strategy.prefetch_viable(req.rid,
                                                 self.sched.watermark):
                continue
            self.strategy.prefetch(req.rid)
            self.prefetches += 1

    def step(self) -> None:
        """One serving step, scheduled around the per-engine queues:

            fence(N-1) -> produce plans -> dispatch urgent -> prefetch
            -> dispatch background -> decode
            [d2h host copies of step N's swap-outs AND the speculative
             h2d scatter overlap this decode]

        FENCE: land step N-1's dispatched swap-out host copies (double
        buffering: dispatched at N-1, fenced here -- the d2h engine's
        completion phase).  PRODUCE: compaction policy, admissions/
        resumes (h2d plans; prefetched resumes commit instead), growth
        + COW barrier (d2d plans, growth preemptions enqueue d2h).
        DISPATCH URGENT: every engine runs its urgent lane -- d2d
        copies and h2d scatters execute, d2h gathers launch --
        everything decode will READ is settled, while the blocking host
        copies stay pending and overlap the decode below.  PREFETCH:
        the LIFO resume candidate's speculative swap-in enqueues and
        launches on the background h2d lane, overlapping the decode
        too.
        """
        t_step = time.perf_counter()
        self.transfers.complete_dispatched()
        # deadline arithmetic runs on the step counter (a deterministic
        # virtual clock), never the wall clock
        self.sched.now = float(self.steps)
        self._maybe_compact()
        self._admit()
        self.steps += 1
        if not self.running:
            self.transfers.drain()      # idle: nothing to overlap against
            return
        grown = self._grow_for_next_token()
        if not self.running:
            self.transfers.drain()
            return
        grown += self._cow_barrier()
        self.sched.observe_growth(grown)
        self.transfers.dispatch(lanes=(URGENT,))
        self._maybe_prefetch()
        self.transfers.dispatch(lanes=(BACKGROUND,))
        t_sync = time.perf_counter()
        self.phase_time["dispatch"] += t_sync - t_step
        uploads = 0
        if self.resident_tables:
            rows, nbytes = self.strategy.sync_device_state_delta(
                self.running)
            if rows:
                uploads += 1
        else:
            self._sync_device_state()
            rows, nbytes = self.strategy.full_sync_cost()
            uploads += 1
        self.table_rows_updated += rows
        self.table_sync_bytes += nbytes
        t0 = time.perf_counter()
        self.phase_time["sync"] += t0 - t_sync
        if self.resident_tables:
            if self._tok_dirty or self._tok_dev is None:
                tok_dev = jnp.asarray(self._next_tok)
                self._tok_dirty = False
                uploads += 1
            else:
                # steady state: this step's inputs ARE last step's
                # argmax, still latched on device -- zero uploads
                tok_dev = self._tok_dev
            nxt_dev = self.strategy.decode_resident(self.params, tok_dev)
            self._tok_dev = nxt_dev
            nxt = np.asarray(nxt_dev)   # the (B,) host crossing
            tokens = self._next_tok     # host truth of this step's inputs
        else:
            tokens = jnp.asarray(self._next_tok)
            uploads += 1
            logits = self.strategy.decode(self.params, tokens)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))  # completion
        t_retire = time.perf_counter()
        self.phase_time["decode"] += t_retire - t0
        self.host_uploads += uploads
        self.sched.observe_decode(t_retire - t0)
        # compute mark: any dispatched host copy that completes -- or
        # speculative scatter that commits -- after this point genuinely
        # overlapped a decode (honest per-engine `overlapped`)
        self.transfers.note_compute()
        self.decode_tokens += len(self.running)
        for slot, req in list(self.running.items()):
            req.generated.append(int(tokens[slot]))
            self._next_tok[slot] = nxt[slot]
            if len(req.generated) >= req.max_new or nxt[slot] == self.eos:
                req.state = "done"
                req.t_done = time.perf_counter()
                self.done.append(req)
                self.strategy.release(req.rid)
                self._deregister_prefix(req)
                del self.running[slot]
        self.phase_time["retire"] += time.perf_counter() - t_retire

    def serve(self, source=None, max_steps: int = 10_000) -> List[Request]:
        """Arrival-driven serving loop: the continuous-batching request
        plane.  Each step polls ``source`` (anything with
        ``poll(now) -> [Request]`` and ``has_more``, e.g.
        ``repro.serve.traffic.RequestSource``) on the engine's step
        clock, submits whatever has arrived, and runs one ``step()`` --
        admissions and retirements happen every step, so the batch
        never drains between requests.  With nothing resident and
        nothing arrived, the step is an idle tick that only advances
        the clock toward the next arrival.  ``source=None`` serves
        exactly the pre-loaded queue (the legacy ``run()`` contract).
        """
        while self.steps < max_steps:
            if source is not None:
                for req in source.poll(float(self.steps)):
                    self.submit(req)
            if not (self.sched.has_work or self.running):
                if source is None or not source.has_more:
                    break
            self.step()
        self.transfers.drain()          # settle trailing transfers
        return self.done

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drain the pre-loaded queue (compat shim over ``serve``)."""
        return self.serve(None, max_steps)

    # ---------------- restart (checkpoint-on-arena) ----------------
    def restore_preempted(self, req: Request) -> None:
        """Re-adopt a preempted request after ``Arena.restore``.

        The arena snapshot carries the sequence's host-tier payload and
        mapping (every pool class of a composite); the caller re-creates
        the ``Request`` (rid, prompt, generated, pending_tok are
        serving-layer state) and this hooks both back together: the
        strategy adopts the restored mappings and the scheduler queues
        the request for resume.
        """
        self.strategy.adopt_restored(req.rid)
        self.sched.on_preempt(req)

    # ---------------- introspection ----------------
    @property
    def stats(self) -> Dict[str, float]:
        st = self.store.stats
        return {
            "steps": self.steps,
            "prefill_tokens": self.prefill_tokens,
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "decode_tokens": self.decode_tokens,
            "prefix_hits": self.prefix_hits,
            "cow_copies": self.cow_copies,
            "preemptions": self.preemptions,
            "rejections": self.rejections,
            "swap_outs": st.swap_outs,
            "swap_ins": st.swap_ins,
            "swap_out_bytes": st.swap_out_bytes,
            "swap_in_bytes": st.swap_in_bytes,
            "swap_by_engine": st.by_engine,
            "prefetches": self.prefetches,
            "prefetch_hits": self.prefetch_hits,
            "prefetch_cancels": self.prefetch_cancels,
            # 0.0 (not a vacuous 1.0) when no speculation ever launched
            "prefetch_hit_rate": (self.prefetch_hits
                                  / max(self.store.stats.swap_ins, 1)
                                  if self.prefetches else 0.0),
            "pool_utilization": self.strategy.utilization,
            "resident_tables": self.resident_tables,
            "host_uploads": self.host_uploads,
            "host_uploads_per_step": (self.host_uploads
                                      / max(self.steps, 1)),
            "table_sync_bytes": self.table_sync_bytes,
            "table_rows_updated": self.table_rows_updated,
            "phase_time_s": dict(self.phase_time),
            "compactions": self.arena.compactions,
            "blocks_compacted": self.arena.blocks_compacted,
            "watermark_effective": self.sched.watermark,
            "transfers": self.transfers.stats.to_dict(),
        }

    def arena_stats(self):
        """The unified address space's ``ArenaStats`` snapshot."""
        return self.arena.stats()

    def latency_report(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant latency percentiles over completed requests.

        TTFT is submit -> first token available (the batched prefill's
        argmax); inter-token latency is the mean decode gap
        (t_done - t_first) / (tokens - 1).  Wall-clock telemetry only
        -- nothing here feeds back into policy.  Values are
        milliseconds; percentile keys are None when a tenant finished
        no request with enough tokens to measure (rendered as "n/a"
        downstream).
        """
        samples: Dict[str, Dict[str, List[float]]] = {}
        for r in self.done:
            if r.t_submit < 0 or r.t_first < 0:
                continue
            d = samples.setdefault(r.tenant, {"ttft": [], "itl": []})
            d["ttft"].append(r.t_first - r.t_submit)
            if r.t_done >= 0 and len(r.generated) > 1:
                d["itl"].append((r.t_done - r.t_first)
                                / (len(r.generated) - 1))

        def pct(vals: List[float], q: float) -> Optional[float]:
            if not vals:
                return None
            return round(float(np.percentile(vals, q)) * 1e3, 3)

        return {tenant: {"requests": len(d["ttft"]),
                         "ttft_p50_ms": pct(d["ttft"], 50),
                         "ttft_p99_ms": pct(d["ttft"], 99),
                         "itl_p50_ms": pct(d["itl"], 50),
                         "itl_p99_ms": pct(d["itl"], 99)}
                for tenant, d in sorted(samples.items())}

    def check_consistency(self) -> None:
        """Invariant audit (used by tests after every step): engine-
        level slot bookkeeping here, pool/ledger/lease invariants
        delegated to the strategy (which checks EVERY class it serves).
        """
        for slot, req in self.running.items():
            assert req.state == "running" and req.slot == slot
        self.strategy.check_consistency(self.running)
