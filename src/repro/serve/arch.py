"""Architecture registry: heterogeneous cache disciplines, one Arena.

The paper's thesis is that a software memory manager over fixed-size
blocks can serve every "large, growing array" a workload throws at it.
This module is where the serving stack cashes that claim for MODEL
ARCHITECTURES: each supported family maps to a ``CacheStrategy`` that
decides what its decode-time state IS (growing paged KV, a fixed-size
recurrent state, or both) and which Arena pool classes back it.  The
``Engine`` holds exactly one strategy and never inspects the model --
``resolve(model)`` is the only dispatch point.

Three disciplines:

* ``PagedKVStrategy`` -- transformers (dense/MoE/MLA/VLM): the
  per-token growing KV cache behind block tables, with COW prefix
  sharing, suffix-only prefill, swap and compaction.  This is the
  pre-registry engine behavior, extracted behind the interface.
* ``ConstantStateStrategy`` -- SSM / linear-attention models (mamba2):
  ONE fixed-size state block per sequence, allocated at admission and
  never grown.  Zero watermark pressure (its footprint is EXACT, so
  admission reserves no growth headroom for it), trivially swappable
  (one block moves the whole sequence), no prefix sharing (the
  recurrent state depends on the entire prefix).
* ``CompositeStrategy`` -- hybrids (zamba2): a growing paged-KV class
  for the shared-attention streams AND a constant-state class for the
  Mamba2 backbone, admitted/swapped/released together.  Whisper's
  registry row composes paged self-attention KV with a read-only
  cross-attention segment (``ReadOnlySegment``) deposited once at
  encode time and COW-shared by every decode beam; full engine serving
  of whisper is not wired yet and its builder says so loudly.

Per-pool-class accounting (``ArenaStats.per_class`` with per-tenant
quota/usage) is surfaced in ``repro.report``; the scheduler's
admission, preemption and the transfer plane's per-engine holds all
route through the strategy's view (``footprint`` / ``free_by_class`` /
``growing_classes`` / ``quota_headroom``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paged_kv import PagedKVCache, PagedKVManager
from repro.mem import Arena, Mapping, NULL_BLOCK, OutOfBlocksError
from repro.serve.swap import HostBlockStore

__all__ = [
    "SupportedArchitecture", "ARCHITECTURES", "resolve", "build_strategy",
    "CacheStrategy", "PagedKVStrategy", "ConstantStateStrategy",
    "CompositeStrategy", "ConstantStateManager", "ReadOnlySegment",
]


# ---------------------------------------------------------------------------
# constant-state pool manager
# ---------------------------------------------------------------------------
class ConstantStateManager:
    """Fixed-size per-sequence state blocks over one Arena pool class.

    The SSM/linear-attention analogue of ``PagedKVManager``: every
    sequence owns exactly ONE block of ``state_elems`` float32 elements
    (the flattened recurrent state), allocated at admission and never
    grown.  The device stream is a flat ``(num_blocks, state_elems)``
    pool registered with the transfer plane (``layered=False``), so
    swap-out/swap-in/prefetch/compaction all ride the same plans and
    kernels as paged KV -- one block per sequence just makes every move
    trivially sized.
    """

    def __init__(self, arena: Arena, pool_class: str, state_elems: int,
                 num_blocks: int):
        if state_elems <= 0:
            raise ValueError("state_elems must be positive")
        self.arena = arena
        self.state_elems = state_elems
        self.pool_class = arena.register_class(
            pool_class, num_blocks=num_blocks,
            block_shape=(state_elems,), dtype=np.float32)
        self.pool = jnp.zeros((num_blocks, state_elems), jnp.float32)
        self._maps: Dict[int, Mapping] = {}
        arena.transfers.register_executor(
            self.pool_class, self._streams, self._set_streams,
            layered=False)

    # -- transfer-plane executor (flat single stream) --
    def _streams(self):
        return [self.pool]

    def _set_streams(self, streams) -> None:
        self.pool = streams[0]

    # -- views --
    @property
    def allocator(self):
        return self.arena.allocator(self.pool_class)

    @property
    def free_blocks(self) -> int:
        return self.arena.num_free(self.pool_class)

    @property
    def swapped(self) -> dict:
        return self.arena.host_counts(self.pool_class)

    @property
    def utilization(self) -> float:
        return (self.arena.num_used(self.pool_class)
                / self.arena.num_blocks(self.pool_class))

    def mapping(self, seq_id: int) -> Mapping:
        return self._maps[seq_id]

    def has_seq(self, seq_id: int) -> bool:
        m = self._maps.get(seq_id)
        return m is not None and m.placement == "device"

    def row(self, seq_id: int) -> int:
        """Physical pool row of the sequence's (single) state block."""
        return self._maps[seq_id].block_ids()[0]

    def blocks_needed(self, tokens: int) -> int:
        """Constant: one block regardless of sequence length -- the
        exactness that zeroes the admission watermark for this class."""
        return 1

    # -- lifecycle --
    def admit(self, seq_id: int, tokens: int = 0,
              tenant: str = "default") -> List[int]:
        if self.free_blocks < 1:
            raise OutOfBlocksError(
                f"constant-state pool {self.pool_class!r} exhausted")
        m = self.arena.mapping(self.pool_class, seq_id, tenant=tenant)
        self._maps[seq_id] = m
        return m.ensure_capacity(1)

    def release(self, seq_id: int) -> None:
        self._maps.pop(seq_id).free()

    def adopt(self, seq_id: int, mapping: Mapping) -> None:
        if mapping.pool_class != self.pool_class:
            raise ValueError(
                f"adopt of mapping in pool class {mapping.pool_class!r}; "
                f"this manager allocates in {self.pool_class!r}")
        if seq_id in self._maps:
            raise ValueError(f"sequence {seq_id} already tracked")
        self._maps[seq_id] = mapping

    def disown(self, seq_id: int) -> Mapping:
        """Inverse of ``adopt``: stop tracking without freeing (the
        disaggregation handoff's export side)."""
        return self._maps.pop(seq_id)

    def reserve_sink(self):
        """Pin one row as the scatter target for empty decode slots."""
        return self.arena.pin(self.pool_class, owner="sink")

    # -- swapping / speculation (generic Mapping verbs) --
    def swap_out(self, seq_id: int) -> List[int]:
        return self._maps[seq_id].migrate("host")

    def swap_in(self, seq_id: int) -> List[int]:
        return self._maps[seq_id].migrate("device")

    def prefetch(self, seq_id: int) -> List[int]:
        return self._maps[seq_id].prefetch()

    def is_prefetched(self, seq_id: int) -> bool:
        m = self._maps.get(seq_id)
        return m is not None and m.prefetched

    def prefetched_ids(self) -> List[int]:
        return [sid for sid, m in self._maps.items() if m.prefetched]

    def commit_prefetch(self, seq_id: int) -> Tuple[List[int], bool]:
        return self._maps[seq_id].commit_prefetch()

    def cancel_prefetch(self, seq_id: int) -> None:
        self._maps[seq_id].cancel_prefetch()

    @property
    def speculative_blocks(self) -> int:
        return sum(m.spec_blocks for m in self._maps.values())


# ---------------------------------------------------------------------------
# read-only segment (whisper cross-attention KV)
# ---------------------------------------------------------------------------
class ReadOnlySegment:
    """Deposit-once block segment, COW-shared by every reader.

    Whisper's cross-attention KV is computed ONCE at encode time and
    then only ever read by decode beams: a growing discipline is wrong
    (it never grows) and a private copy per beam is waste.  The segment
    is a Mapping whose blocks are written exactly once at deposit;
    ``share`` hands a beam a full alias (pure refcount traffic, no
    bytes), and there is deliberately NO write barrier -- calling
    ``ensure_writable`` on a read-only segment is a bug, not a COW.
    Swap/migrate verbs stay available (the segment relocates like any
    other mapping).
    """

    def __init__(self, arena: Arena, pool_class: str):
        self.arena = arena
        self.pool_class = pool_class
        self._segments: Dict[object, Mapping] = {}
        self._readers: Dict[object, Mapping] = {}

    def deposit(self, owner, nblocks: int) -> List[int]:
        """Allocate the segment's blocks (encode writes them once)."""
        if owner in self._segments:
            raise ValueError(f"segment {owner!r} already deposited")
        m = self.arena.mapping(self.pool_class, owner)
        self._segments[owner] = m
        return m.ensure_capacity(nblocks)

    def share(self, owner, reader) -> List[int]:
        """Alias the FULL segment to ``reader`` -- refcounts only."""
        seg = self._segments[owner]
        child = seg.fork(reader, len(seg))
        self._readers[reader] = child
        return child.block_ids()

    def block_ids(self, owner) -> List[int]:
        m = self._segments.get(owner) or self._readers[owner]
        return m.block_ids()

    def ensure_writable(self, owner, idx: int):
        raise TypeError(
            f"segment {owner!r} is read-only: cross-attention KV is "
            f"deposited once at encode time; a write barrier here means "
            f"a decode path is trying to mutate shared encoder output")

    def drop_reader(self, reader) -> None:
        self._readers.pop(reader).free()

    def release(self, owner) -> None:
        """Free the segment itself (readers keep their aliases alive)."""
        self._segments.pop(owner).free()

    def migrate(self, owner, to: str) -> List[int]:
        return self._segments[owner].migrate(to)


# ---------------------------------------------------------------------------
# strategy interface
# ---------------------------------------------------------------------------
class CacheStrategy:
    """What a model family's decode-time state is, and how it is served.

    One instance per Engine; owns the Arena pool classes, device
    streams, managers and swap ledgers for its discipline, and is the
    scheduler's admission view (``footprint``/``free_by_class``/
    ``growing_classes``/``quota_headroom`` select the per-pool-class
    vector path in ``Scheduler.plan_admissions``).
    """

    #: full Arena pool-class names this strategy allocates in
    pool_classes: List[str]
    #: subset of pool_classes whose footprint can grow after admission
    #: (the watermark applies only to these)
    growing_classes: frozenset
    supports_prefix_sharing = False
    supports_suffix_prefill = False
    #: engine-set: device tables/rows are resident and delta-maintained
    resident = False

    # -- admission view (scheduler vector path) --
    def footprint(self, req) -> Dict[str, int]:
        """Worst-case per-pool-class block demand of admitting ``req``."""
        raise NotImplementedError

    def free_by_class(self) -> Dict[str, int]:
        """Grantable leases per class, crediting uncommitted prefetches
        as free (they cancel instantly under pressure, keeping the
        speculative schedule decision-identical)."""
        raise NotImplementedError

    def quota_headroom(self, tenant: str) -> Dict[str, int]:
        """Remaining per-class block budget for ``tenant`` -- only
        classes with a registered quota appear; absent = unlimited."""
        room = {}
        for cls in self.pool_classes:
            q = self.arena.tenant_quota(cls, tenant)
            if q is not None:
                used = self.arena.blocks_by_tenant(cls).get(tenant, 0)
                room[cls] = q - used
        return room

    # -- lifecycle --
    def admit(self, rid: int, prompt_tokens: int, tenant: str) -> None:
        raise NotImplementedError

    def fork(self, parent: int, child: int, shared_tokens: int,
             tenant: str) -> None:
        raise NotImplementedError(
            f"{type(self).__name__} does not share prefixes")

    def extend(self, rid: int, total_tokens: int) -> List[int]:
        """Grow to cover ``total_tokens``; [] for constant disciplines."""
        raise NotImplementedError

    def ensure_writable(self, rid: int, token_pos: int):
        """COW write barrier; None when nothing was shared."""
        raise NotImplementedError

    def release(self, rid: int) -> None:
        raise NotImplementedError

    def has_seq(self, rid: int) -> bool:
        raise NotImplementedError

    # -- swap / speculation --
    def swap_out(self, rid: int) -> None:
        raise NotImplementedError

    def swap_in(self, rid: int) -> None:
        raise NotImplementedError

    def is_prefetched(self, rid: int) -> bool:
        return False

    def commit_prefetch(self, rid: int) -> Tuple[List[int], bool]:
        raise NotImplementedError

    def cancel_prefetch(self, rid: int) -> None:
        raise NotImplementedError

    def prefetched_ids(self) -> List[int]:
        return []

    def prefetch_viable(self, rid: int, watermark: int) -> bool:
        """May ``rid``'s swap-in be speculated right now? (headroom,
        residency and in-transit guards -- see Engine._maybe_prefetch)"""
        return False

    def prefetch(self, rid: int) -> None:
        raise NotImplementedError

    # -- per-step mechanism --
    def sync_device_state(self, running: Dict[int, object]) -> None:
        """Derive device tables/row indices from host truth (the read
        barrier: every running mapping must be settled)."""
        raise NotImplementedError

    def decode(self, params, tokens):
        """One decode step over the synced device state; returns logits."""
        raise NotImplementedError

    # -- resident delta-sync protocol --
    def _init_resident(self) -> None:
        """Per-instance dirty-tracking state for the delta-sync path
        (strategies call this from __init__; no super().__init__)."""
        self._dirty: set = set()        # slots whose mapping changed
        self._resident: set = set()     # slots synced as live last step
        self._all_dirty = True          # first sync scatters every slot

    def mark_dirty(self, slot: int) -> None:
        """Mapping-mutation hook: ``slot``'s device rows must re-scatter
        at the next sync (growth, COW fulfilment, fork, resume, swap-in,
        adopt_device all route here via the engine)."""
        self._dirty.add(slot)

    def mark_all_dirty(self) -> None:
        """Every slot's device rows are stale -- physical ids moved under
        the tables (``compact()`` lease rewrite), including the sink the
        empty slots point at."""
        self._all_dirty = True

    def _take_dirty(self, running) -> set:
        """Slots to scatter this sync: dirty live slots plus departures
        (slots that WERE live and must be reset to the sink, or their
        stale tables would clobber reallocated blocks)."""
        run = set(running)
        if self._all_dirty:
            upd = set(range(self.slots))
            self._all_dirty = False
        else:
            upd = (self._dirty & run) | (self._resident - run)
        self._dirty.clear()
        self._resident = run
        return upd

    @staticmethod
    def _bucket(n: int, slots: int) -> int:
        """Power-of-two width for the update arrays, so repeats hit a
        warm jit trace (pad entries index ``slots`` -> scatter-dropped)."""
        return min(1 << (n - 1).bit_length(), slots) if n else 0

    def full_sync_cost(self) -> Tuple[int, int]:
        """(rows, bytes) one full-rebuild sync uploads -- the eager
        fallback's per-step cost, reported beside the delta path's."""
        raise NotImplementedError

    def sync_device_state_delta(self, running) -> Tuple[int, int]:
        """Delta read barrier: scatter only the dirty slots' rows and
        stash the update arrays for ``decode_resident``; returns
        (rows_updated, bytes_staged)."""
        raise NotImplementedError

    def decode_resident(self, params, tokens):
        """Fused step tail: delta-scatter + state step + argmax in one
        jitted, buffer-donated callable; returns the DEVICE (B,)
        next-token array (the only thing that crosses to host)."""
        raise NotImplementedError

    def prefill(self, params, batch) -> Tuple[np.ndarray, int]:
        """ONE padded prefill for ``[(slot, req, shared), ...]``;
        returns (next-token per row, prompt tokens billed)."""
        raise NotImplementedError

    def prefill_suffix(self, params, batch) -> Tuple[np.ndarray, int, int]:
        raise NotImplementedError(
            f"{type(self).__name__} does not suffix-prefill")

    # -- compaction --
    def should_compact(self, *, min_free_frac: float,
                       frag_threshold: float) -> bool:
        return any(self.arena.should_compact(c, min_free_frac=min_free_frac,
                                             frag_threshold=frag_threshold)
                   for c in self.pool_classes)

    def compact_now(self) -> int:
        moved = 0
        for c in self.pool_classes:
            src, _ = self.arena.compact(c)
            moved += len(src)
        if moved:
            # leases were rewritten under the tables: every resident
            # row (including the empty slots' sink pointer) is stale
            self.mark_all_dirty()
        return moved

    # -- restart / teardown / audit --
    def adopt_restored(self, rid: int) -> None:
        raise NotImplementedError

    def adopt_device(self, rid: int) -> None:
        """Adopt a DEVICE-resident mapping restored from a live-migration
        snapshot (``Arena.restore`` with device payloads) or scattered by
        ``adopt_payload`` -- the sequence resumes decoding with zero
        swap-in traffic.  ``adopt_restored`` stays the host-resident
        restart path."""
        raise NotImplementedError

    def release_arena(self) -> None:
        raise NotImplementedError

    def check_consistency(self, running: Dict[int, object]) -> None:
        raise NotImplementedError


class PagedKVStrategy(CacheStrategy):
    """Growing per-token KV behind block tables (transformers).

    The pre-registry engine mechanism, extracted: COW prefix sharing,
    suffix-only prefill, padded batched prefill through a pinned sink
    block, per-step table sync, swap and speculative prefetch.
    """

    supports_prefix_sharing = True

    def __init__(self, model, *, arena: Arena, slots: int, max_seq: int,
                 num_blocks: int, dp_groups: int = 1, pool_prefix: str = ""):
        self.model = model
        self.arena = arena
        self.slots = slots
        kvcfg = model.kv_config(max_seq=max_seq, num_blocks=num_blocks,
                                batch=slots, dp_groups=dp_groups)
        self.cache = PagedKVCache.create(kvcfg, slots)
        self.mgr = PagedKVManager(kvcfg, arena=arena,
                                  pool_class=pool_prefix + "kv")
        self._sink = self.mgr.reserve_sink()
        # resident tables must START all-sink / length-0: the created
        # cache fills tables with NULL (-1), and jax scatter WRAPS
        # negative indices -- an untouched empty slot would aim every
        # padded decode write at the pool's last block.  (Harmless for
        # the eager fallback, which rebuilds the full table per step.)
        self.cache = dataclasses.replace(
            self.cache,
            block_tables=jnp.full_like(self.cache.block_tables, self.sink),
            seq_lens=jnp.zeros_like(self.cache.seq_lens))
        self.store = HostBlockStore(arena, self.mgr.pool_class)
        self.pool_classes = [self.mgr.pool_class]
        self.growing_classes = frozenset(self.pool_classes)
        self.supports_suffix_prefill = getattr(
            model, "supports_suffix_prefill", False)
        self._init_resident()
        self._upd = None
        arena.transfers.register_executor(
            self.mgr.pool_class, self._streams, self._set_streams)

    # -- transfer-plane executor --
    def _streams(self):
        c = self.cache
        return [c.k_pool] + ([c.v_pool] if c.v_pool is not None else [])

    def _set_streams(self, streams) -> None:
        k, *rest = streams
        self.cache = dataclasses.replace(
            self.cache, k_pool=k, v_pool=rest[0] if rest else None)

    @property
    def sink(self) -> int:
        return self._sink.block

    @property
    def block_tokens(self) -> int:
        return self.cache.config.block_tokens

    @property
    def utilization(self) -> float:
        return self.mgr.utilization

    @property
    def swapped(self) -> dict:
        return self.mgr.swapped

    # -- admission view --
    def footprint(self, req) -> Dict[str, int]:
        return {self.mgr.pool_class: self.mgr.blocks_needed(req.max_tokens)}

    def free_by_class(self) -> Dict[str, int]:
        return {self.mgr.pool_class: (self.mgr.free_blocks
                                      + self.mgr.speculative_blocks)}

    # -- lifecycle --
    def admit(self, rid, prompt_tokens, tenant):
        self.mgr.admit(rid, prompt_tokens, tenant=tenant)

    def fork(self, parent, child, shared_tokens, tenant):
        self.mgr.fork(parent, child, shared_tokens, tenant=tenant)

    def extend(self, rid, total_tokens):
        return self.mgr.extend(rid, total_tokens)

    def ensure_writable(self, rid, token_pos):
        return self.mgr.ensure_writable(rid, token_pos)

    def release(self, rid):
        self.mgr.release(rid)

    def has_seq(self, rid):
        return self.mgr.has_seq(rid)

    # -- swap / speculation --
    def swap_out(self, rid):
        self.mgr.swap_out(rid)

    def swap_in(self, rid):
        self.mgr.swap_in(rid)

    def is_prefetched(self, rid):
        return self.mgr.is_prefetched(rid)

    def commit_prefetch(self, rid):
        return self.mgr.commit_prefetch(rid)

    def cancel_prefetch(self, rid):
        self.mgr.cancel_prefetch(rid)

    def prefetched_ids(self):
        return self.mgr.prefetched_ids()

    def prefetch_viable(self, rid, watermark):
        if self.mgr.is_prefetched(rid) or rid not in self.mgr.swapped:
            return False
        if self.store.in_transit(rid):
            return False               # wait for the d2h fence first
        need = self.mgr.swapped[rid]
        if need == 0:
            return False
        return self.mgr.free_blocks - need >= watermark

    def prefetch(self, rid):
        self.mgr.prefetch(rid)

    # -- per-step mechanism --
    def sync_device_state(self, running) -> None:
        """Empty slots map to the SINK block, not NULL: jax scatter
        WRAPS negative indices, so a NULL (-1) entry would clobber the
        pool's last block on every padded decode write."""
        cfg = self.cache.config
        bt = cfg.block_tokens
        tables = np.full((self.slots, cfg.max_blocks_per_seq), self.sink,
                         np.int32)
        lens = np.zeros(self.slots, np.int32)
        writes = []
        for slot, req in running.items():
            self.mgr.mapping(req.rid).assert_settled()
            tbl = self.mgr.device_table(req.rid)
            tables[slot] = tbl
            lens[slot] = req.tokens_held
            # the coming decode appends this slot's KV at token position
            # tokens_held -- dirty its tail block for live migration
            writes.append(int(tbl[req.tokens_held // bt]))
        self.mgr.allocator.note_write(writes)
        self.cache = dataclasses.replace(
            self.cache, block_tables=jnp.asarray(tables),
            seq_lens=jnp.asarray(lens))

    def decode(self, params, tokens):
        logits, self.cache = self.model.decode_step(params, tokens,
                                                    self.cache)
        return logits

    def full_sync_cost(self):
        mb = self.cache.config.max_blocks_per_seq
        return self.slots, self.slots * (mb + 1) * 4

    def sync_device_state_delta(self, running):
        """Delta read barrier: the device table is the cached translation
        structure; only slots whose mapping changed since the last step
        re-scatter.  Live-migration write tracking stays per-step (the
        coming decode appends at ``tokens_held`` regardless of table
        churn), read off the host mapping without building any table."""
        bt = self.cache.config.block_tokens
        writes = []
        for slot, req in running.items():
            self.mgr.mapping(req.rid).assert_settled()
            writes.append(int(self.mgr.block_ids(req.rid)
                              [req.tokens_held // bt]))
        self.mgr.allocator.note_write(writes)
        upd = self._take_dirty(running)
        mb = self.cache.config.max_blocks_per_seq
        W = self._bucket(len(upd), self.slots)
        upd_slots = np.full(W, self.slots, np.int32)   # pad -> dropped
        upd_tables = np.full((W, mb), self.sink, np.int32)
        upd_lens = np.zeros(W, np.int32)
        for i, slot in enumerate(sorted(upd)):
            upd_slots[i] = slot
            if slot in running:
                req = running[slot]
                upd_tables[i] = self.mgr.device_table(req.rid)
                upd_lens[i] = req.tokens_held
            # departed slots reset to all-sink / length 0: their stale
            # tables would aim padded writes at reallocated blocks
        self._upd = (upd_slots, upd_tables, upd_lens)
        nbytes = (upd_slots.nbytes + upd_tables.nbytes + upd_lens.nbytes
                  if W else 0)
        return len(upd), nbytes

    def decode_resident(self, params, tokens):
        upd_slots, upd_tables, upd_lens = self._upd
        nxt, self.cache = self.model.decode_fused(
            params, tokens, self.cache, jnp.asarray(upd_slots),
            jnp.asarray(upd_tables), jnp.asarray(upd_lens))
        return nxt

    def prefill(self, params, batch):
        """Rows padded to the longest block-aligned prompt; per-row
        prefill tables redirect padding AND COW-aliased prefix blocks to
        the sink, so writes land only in privately owned blocks."""
        cfg = self.cache.config
        bt = cfg.block_tokens
        lens = [req.tokens_held for _, req, _ in batch]
        S = -(-max(lens) // bt) * bt
        toks = np.zeros((len(batch), S), np.int64)
        tables = np.full((len(batch), cfg.max_blocks_per_seq), self.sink,
                         np.int32)
        for row, (slot, req, shared) in enumerate(batch):
            toks[row, : lens[row]] = np.concatenate(
                [np.asarray(req.prompt, np.int64),
                 np.asarray(req.generated, np.int64)])
            tbl = self.mgr.device_table(req.rid)
            keep = tbl != NULL_BLOCK
            keep[: -(-shared // bt) if shared else 0] = False
            tables[row, keep] = tbl[keep]
        view = PagedKVCache(self.cache.k_pool, self.cache.v_pool,
                            jnp.asarray(tables),
                            jnp.zeros((len(batch),), jnp.int32), cfg)
        last, view = self.model.prefill(
            params, {"tokens": jnp.asarray(toks)}, view,
            jnp.asarray(lens, jnp.int32))
        nxt = np.asarray(jnp.argmax(last, axis=-1))   # forces completion
        self.cache = dataclasses.replace(self.cache, k_pool=view.k_pool,
                                         v_pool=view.v_pool)
        return nxt, sum(lens)

    def prefill_suffix(self, params, batch):
        """Suffix-only prefill for forked children: each row runs the
        forward pass over just its un-cached suffix, attending through
        its FULL table (sharing saves FLOPs, not just bytes); KV writes
        route through a per-row write table (sink for aliased blocks and
        padding).  Padded width buckets to a power-of-two block count so
        repeats hit a warm jit trace."""
        cfg = self.cache.config
        bt = cfg.block_tokens
        lens = [req.tokens_held for _, req, _ in batch]
        starts = [shared if shared < lens[row]
                  else ((lens[row] - 1) // bt) * bt
                  for row, (_, _, shared) in enumerate(batch)]
        nblk = max(-(-(lens[r] - starts[r]) // bt) for r in range(len(batch)))
        nblk = min(1 << (nblk - 1).bit_length(), cfg.max_blocks_per_seq)
        S = nblk * bt
        toks = np.zeros((len(batch), S), np.int64)
        tables = np.full((len(batch), cfg.max_blocks_per_seq), self.sink,
                         np.int32)
        wtables = np.full((len(batch), nblk), self.sink, np.int32)
        for row, (slot, req, shared) in enumerate(batch):
            full = np.concatenate([np.asarray(req.prompt, np.int64),
                                   np.asarray(req.generated, np.int64)])
            toks[row, : lens[row] - starts[row]] = full[starts[row]:]
            tbl = self.mgr.device_table(req.rid)
            keep = tbl != NULL_BLOCK
            tables[row, keep] = tbl[keep]
            n_alias = -(-shared // bt)
            for j in range(nblk):
                a = starts[row] // bt + j
                if (a >= n_alias and a < len(tbl) and tbl[a] != NULL_BLOCK
                        and a * bt < lens[row]):
                    wtables[row, j] = tbl[a]
        view = PagedKVCache(self.cache.k_pool, self.cache.v_pool,
                            jnp.asarray(tables),
                            jnp.zeros((len(batch),), jnp.int32), cfg)
        suffix_tokens = sum(lens[r] - starts[r] for r in range(len(batch)))
        last, view = self.model.prefill_suffix(
            params, jnp.asarray(toks), view,
            jnp.asarray(lens, jnp.int32), jnp.asarray(starts, jnp.int32),
            jnp.asarray(wtables))
        nxt = np.asarray(jnp.argmax(last, axis=-1))   # forces completion
        self.cache = dataclasses.replace(self.cache, k_pool=view.k_pool,
                                         v_pool=view.v_pool)
        return nxt, suffix_tokens, sum(starts)

    # -- restart / teardown / audit --
    def adopt_restored(self, rid) -> None:
        m = self.arena.find_mapping(self.mgr.pool_class, rid)
        if m is None or m.placement != "host":
            raise ValueError(
                f"no restored host-resident mapping for rid {rid}; "
                f"run Arena.restore first (device-resident sequences do "
                f"not survive a restart -- re-submit them)")
        self.mgr.adopt(rid, m)

    def adopt_device(self, rid) -> None:
        m = self.arena.find_mapping(self.mgr.pool_class, rid)
        if m is None or m.placement != "device":
            raise ValueError(
                f"no device-resident mapping for rid {rid}; restore a "
                f"device snapshot (live migration) or adopt_payload a "
                f"handoff bundle first")
        self.mgr.adopt(rid, m)

    def release_arena(self) -> None:
        self.arena.transfers.unregister_executor(self.mgr.pool_class)
        self.arena.transfers.remove_observer(
            f"swap-ledger:{self.mgr.pool_class}")

    def check_consistency(self, running) -> None:
        alloc = self.mgr.allocator
        assert (alloc.num_used + alloc.num_free + alloc.num_held
                == alloc.num_blocks)
        assert alloc.refcount(self.sink) == 1
        bt = self.block_tokens
        lens = np.asarray(self.cache.seq_lens)
        for slot, req in running.items():
            tbl = self.mgr.block_ids(req.rid)
            assert len(tbl) * bt >= req.tokens_held
            assert all(alloc.is_allocated(b) for b in tbl)
            assert lens[slot] == req.tokens_held, (slot, lens[slot],
                                                   req.tokens_held)
        if self.resident and not self._all_dirty:
            # resident shadow vs host truth: a missed mark_dirty hook
            # surfaces HERE, not as a silent wrong-block read
            dev = np.asarray(self.cache.block_tables)
            for slot, req in running.items():
                if slot in self._dirty:
                    continue            # scatter staged for next sync
                want = self.mgr.device_table(req.rid)
                assert np.array_equal(dev[slot], want), (
                    f"slot {slot}: resident table diverged from mapping "
                    f"truth (missed dirty mark?)")
        transfers = self.arena.transfers
        transit = set(transfers.in_transit(self.mgr.pool_class))
        assert len(self.store) + len(transit) == len(self.mgr.swapped)
        for rid in self.mgr.swapped:
            assert rid in self.store or rid in transit
        pending_dst = transfers.in_flight_blocks(self.mgr.pool_class)
        for rid in self.mgr.tables:
            for lease in self.mgr.mapping(rid).leases:
                if lease.in_flight:
                    assert lease.block in pending_dst, (
                        f"rid {rid}: lease {lease!r} flagged in-flight "
                        f"but no pending plan targets it")
        for rid in self.mgr.prefetched_ids():
            m = self.mgr.mapping(rid)
            assert rid in self.store, (
                f"rid {rid}: prefetched but its host payload is gone")
            for lease in m._spec:
                if lease.in_flight:
                    assert lease.block in pending_dst, (
                        f"rid {rid}: speculative lease {lease!r} flagged "
                        f"in-flight but no pending plan targets it")
        self.arena.check_registry(self.mgr.pool_class)


class ConstantStateStrategy(CacheStrategy):
    """Fixed-size recurrent state, one block per sequence (SSM models).

    The pool IS the authoritative device state: every decode gathers
    each running slot's state row, steps the model, and scatters the
    new rows back -- so a swap-out gather at any step boundary reads
    the current state, and a resume is one block's scatter.  Footprint
    is EXACT (1 block, never grows): admission applies no watermark to
    this class, and preemption of one sequence always frees exactly
    what the next admission of its kind needs.
    """

    def __init__(self, model, *, arena: Arena, slots: int, max_seq: int,
                 num_blocks: int, dp_groups: int = 1, pool_prefix: str = ""):
        if dp_groups > 1:
            raise NotImplementedError(
                "constant-state serving is single-pool-group for now")
        self.model = model
        self.arena = arena
        self.slots = slots
        self.mgr = ConstantStateManager(arena, pool_prefix + "state",
                                        model.state_elems, num_blocks)
        self._sink = self.mgr.reserve_sink()
        self.store = HostBlockStore(arena, self.mgr.pool_class)
        self.pool_classes = [self.mgr.pool_class]
        self.growing_classes = frozenset()      # footprint is exact
        # padded prefill must keep the SSD chunk divisibility invariant
        self._pad = max(1, getattr(model.cfg.ssm, "chunk", 1))
        self._rows = np.full(slots, self.sink, np.int32)
        self._init_resident()
        self._upd = None
        self._rows_dev = None           # device-resident row indices
        self._fused = None              # cached fused decode jit

    @property
    def sink(self) -> int:
        return self._sink.block

    @property
    def block_tokens(self) -> int:
        """No paged table: prefix granularity is irrelevant (the
        recurrent state folds the whole prefix), but the engine's
        bookkeeping wants a positive quantum."""
        return 1

    @property
    def utilization(self) -> float:
        return self.mgr.utilization

    @property
    def swapped(self) -> dict:
        return self.mgr.swapped

    # -- admission view --
    def footprint(self, req) -> Dict[str, int]:
        return {self.mgr.pool_class: 1}

    def free_by_class(self) -> Dict[str, int]:
        return {self.mgr.pool_class: (self.mgr.free_blocks
                                      + self.mgr.speculative_blocks)}

    # -- lifecycle --
    def admit(self, rid, prompt_tokens, tenant):
        self.mgr.admit(rid, prompt_tokens, tenant=tenant)

    def extend(self, rid, total_tokens):
        return []                       # constant: zero growth, ever

    def ensure_writable(self, rid, token_pos):
        return None                     # nothing is ever COW-shared

    def release(self, rid):
        self.mgr.release(rid)

    def has_seq(self, rid):
        return self.mgr.has_seq(rid)

    # -- swap / speculation --
    def swap_out(self, rid):
        self.mgr.swap_out(rid)

    def swap_in(self, rid):
        self.mgr.swap_in(rid)

    def is_prefetched(self, rid):
        return self.mgr.is_prefetched(rid)

    def commit_prefetch(self, rid):
        return self.mgr.commit_prefetch(rid)

    def cancel_prefetch(self, rid):
        self.mgr.cancel_prefetch(rid)

    def prefetched_ids(self):
        return self.mgr.prefetched_ids()

    def prefetch_viable(self, rid, watermark):
        if self.mgr.is_prefetched(rid) or rid not in self.mgr.swapped:
            return False
        if self.store.in_transit(rid):
            return False
        return self.mgr.free_blocks - 1 >= watermark

    def prefetch(self, rid):
        self.mgr.prefetch(rid)

    # -- per-step mechanism --
    def sync_device_state(self, running) -> None:
        rows = np.full(self.slots, self.sink, np.int32)
        for slot, req in running.items():
            self.mgr.mapping(req.rid).assert_settled()
            rows[slot] = self.mgr.row(req.rid)
        # every decode scatters fresh state into every running row --
        # dirty them all for live migration
        self.mgr.allocator.note_write(
            [int(r) for r in rows if r != self.sink])
        self._rows = rows

    def decode(self, params, tokens):
        idx = jnp.asarray(self._rows, jnp.int32)
        state = self.model.rows_to_state(self.mgr.pool[idx])
        logits, new_state = self.model.decode_step(params, tokens, state)
        # scatter back every step: the pool stays authoritative, so a
        # later swap-out gather always reads the current state
        self.mgr.pool = self.mgr.pool.at[idx].set(
            self.model.state_to_rows(new_state))
        return logits

    def full_sync_cost(self):
        return self.slots, self.slots * 4

    def sync_device_state_delta(self, running):
        for slot, req in running.items():
            self.mgr.mapping(req.rid).assert_settled()
        # every decode scatters fresh state into every running row
        self.mgr.allocator.note_write(
            [int(self.mgr.row(req.rid)) for req in running.values()])
        upd = self._take_dirty(running)
        W = self._bucket(len(upd), self.slots)
        upd_slots = np.full(W, self.slots, np.int32)   # pad -> dropped
        upd_rows = np.full(W, self.sink, np.int32)
        for i, slot in enumerate(sorted(upd)):
            upd_slots[i] = slot
            if slot in running:
                upd_rows[i] = self.mgr.row(running[slot].rid)
            self._rows[slot] = upd_rows[i]             # host shadow
        self._upd = (upd_slots, upd_rows)
        return len(upd), (upd_slots.nbytes + upd_rows.nbytes if W else 0)

    def _fused_fn(self):
        """One jitted, pool-donated trace: row delta-scatter -> state
        gather -> decode step -> state scatter-back -> argmax.  The row
        index vector stays latched on device between steps."""
        if self._fused is None:
            model = self.model

            def impl(p, tokens, pool, rows, upd_slots, upd_rows):
                rows = rows.at[upd_slots].set(upd_rows, mode="drop")
                state = model.rows_to_state(pool[rows])
                logits, new_state = model.decode_step(p, tokens, state)
                pool = pool.at[rows].set(model.state_to_rows(new_state))
                return jnp.argmax(logits, axis=-1), pool, rows

            self._fused = jax.jit(impl, donate_argnums=(2,))
        return self._fused

    def decode_resident(self, params, tokens):
        if self._rows_dev is None:
            self._rows_dev = jnp.full((self.slots,), self.sink, jnp.int32)
        upd_slots, upd_rows = self._upd
        nxt, self.mgr.pool, self._rows_dev = self._fused_fn()(
            params, tokens, self.mgr.pool, self._rows_dev,
            jnp.asarray(upd_slots), jnp.asarray(upd_rows))
        return nxt

    def prefill(self, params, batch):
        """Padded batched prefill from zero state; ``lengths`` masks the
        right padding out of the SSM scan exactly, so this is
        token-identical to per-sequence prefill."""
        lens = [req.tokens_held for _, req, _ in batch]
        S = -(-max(lens) // self._pad) * self._pad
        toks = np.zeros((len(batch), S), np.int64)
        for row, (_, req, _) in enumerate(batch):
            toks[row, : lens[row]] = np.concatenate(
                [np.asarray(req.prompt, np.int64),
                 np.asarray(req.generated, np.int64)])
        state0 = self.model.init_state(len(batch))
        last, state = self.model.prefill(
            params, {"tokens": jnp.asarray(toks)}, state0,
            jnp.asarray(lens, jnp.int32))
        nxt = np.asarray(jnp.argmax(last, axis=-1))   # forces completion
        idx = jnp.asarray([self.mgr.row(req.rid) for _, req, _ in batch],
                          jnp.int32)
        self.mgr.pool = self.mgr.pool.at[idx].set(
            self.model.state_to_rows(state))
        return nxt, sum(lens)

    # -- restart / teardown / audit --
    def adopt_restored(self, rid) -> None:
        m = self.arena.find_mapping(self.mgr.pool_class, rid)
        if m is None or m.placement != "host":
            raise ValueError(
                f"no restored host-resident mapping for rid {rid}; "
                f"run Arena.restore first (device-resident sequences do "
                f"not survive a restart -- re-submit them)")
        self.mgr.adopt(rid, m)

    def adopt_device(self, rid) -> None:
        m = self.arena.find_mapping(self.mgr.pool_class, rid)
        if m is None or m.placement != "device":
            raise ValueError(
                f"no device-resident mapping for rid {rid}; restore a "
                f"device snapshot (live migration) or adopt_payload a "
                f"handoff bundle first")
        self.mgr.adopt(rid, m)

    def release_arena(self) -> None:
        self.arena.transfers.unregister_executor(self.mgr.pool_class)
        self.arena.transfers.remove_observer(
            f"swap-ledger:{self.mgr.pool_class}")

    def check_consistency(self, running) -> None:
        alloc = self.mgr.allocator
        assert (alloc.num_used + alloc.num_free + alloc.num_held
                == alloc.num_blocks)
        assert alloc.refcount(self.sink) == 1
        for slot, req in running.items():
            m = self.mgr.mapping(req.rid)
            assert len(m) == 1 and m.placement == "device"
            assert alloc.is_allocated(m.block_ids()[0])
        if (self.resident and not self._all_dirty
                and self._rows_dev is not None):
            dev = np.asarray(self._rows_dev)
            for slot, req in running.items():
                if slot in self._dirty:
                    continue
                assert dev[slot] == self.mgr.row(req.rid), (
                    f"slot {slot}: resident state row diverged from "
                    f"mapping truth (missed dirty mark?)")
        transfers = self.arena.transfers
        transit = set(transfers.in_transit(self.mgr.pool_class))
        assert len(self.store) + len(transit) == len(self.mgr.swapped)
        for rid in self.mgr.swapped:
            assert rid in self.store or rid in transit
        self.arena.check_registry(self.mgr.pool_class)


class CompositeStrategy(CacheStrategy):
    """Hybrid: a growing paged-KV class AND a constant-state class,
    admitted, swapped, preempted and released together (zamba2).

    The watermark applies only to the KV class; the state side's
    footprint is exact.  Prefix sharing is off: the recurrent state
    depends on the entire prefix, so aliasing KV blocks alone would
    serve the wrong state.  Speculative prefetch is off for the same
    compound reason (a half-arrived sequence is unusable) -- demand
    swap-in moves both classes' plans in one dispatch.
    """

    def __init__(self, model, *, arena: Arena, slots: int, max_seq: int,
                 num_blocks: int, dp_groups: int = 1, pool_prefix: str = "",
                 state_blocks: Optional[int] = None):
        if dp_groups > 1:
            raise NotImplementedError(
                "hybrid serving is single-pool-group for now")
        self.model = model
        self.arena = arena
        self.slots = slots
        kvcfg = model.kv_config(max_seq=max_seq, num_blocks=num_blocks,
                                batch=slots, dp_groups=dp_groups)
        self.cache = PagedKVCache.create(kvcfg, slots)
        self.mgr = PagedKVManager(kvcfg, arena=arena,
                                  pool_class=pool_prefix + "kv")
        self._kv_sink = self.mgr.reserve_sink()
        # device rows: resident slots + one in-flight resume + sink
        self.state_mgr = ConstantStateManager(
            arena, pool_prefix + "state", model.state_elems,
            state_blocks if state_blocks is not None else 2 * slots + 2)
        self._state_sink = self.state_mgr.reserve_sink()
        self.store = HostBlockStore(arena, self.mgr.pool_class)
        self.state_store = HostBlockStore(arena, self.state_mgr.pool_class)
        self.pool_classes = [self.mgr.pool_class, self.state_mgr.pool_class]
        self.growing_classes = frozenset([self.mgr.pool_class])
        bt = kvcfg.block_tokens
        chunk = max(1, getattr(model.cfg.ssm, "chunk", 1))
        self._pad = bt * chunk // math.gcd(bt, chunk)
        self._rows = np.full(slots, self.state_sink, np.int32)
        # resident tables start all-sink / length-0 (see PagedKVStrategy)
        self.cache = dataclasses.replace(
            self.cache,
            block_tables=jnp.full_like(self.cache.block_tables, self.sink),
            seq_lens=jnp.zeros_like(self.cache.seq_lens))
        self._init_resident()
        self._upd = None
        self._rows_dev = None
        self._fused = None
        arena.transfers.register_executor(
            self.mgr.pool_class, self._streams, self._set_streams)

    def _streams(self):
        c = self.cache
        return [c.k_pool] + ([c.v_pool] if c.v_pool is not None else [])

    def _set_streams(self, streams) -> None:
        k, *rest = streams
        self.cache = dataclasses.replace(
            self.cache, k_pool=k, v_pool=rest[0] if rest else None)

    @property
    def sink(self) -> int:
        return self._kv_sink.block

    @property
    def state_sink(self) -> int:
        return self._state_sink.block

    @property
    def block_tokens(self) -> int:
        return self.cache.config.block_tokens

    @property
    def utilization(self) -> float:
        return self.mgr.utilization

    @property
    def swapped(self) -> dict:
        return self.mgr.swapped       # state residency mirrors kv 1:1

    # -- admission view --
    def footprint(self, req) -> Dict[str, int]:
        return {self.mgr.pool_class: self.mgr.blocks_needed(req.max_tokens),
                self.state_mgr.pool_class: 1}

    def free_by_class(self) -> Dict[str, int]:
        return {self.mgr.pool_class: self.mgr.free_blocks,
                self.state_mgr.pool_class: self.state_mgr.free_blocks}

    # -- lifecycle (both classes, always together) --
    def admit(self, rid, prompt_tokens, tenant):
        self.mgr.admit(rid, prompt_tokens, tenant=tenant)
        try:
            self.state_mgr.admit(rid, tenant=tenant)
        except OutOfBlocksError:
            self.mgr.release(rid)     # atomic: no half-admitted hybrid
            raise

    def extend(self, rid, total_tokens):
        return self.mgr.extend(rid, total_tokens)

    def ensure_writable(self, rid, token_pos):
        return None                   # no prefix sharing -> never shared

    def release(self, rid):
        self.mgr.release(rid)
        self.state_mgr.release(rid)

    def has_seq(self, rid):
        return self.mgr.has_seq(rid)

    # -- swap (both classes ride the same dispatch) --
    def swap_out(self, rid):
        self.mgr.swap_out(rid)
        self.state_mgr.swap_out(rid)

    def swap_in(self, rid):
        self.mgr.swap_in(rid)
        self.state_mgr.swap_in(rid)

    # -- per-step mechanism --
    def sync_device_state(self, running) -> None:
        cfg = self.cache.config
        tables = np.full((self.slots, cfg.max_blocks_per_seq), self.sink,
                         np.int32)
        lens = np.zeros(self.slots, np.int32)
        rows = np.full(self.slots, self.state_sink, np.int32)
        bt = cfg.block_tokens
        kv_writes = []
        for slot, req in running.items():
            self.mgr.mapping(req.rid).assert_settled()
            self.state_mgr.mapping(req.rid).assert_settled()
            tbl = self.mgr.device_table(req.rid)
            tables[slot] = tbl
            lens[slot] = req.tokens_held
            rows[slot] = self.state_mgr.row(req.rid)
            kv_writes.append(int(tbl[req.tokens_held // bt]))
        # dirty the decode write targets for live migration: the KV
        # tail block AND the state row of every running sequence
        self.mgr.allocator.note_write(kv_writes)
        self.state_mgr.allocator.note_write(
            [int(r) for r in rows if r != self.state_sink])
        self.cache = dataclasses.replace(
            self.cache, block_tables=jnp.asarray(tables),
            seq_lens=jnp.asarray(lens))
        self._rows = rows

    def decode(self, params, tokens):
        from repro.models.zamba2 import ZambaState
        idx = jnp.asarray(self._rows, jnp.int32)
        conv, ssd = self.model.rows_to_state(self.state_mgr.pool[idx])
        state = ZambaState(conv, ssd, self.cache)
        logits, new_state = self.model.decode_step(params, tokens, state)
        self.state_mgr.pool = self.state_mgr.pool.at[idx].set(
            self.model.state_to_rows(new_state.conv, new_state.ssd))
        # carry the advanced seq_lens forward too (PagedKVStrategy keeps
        # the whole returned cache): between steps the device lens must
        # equal tokens_held, which check_consistency audits
        self.cache = dataclasses.replace(
            self.cache, k_pool=new_state.kv.k_pool,
            v_pool=new_state.kv.v_pool, seq_lens=new_state.kv.seq_lens)
        return logits

    def full_sync_cost(self):
        mb = self.cache.config.max_blocks_per_seq
        return self.slots, self.slots * (mb + 2) * 4

    def sync_device_state_delta(self, running):
        """Both disciplines' deltas ride ONE update-slot vector: a slot
        is dirty for its KV table and its state row together (admission,
        swap and release move both classes atomically)."""
        bt = self.cache.config.block_tokens
        kv_writes, st_writes = [], []
        for slot, req in running.items():
            self.mgr.mapping(req.rid).assert_settled()
            self.state_mgr.mapping(req.rid).assert_settled()
            kv_writes.append(int(self.mgr.block_ids(req.rid)
                                 [req.tokens_held // bt]))
            st_writes.append(int(self.state_mgr.row(req.rid)))
        self.mgr.allocator.note_write(kv_writes)
        self.state_mgr.allocator.note_write(st_writes)
        upd = self._take_dirty(running)
        mb = self.cache.config.max_blocks_per_seq
        W = self._bucket(len(upd), self.slots)
        upd_slots = np.full(W, self.slots, np.int32)   # pad -> dropped
        upd_tables = np.full((W, mb), self.sink, np.int32)
        upd_lens = np.zeros(W, np.int32)
        upd_rows = np.full(W, self.state_sink, np.int32)
        for i, slot in enumerate(sorted(upd)):
            upd_slots[i] = slot
            if slot in running:
                req = running[slot]
                upd_tables[i] = self.mgr.device_table(req.rid)
                upd_lens[i] = req.tokens_held
                upd_rows[i] = self.state_mgr.row(req.rid)
            self._rows[slot] = upd_rows[i]             # host shadow
        self._upd = (upd_slots, upd_tables, upd_lens, upd_rows)
        nbytes = (upd_slots.nbytes + upd_tables.nbytes + upd_lens.nbytes
                  + upd_rows.nbytes if W else 0)
        return len(upd), nbytes

    def _fused_fn(self):
        """One jitted trace for the hybrid tail: table/len/row
        delta-scatter -> state gather -> decode (KV append inside) ->
        state scatter-back -> argmax; the KV cache and state pool are
        both donated."""
        if self._fused is None:
            from repro.models.zamba2 import ZambaState
            model = self.model

            def impl(p, tokens, cache, pool, rows, upd_slots, upd_tables,
                     upd_lens, upd_rows):
                tables = cache.block_tables.at[upd_slots].set(
                    upd_tables, mode="drop")
                lens = cache.seq_lens.at[upd_slots].set(upd_lens,
                                                        mode="drop")
                rows = rows.at[upd_slots].set(upd_rows, mode="drop")
                cache = dataclasses.replace(cache, block_tables=tables,
                                            seq_lens=lens)
                conv, ssd = model.rows_to_state(pool[rows])
                logits, st = model.decode_step(
                    p, tokens, ZambaState(conv, ssd, cache))
                pool = pool.at[rows].set(
                    model.state_to_rows(st.conv, st.ssd))
                return jnp.argmax(logits, axis=-1), st.kv, pool, rows

            self._fused = jax.jit(impl, donate_argnums=(2, 3))
        return self._fused

    def decode_resident(self, params, tokens):
        if self._rows_dev is None:
            self._rows_dev = jnp.full((self.slots,), self.state_sink,
                                      jnp.int32)
        upd_slots, upd_tables, upd_lens, upd_rows = self._upd
        nxt, self.cache, self.state_mgr.pool, self._rows_dev = (
            self._fused_fn()(params, tokens, self.cache,
                             self.state_mgr.pool, self._rows_dev,
                             jnp.asarray(upd_slots),
                             jnp.asarray(upd_tables),
                             jnp.asarray(upd_lens),
                             jnp.asarray(upd_rows)))
        return nxt

    def prefill(self, params, batch):
        """One padded call writes BOTH disciplines: paged KV lands in
        each row's private blocks (padding scatters to the kv sink) and
        the recurrent state rows scatter into the state pool."""
        from repro.models.zamba2 import ZambaState
        cfg = self.cache.config
        lens = [req.tokens_held for _, req, _ in batch]
        S = -(-max(lens) // self._pad) * self._pad
        toks = np.zeros((len(batch), S), np.int64)
        tables = np.full((len(batch), cfg.max_blocks_per_seq), self.sink,
                         np.int32)
        for row, (_, req, _) in enumerate(batch):
            toks[row, : lens[row]] = np.concatenate(
                [np.asarray(req.prompt, np.int64),
                 np.asarray(req.generated, np.int64)])
            tbl = self.mgr.device_table(req.rid)
            keep = tbl != NULL_BLOCK
            tables[row, keep] = tbl[keep]
        view = PagedKVCache(self.cache.k_pool, self.cache.v_pool,
                            jnp.asarray(tables),
                            jnp.zeros((len(batch),), jnp.int32), cfg)
        conv, ssd = self.model.init_recurrent(len(batch))
        last, state = self.model.prefill(
            params, {"tokens": jnp.asarray(toks)},
            ZambaState(conv, ssd, view), jnp.asarray(lens, jnp.int32))
        nxt = np.asarray(jnp.argmax(last, axis=-1))   # forces completion
        self.cache = dataclasses.replace(self.cache,
                                         k_pool=state.kv.k_pool,
                                         v_pool=state.kv.v_pool)
        idx = jnp.asarray([self.state_mgr.row(req.rid)
                           for _, req, _ in batch], jnp.int32)
        self.state_mgr.pool = self.state_mgr.pool.at[idx].set(
            self.model.state_to_rows(state.conv, state.ssd))
        return nxt, sum(lens)

    # -- restart / teardown / audit --
    def adopt_restored(self, rid) -> None:
        for mgr in (self.mgr, self.state_mgr):
            m = self.arena.find_mapping(mgr.pool_class, rid)
            if m is None or m.placement != "host":
                raise ValueError(
                    f"no restored host-resident {mgr.pool_class!r} "
                    f"mapping for rid {rid}; run Arena.restore first")
        self.mgr.adopt(rid, self.arena.find_mapping(self.mgr.pool_class,
                                                    rid))
        self.state_mgr.adopt(
            rid, self.arena.find_mapping(self.state_mgr.pool_class, rid))

    def adopt_device(self, rid) -> None:
        for mgr in (self.mgr, self.state_mgr):
            m = self.arena.find_mapping(mgr.pool_class, rid)
            if m is None or m.placement != "device":
                raise ValueError(
                    f"no device-resident {mgr.pool_class!r} mapping for "
                    f"rid {rid}; restore a device snapshot first")
        self.mgr.adopt(rid, self.arena.find_mapping(self.mgr.pool_class,
                                                    rid))
        self.state_mgr.adopt(
            rid, self.arena.find_mapping(self.state_mgr.pool_class, rid))

    def release_arena(self) -> None:
        for cls in self.pool_classes:
            self.arena.transfers.unregister_executor(cls)
            self.arena.transfers.remove_observer(f"swap-ledger:{cls}")

    def check_consistency(self, running) -> None:
        for mgr, sink in ((self.mgr, self.sink),
                          (self.state_mgr, self.state_sink)):
            alloc = mgr.allocator
            assert (alloc.num_used + alloc.num_free + alloc.num_held
                    == alloc.num_blocks)
            assert alloc.refcount(sink) == 1
            self.arena.check_registry(mgr.pool_class)
        bt = self.block_tokens
        lens = np.asarray(self.cache.seq_lens)
        for slot, req in running.items():
            tbl = self.mgr.block_ids(req.rid)
            assert len(tbl) * bt >= req.tokens_held
            assert lens[slot] == req.tokens_held
            assert len(self.state_mgr.mapping(req.rid)) == 1
        if self.resident and not self._all_dirty:
            dev = np.asarray(self.cache.block_tables)
            rows_dev = (np.asarray(self._rows_dev)
                        if self._rows_dev is not None else None)
            for slot, req in running.items():
                if slot in self._dirty:
                    continue
                want = self.mgr.device_table(req.rid)
                assert np.array_equal(dev[slot], want), (
                    f"slot {slot}: resident KV table diverged")
                if rows_dev is not None:
                    assert rows_dev[slot] == self.state_mgr.row(req.rid), (
                        f"slot {slot}: resident state row diverged")
        transfers = self.arena.transfers
        for mgr, store in ((self.mgr, self.store),
                           (self.state_mgr, self.state_store)):
            transit = set(transfers.in_transit(mgr.pool_class))
            assert len(store) + len(transit) == len(mgr.swapped)


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SupportedArchitecture:
    """One registry row: model family -> cache discipline -> pools."""
    key: str                 # family or ssm kind this row matches
    strategy: type           # CacheStrategy subclass
    pool_suffixes: Tuple[str, ...]   # class names (pool_prefix prepended)
    description: str
    served: bool = True      # False: recognized but not engine-servable


ARCHITECTURES: Tuple[SupportedArchitecture, ...] = (
    SupportedArchitecture(
        "dense", PagedKVStrategy, ("kv",),
        "decoder transformers (dense/MoE/MLA/VLM): growing paged KV, "
        "COW prefix sharing, suffix prefill"),
    SupportedArchitecture(
        "mamba2", ConstantStateStrategy, ("state",),
        "pure SSM (mamba2): one constant state block per sequence, "
        "exact footprint, zero watermark pressure"),
    SupportedArchitecture(
        "hybrid", CompositeStrategy, ("kv", "state"),
        "zamba2 hybrid: paged KV for the shared-attention streams + "
        "constant state for the Mamba2 backbone"),
    SupportedArchitecture(
        "audio", CompositeStrategy, ("kv", "xattn"),
        "whisper: paged self-attention KV + read-only cross-attention "
        "segment (deposit once at encode, COW-share to decode beams)",
        served=False),
    SupportedArchitecture(
        "rwkv6", ConstantStateStrategy, ("state",),
        "RWKV6: one constant state block per sequence (shift vectors + "
        "wkv matrix state), length-masked padded prefill"),
)


def resolve(model) -> SupportedArchitecture:
    """Registry lookup from the model's config -- the ONLY dispatch
    point between model family and cache discipline (the engine itself
    has no isinstance-on-model cases left)."""
    cfg = model.cfg
    key = cfg.family
    if getattr(cfg, "ssm", None) is not None and key not in ("hybrid",):
        key = cfg.ssm.kind
    for row in ARCHITECTURES:
        if row.key == key:
            return row
    # plain decoder families (dense/moe/vlm/...) all serve paged KV
    if hasattr(model, "kv_config"):
        return ARCHITECTURES[0]
    raise NotImplementedError(
        f"no cache strategy registered for model family {cfg.family!r}")


def build_strategy(model, *, arena: Arena, slots: int, max_seq: int,
                   num_blocks: int, dp_groups: int = 1,
                   pool_prefix: str = "",
                   state_blocks: Optional[int] = None) -> CacheStrategy:
    """Resolve and construct the model's strategy over ``arena``."""
    row = resolve(model)
    if not row.served:
        raise NotImplementedError(
            f"architecture {row.key!r} is registered but not servable: "
            f"{row.description}")
    kw = dict(arena=arena, slots=slots, max_seq=max_seq,
              num_blocks=num_blocks, dp_groups=dp_groups,
              pool_prefix=pool_prefix)
    if row.strategy is CompositeStrategy:
        kw["state_blocks"] = state_blocks
    return row.strategy(model, **kw)
