"""Cross-process serving over the migratable Arena: prefill/decode
disaggregation and live engine migration.

Both halves of this module are the serving-layer face of one mem-layer
fact (``repro.mem.migrate``): because every payload move is a
transfer-plane plan and every table an id-indirected ``Mapping``, a
sequence's cache -- or a whole engine's address space -- can change
processes without any new device mechanism.

**Prefill/decode disaggregation.**  A ``PrefillWorker`` runs prompt
prefill on its own engine (own arena, own pools), then deposits the
finished sequence's blocks as ``BlockBundle``s (one per pool class); a
``DecodeWorker`` adopts the bundles onto fresh blocks of the decode
engine's arena and places the request directly into a decode slot --
never re-running prefill.  ``DisaggregatedEngine`` is the front-end:
it polls the arrival source on the decode engine's step clock,
preserves admission-style footprint gating and the latency stamps
(``t_submit`` at intake, ``t_first`` at the prefill argmax), and hands
each prompt prefill -> handoff -> decode.  Token identity with a
monolithic engine is pinned in tests: the padded prefill is
length-masked, so per-sequence prefill on another process computes the
same first token, and the handed-off KV bytes are exactly the blocks
decode would have read locally.

**Live migration.**  ``migrate_live`` drives the mem layer's
``MigrationSession`` against a serving engine: pre-copy rounds overlap
decode steps (background d2h gathers of live blocks take no holds),
the dirty set converges to the running working set, and the
stop-and-copy pause re-gathers only that tail before one
``Arena.snapshot``.  ``capture_request_plane``/``resume_engine`` move
the request-plane state (running slots, next-token latches, queued and
preempted requests, the admission stamp counter and the step clock) so
the destination engine resumes EVERY in-flight request -- running
sequences re-adopt their device-restored mappings
(``CacheStrategy.adopt_device``), preempted ones their host-tier
mappings, and decoding continues byte-identically to an unmigrated
control.
"""

from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple

from repro.mem.migrate import (BlockBundle, MigrationSession, adopt_payload,
                               export_mapping)
from repro.serve.engine import Engine
from repro.serve.scheduler import Request

__all__ = ["PrefillWorker", "DecodeWorker", "DisaggregatedEngine",
           "capture_request_plane", "resume_engine", "migrate_live"]


def _managers(strategy) -> List[object]:
    """A strategy's block managers, in ``pool_classes`` order (the
    paged-KV manager first, the constant-state manager when hybrid)."""
    out = [strategy.mgr]
    sm = getattr(strategy, "state_mgr", None)
    if sm is not None:
        out.append(sm)
    return out


class PrefillWorker:
    """The prefill side of the disaggregated pair: its own engine
    (own arena and pools) runs each prompt's padded prefill, then
    exports the finished sequence's blocks as transferable bundles.
    ``slots=1`` -- the worker never decodes, it only needs prefill
    tables."""

    def __init__(self, model, params, *, max_seq: int, num_blocks: int,
                 pool_prefix: str = "", **engine_kw):
        engine_kw.setdefault("share_prefixes", False)
        engine_kw.setdefault("prefetch", False)
        self.engine = Engine(model, params, slots=1, max_seq=max_seq,
                             num_blocks=num_blocks,
                             pool_prefix=pool_prefix, **engine_kw)
        self.prefills = 0

    def prefill_one(self, req: Request) -> Tuple[int, List[BlockBundle]]:
        """Prefill ``req``'s prompt and hand its cache over: returns the
        first generated token (the prefill argmax -- TTFT ends here) and
        one ``BlockBundle`` per pool class.  The worker's blocks are
        released back to its own pool by the export."""
        eng = self.engine
        eng.strategy.admit(req.rid, len(req.prompt), req.tenant)
        t0 = time.perf_counter()
        nxt, billed = eng.strategy.prefill(eng.params, [(0, req, 0)])
        t1 = time.perf_counter()
        eng.sched.observe_prefill(billed, t1 - t0)
        eng.prefill_tokens += billed
        if req.t_first < 0:
            req.t_first = t1       # first token IS the prefill's argmax
        bundles = [export_mapping(eng.arena, mgr.disown(req.rid))
                   for mgr in _managers(eng.strategy)]
        self.prefills += 1
        return int(nxt[0]), bundles


class DecodeWorker:
    """The decode side: adopts handed-off bundles onto the decode
    engine's arena and places the request directly into a slot (no
    admission prefill -- the first token already exists)."""

    def __init__(self, engine: Engine):
        self.engine = engine

    def adopt(self, req: Request, bundles: List[BlockBundle],
              first_tok: int, slot: Optional[int] = None) -> int:
        eng = self.engine
        if slot is None:
            slot = eng._free_slots()[0]
        # bundle order follows the source strategy's pool_classes;
        # remap positionally so prefill/decode pool prefixes may differ
        for bundle, cls in zip(bundles, eng.strategy.pool_classes):
            adopt_payload(eng.arena, req.rid, bundle, pool_class=cls)
        eng.strategy.adopt_device(req.rid)
        eng.sched._stamp(req)          # LIFO/admission stamp for victims
        eng._next_tok[slot] = first_tok
        eng._place(req, slot)
        return slot


class DisaggregatedEngine:
    """Front-end over a (prefill worker, decode engine) pair.

    ``serve(source)`` keeps the continuous-batching contract of
    ``Engine.serve``: arrivals are polled on the DECODE engine's step
    clock, ``t_submit`` is stamped at intake, and each step first hands
    off as many backlogged prompts as the decode side can admit
    (worst-case per-pool-class footprint must fit, exactly the
    monolithic admission gate), then runs one decode step.  Requests
    the decode engine later preempts resume through its normal
    swap-in path -- disaggregation only moves PREFILL off-engine.
    """

    def __init__(self, prefill: PrefillWorker, decode: Engine):
        self.prefill = prefill
        self.decode = DecodeWorker(decode)
        self.backlog: List[Request] = []
        self.handoffs = 0
        self.handoff_bytes = 0

    @property
    def engine(self) -> Engine:
        return self.decode.engine

    @property
    def done(self) -> List[Request]:
        return self.engine.done

    def submit(self, req: Request) -> None:
        if req.t_submit < 0:
            req.t_submit = time.perf_counter()
        self.backlog.append(req)

    def _admit_backlog(self) -> None:
        eng = self.engine
        free = eng._free_slots()
        while self.backlog and free:
            req = self.backlog[0]
            need = eng.strategy.footprint(req)
            avail = eng.strategy.free_by_class()
            if any(n > avail.get(c, 0) for c, n in need.items()):
                break              # worst case must fit, as everywhere
            self.backlog.pop(0)
            first, bundles = self.prefill.prefill_one(req)
            self.decode.adopt(req, bundles, first, slot=free.pop(0))
            self.handoffs += 1
            self.handoff_bytes += sum(b.nbytes for b in bundles)

    def serve(self, source=None, max_steps: int = 10_000) -> List[Request]:
        eng = self.engine
        while eng.steps < max_steps:
            if source is not None:
                for req in source.poll(float(eng.steps)):
                    self.submit(req)
            self._admit_backlog()
            if not (self.backlog or eng.running or eng.sched.has_work):
                if source is None or not source.has_more:
                    break
            eng.step()
        eng.transfers.drain()
        return eng.done

    def run(self, max_steps: int = 10_000) -> List[Request]:
        return self.serve(None, max_steps)


# ---------------------------------------------------------------------------
# live migration of a whole serving engine
# ---------------------------------------------------------------------------

def capture_request_plane(engine: Engine) -> dict:
    """Snapshot the serving-layer state the Arena checkpoint does not
    carry: running requests with their slots and next-token latches,
    the queued and preempted sets, the finished list, the step clock
    and the admission stamp counter.  DESTRUCTIVE on the preempted
    stack (the source engine is being migrated away); the returned
    ``preempted`` list is top-of-stack first."""
    preempted: List[Request] = []
    while len(engine.sched.preempted) > 0:
        preempted.append(engine.sched.preempted.pop())
    return {
        "steps": engine.steps,
        "running": {slot: (req, int(engine._next_tok[slot]))
                    for slot, req in engine.running.items()},
        "queued": list(engine.sched.queue),
        "preempted": preempted,
        "done": list(engine.done),
        "admit_counter": engine.sched._admit_counter,
    }


def resume_engine(engine: Engine, plane: dict) -> None:
    """Rebuild the request plane on a destination engine whose arena
    has been ``Arena.restore``d from a live-migration snapshot: every
    running request re-adopts its DEVICE-restored mappings and keeps
    its slot and next-token latch; preempted requests re-adopt their
    host-tier mappings and keep their LIFO order; the step clock and
    admission stamps continue, so deadline arithmetic and victim choice
    are unchanged across the move."""
    engine.steps = plane["steps"]
    engine.sched.now = float(plane["steps"])
    engine.sched._admit_counter = plane["admit_counter"]
    engine.done.extend(plane["done"])
    for req in plane["queued"]:
        engine.sched.submit(req)
    # plane stores top-first; pushing bottom-first restores LIFO order
    for req in reversed(plane["preempted"]):
        engine.restore_preempted(req)
    for slot, (req, nxt) in plane["running"].items():
        engine.strategy.adopt_device(req.rid)
        engine._next_tok[slot] = nxt
        engine._place(req, slot)


def migrate_live(src: Engine, build_dst: Callable[[], Engine], path: str,
                 *, max_rounds: int = 8
                 ) -> Tuple[Engine, MigrationSession]:
    """Incremental live migration of a serving engine.

    Pre-copy rounds run on the background d2h lane while ``src`` keeps
    decoding (one engine step per round -- the round's gathers are
    dispatched by that step's own queue schedule); once the dirty set
    converges, the engine pauses, ``finalize`` re-copies the dirty tail
    and writes the snapshot, the request plane is captured, and the
    destination engine (``build_dst()`` -- same model geometry, fresh
    arena) restores and resumes every in-flight request.  Returns
    ``(dst_engine, session)``; ``session.migration_report()`` carries
    the acceptance surface (rounds, bytes/round, pause steps).
    """
    sess = MigrationSession(src.arena, max_rounds=max_rounds)
    while not sess.converged():
        sess.begin_round()
        if src.running or src.sched.has_work:
            src.step()       # decode overlaps this round's gathers
        sess.collect_round()
    plane = capture_request_plane(src)
    sess.finalize(path)
    dst = build_dst()
    dst.arena.restore(path)
    resume_engine(dst, plane)
    return dst, sess
