"""§Perf hillclimb driver: lower a cell VARIANT, compare roofline terms.

Each variant is (name, cfg_transform, rules, lower kwargs); results go to
perf_report.jsonl with the hypothesis text, so EXPERIMENTS.md §Perf is
generated from measured artifacts, not prose.

Run as:  PYTHONPATH=src python -m repro.perf --cell rwkv_train --variant all
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time
import traceback
from typing import Callable, Dict, Optional

from repro import roofline as RL
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh


@dataclasses.dataclass
class Variant:
    cell: str                    # "arch/shape"
    name: str
    hypothesis: str
    cfg_transform: Optional[Callable] = None
    rules: Optional[Dict] = None
    lower_kwargs: Optional[Dict] = None


def _chunk(cfg, n, sub=0):
    return dataclasses.replace(cfg, ssm=dataclasses.replace(
        cfg.ssm, chunk=n, subchunk=sub))


def _intra_bf16(cfg):
    return dataclasses.replace(cfg, ssm=dataclasses.replace(
        cfg.ssm, intra_dtype="bfloat16"))


def _moe_ep(cfg):
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, parallel_mode="ep"))


def _kv_bt(n):
    def t(cfg):
        return dataclasses.replace(cfg, kv_block_tokens=n)
    return t


def _latent_tp(cfg):
    return dataclasses.replace(cfg, mla_latent_tp=True)


def _latent_tp_bt(n):
    def t(cfg):
        return dataclasses.replace(cfg, mla_latent_tp=True,
                                   kv_block_tokens=n)
    return t


VARIANTS = [
    # ---- Cell A: rwkv6_7b x train_4k (worst roofline fraction) ----
    Variant("rwkv6_7b/train_4k", "baseline",
            "paper-faithful baseline: chunked RWKV6, C=64 direct intra"),
    Variant("rwkv6_7b/train_4k", "chunk16",
            "H1: the direct (C,C,dk) decay tensor dominates HBM traffic "
            "(~C*dk*4B per token per head); C 64->16 should cut the memory "
            "term ~3-4x at the cost of 4x more (cheap) state carries",
            cfg_transform=lambda c: _chunk(c, 16)),
    Variant("rwkv6_7b/train_4k", "chunk8",
            "H2: continue C->8; predicted further ~2x on the intra term, "
            "diminishing as ddlerp/projection traffic starts to dominate",
            cfg_transform=lambda c: _chunk(c, 8)),
    Variant("rwkv6_7b/train_4k", "sub16",
            "H3 (after H1/H2 REFUTED -- traffic scales 1/C, i.e. per-"
            "while-iteration constants dominate, not the decay tensor): "
            "keep C=64 outer trips but tile the body into UNROLLED "
            "subchunks of 16 -- decay tensor shrinks 4x AND iteration "
            "count stays put",
            cfg_transform=lambda c: _chunk(c, 64, 16)),
    Variant("rwkv6_7b/train_4k", "sub16_c256",
            "H4: if per-iteration constants dominate, C=256 with sub=16 "
            "cuts while trips 4x at unchanged tile cost",
            cfg_transform=lambda c: _chunk(c, 256, 16)),
    Variant("rwkv6_7b/train_4k", "intra_bf16",
            "H5 (H3/H4 also refuted -- smaller tiles multiply fusion-"
            "boundary materializations; the monolithic C=64 body is the "
            "pure-JAX optimum; the true fix is a fused chunk kernel): "
            "bf16 for the (C,C,dk) decay tensor and score operands, f32 "
            "accumulation -- predicted ~1.8x on the dominant term",
            cfg_transform=_intra_bf16),
    # ---- Cell B: qwen3_moe x decode_32k (most collective-bound) ----
    Variant("qwen3_moe_30b_a3b/decode_32k", "baseline",
            "baseline: TP-in-expert MoE (d_ff sharded), kv pool replicated "
            "over model (kvh=4 < 16)"),
    Variant("qwen3_moe_30b_a3b/decode_32k", "attn_pinned",
            "H2: HLO shows a 51.5GB f32 all-gather of the WHOLE pool "
            "carry + 12.9GB/layer K gathers: GSPMD picked a replicated "
            "layout for the ambiguous kvh=4<16 attention. Pin decode "
            "attention to batch-only sharding (replicated compute is "
            "~1ms); predicted: both gathers vanish, collective -> ~0",
            ),
    Variant("qwen3_moe_30b_a3b/decode_32k", "attn_pinned_xsys",
            "H3: combine the pinned attention layout with xs->ys pool "
            "threading (the pool-as-carry form copies the whole carry "
            "per layer: measured 10.0s memory). Predicted: collective ~0 "
            "(from H2) AND memory back under the 1.45s baseline since "
            "the 0.67TB of f32 layout-gathers are gone too"),
    Variant("qwen3_moe_30b_a3b/decode_32k", "qpin_bf16_final",
            "H4 (landed default): pin only q/o (pinning k/v fights the "
            "pool layout, H3 refuted at 10.4s mem); bf16 attention "
            "operands with f32 accumulation. S-split flash-decoding over "
            "'model' also tried and refuted (GSPMD involuntary full "
            "remat of the gather)."),
    Variant("qwen3_moe_30b_a3b/decode_32k", "moe_ep",
            "H1: decode is collective-bound; TP MoE psums the full (B,d) "
            "activation per layer over model=16. EP with all_to_all moves "
            "only top_k routed token copies: predicted collective bytes "
            "drop ~(2*top_k/TP) vs psum -> ~x4 less",
            cfg_transform=_moe_ep),
    # ---- Cell C: deepseek x decode_32k (paper-technique representative) --
    Variant("deepseek_v2_lite_16b/decode_32k", "baseline",
            "paper-faithful baseline: absorbed-MLA paged latent pool, "
            "replicated over the model axis (latent has no head dim)"),
    Variant("deepseek_v2_lite_16b/decode_32k", "latent_tp",
            "H1 (beyond-paper): shard the latent pool over 'model' on the "
            "kv_lora dim (rope stream separate); score/value contractions "
            "become partial + tiny psums. Pool bytes/chip /16: memory term "
            "predicted ~2.0s -> ~0.2s",
            cfg_transform=_latent_tp),
    Variant("deepseek_v2_lite_16b/decode_32k", "latent_tp_bt128",
            "H2: with the pool sharded, per-block bookkeeping and partial-"
            "block waste shrink with bigger blocks; bt 64->128",
            cfg_transform=_latent_tp_bt(128)),
]


def run_variant(v: Variant, out_path: str):
    mesh = make_production_mesh()
    arch, shape = v.cell.split("/")
    t0 = time.time()
    row = {"cell": v.cell, "variant": v.name, "hypothesis": v.hypothesis}
    try:
        kw = dict(v.lower_kwargs or {})
        lowered, mf, chips = lower_cell(
            arch, shape, mesh, rules=v.rules,
            cfg_transform=v.cfg_transform, **kw)
        compiled = lowered.compile()
        rl = RL.analyze(compiled, arch=arch, shape=shape, mesh_desc="16x16",
                        chips=chips, model_flops=mf)
        row.update(rl.row())
        row["status"] = "ok"
        row["t_total_s"] = round(time.time() - t0, 1)
    except Exception as e:
        row["status"] = "FAIL"
        row["error"] = f"{type(e).__name__}: {e}"
        row["trace"] = traceback.format_exc()[-1500:]
    with open(out_path, "a") as f:
        f.write(json.dumps(row) + "\n")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all")
    ap.add_argument("--variant", default="all")
    ap.add_argument("--out", default="perf_report.jsonl")
    args = ap.parse_args()
    done = set()
    if os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("status") == "ok":
                done.add((r["cell"], r["variant"]))
    for v in VARIANTS:
        if args.cell != "all" and not v.cell.startswith(args.cell):
            continue
        if args.variant != "all" and v.name != args.variant:
            continue
        if (v.cell, v.name) in done:
            continue
        print(f"[perf] {v.cell} :: {v.name}", flush=True)
        row = run_variant(v, args.out)
        if row["status"] == "ok":
            by = row.get("mem_by_op_gb", {})
            top = ", ".join(f"{k}={v:.2f}GB"
                            for k, v in list(by.items())[:3])
            print(f"  t=({row['t_compute_s']:.3f}, {row['t_memory_s']:.3f}, "
                  f"{row['t_collective_s']:.3f})s bn={row['bottleneck']} "
                  f"frac={row['roofline_fraction']:.4f}"
                  + (f" mem[{top}]" if top else ""), flush=True)
        else:
            print(f"  FAIL {row['error']}", flush=True)


if __name__ == "__main__":
    main()
