"""Train step with int8 error-feedback gradient sync (distributed-
optimization feature for slow inter-pod links).

Structure: per-shard gradients are computed with ``jax.vmap`` over an
explicit leading shard dimension that is GSPMD-sharded over the data
axes -- each data shard computes the gradient of ITS microbatch, while
Megatron TP inside the loss still partitions over 'model' as usual.
The DP mean then goes through ``optim.compression.sync_mean`` (quantize
→ all_gather int8+scales → dequantize+average, residual kept per
device) inside a fully-manual ``shard_map`` -- 4x fewer DP sync bytes
on the wire than the f32 psum XLA would insert, with error feedback
making the quantization bias vanish across steps.

(A previous revision computed the per-shard gradients inside a shard_map
MANUAL over data / AUTO over 'model'; the partial-manual + collective
combination fatals in XLA on jax 0.4.x -- ``Check failed:
sharding.IsManualSubgroup()`` -- so the per-shard stage is expressed in
pure GSPMD and only the collective stage is manual, which is portable.)

At 2+ pod scale this is the collective that crosses the slow inter-pod
links every step, which is why it is worth compressing even though the
in-pod TP collectives stay full precision.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.launch import shardings as SH
from repro.launch.mesh import batch_axes
from repro.launch.steps import Step, opt_shardings, rules_for, _ns
from repro.optim import adamw as OPT
from repro.optim import compression as C


def residual_specs(params) -> jax.ShapeDtypeStruct:
    """Flat residual vector shape for a param tree (per data shard)."""
    n = 0
    for leaf in jax.tree.leaves(params):
        size = 1
        for d in leaf.shape:
            size *= d
        n += size + ((-size) % C.BLOCK)
    return jax.ShapeDtypeStruct((n,), jnp.float32)


def build_compressed_train_step(model, mesh: Mesh,
                                opt_cfg: OPT.AdamWConfig, *,
                                rules: Optional[Dict[str, Any]] = None,
                                remat: bool = True) -> Step:
    """Like build_train_step but with int8 DP gradient sync.

    Signature: step(params, opt_state, residual, batch) ->
               (params, opt_state, residual, metrics)
    residual: (n_dp_shards, L) f32 sharded over the data axes (each
    shard's error-feedback buffer).
    """
    rules = rules_for(model.cfg, mesh, rules)
    bax = batch_axes(mesh)
    ndp = 1
    for a in bax:
        ndp *= mesh.shape[a]
    pshapes, axes = model.param_specs()
    pshard = SH.param_shardings(axes, mesh, rules)
    oshard = opt_shardings(mesh, pshard, pshapes, zero1=False)

    def train_step(params, opt_state, residual, batch):
        # ---- stage 1: per-shard gradients, pure GSPMD ----
        # (ndp, B/ndp, ...) with the shard dim sharded over the data
        # axes: each data shard computes its own microbatch gradient.
        def shard_view(t):
            return t.reshape((ndp, t.shape[0] // ndp) + t.shape[1:])

        sbatch = jax.tree.map(shard_view, batch)

        def per_shard(p, local_batch):
            # under vmap the activation constraints may only reference
            # the non-data axes ('model'); batch stays unconstrained
            inner_rules = {**(rules or {}), "batch": None}

            def loss_fn(pp):
                with SH.use_rules(mesh, inner_rules):
                    return model.loss(pp, local_batch, remat=remat)

            (loss, mets), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p)
            vec, _, _ = C.flatten_tree(grads)
            return vec, loss

        vecs, losses = jax.vmap(per_shard, in_axes=(None, 0))(params,
                                                              sbatch)
        vecs = jax.lax.with_sharding_constraint(vecs, _ns(mesh, bax))

        # ---- stage 2: int8 sync, fully-manual shard_map ----
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(bax), P(bax)), out_specs=(P(), P(bax)),
            check_vma=False)
        def sync(g, res):
            # HIERARCHICAL sync (measured, see §Perf): an int8 all_gather
            # over n shards moves n*bytes/4 on the wire -- WORSE than a
            # f32 ring all-reduce (2*bytes) once n > 8.  So: exact f32
            # pmean over the fast in-pod 'data' axis, int8+error-feedback
            # only across the slow 'pod' hop (n=2: 4x fewer inter-pod
            # bytes).  Falls back to int8-over-data when there is no pod
            # axis (small-DP case where it does win).
            if "pod" in bax and len(bax) > 1:
                inner = tuple(a for a in bax if a != "pod")
                vec = jax.lax.pmean(g[0], inner)
                mean_vec, new_res = C.sync_mean(vec, res[0], ("pod",))
            else:
                mean_vec, new_res = C.sync_mean(g[0], res[0], bax)
            return mean_vec, new_res[None]

        mean_vec, residual = sync(vecs, residual)
        _, treedef, shapes = C.flatten_tree(params)   # grads tree == params tree
        grads = C.unflatten_tree(mean_vec, treedef, shapes)
        params, opt_state, om = OPT.apply_updates(opt_cfg, params, grads,
                                                  opt_state)
        mets = {"loss": jnp.mean(losses), **om}
        return params, opt_state, residual, mets

    rshard = _ns(mesh, bax)
    jitted = jax.jit(
        train_step,
        in_shardings=(pshard, oshard, rshard, None),
        out_shardings=(pshard, oshard, rshard, None),
        donate_argnums=(0, 1, 2))
    return Step(jitted, mesh, rules, (pshard, oshard, rshard),
                (pshard, oshard, rshard))


def init_residual(params, mesh: Mesh):
    bax = batch_axes(mesh)
    ndp = 1
    for a in bax:
        ndp *= mesh.shape[a]
    spec = residual_specs(params)
    return jax.device_put(jnp.zeros((ndp, spec.shape[0]), jnp.float32),
                          _ns(mesh, bax))
