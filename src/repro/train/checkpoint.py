"""Block-based checkpointing: fixed-size blocks + manifest.

The paper's allocator discipline applied to checkpoints: every tensor is
serialized into fixed-size blocks (default 4 MiB) named by content
position, with a JSON manifest as the 'tree' (per-tensor block lists +
shapes/dtypes).  Consequences, exactly the paper's claims:

  * no contiguous file of model size is ever required (a 60 GB qwen3
    checkpoint is 15k independent 4 MiB objects -- object stores and
    parallel filesystems love this);
  * **elastic restore**: a different mesh/device count just reads a
    different block->shard mapping -- restore is a metadata remap, not a
    repartition (tests/test_checkpoint.py restores 8-dev -> 4-dev);
  * fault tolerance: write blocks + manifest-tmp, fsync, atomic rename;
    a crashed writer never corrupts the previous checkpoint.  keep_last
    garbage-collects old steps by deleting their block files.

Layout:
    <dir>/step_<k>/blocks/<tensor_idx>_<block_idx>.bin
    <dir>/step_<k>/manifest.json          (atomic rename last)
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

BLOCK_BYTES = 4 * 1024 * 1024


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep_last: int = 3,
         block_bytes: int = BLOCK_BYTES) -> str:
    """Serialize a pytree of arrays; returns the checkpoint path."""
    paths, leaves, _ = _flatten_with_paths(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    blocks_dir = os.path.join(tmp_dir, "blocks")
    os.makedirs(blocks_dir, exist_ok=True)

    manifest: Dict[str, Any] = {"step": step, "block_bytes": block_bytes,
                                "tensors": []}
    for ti, (pth, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        n_blocks = max(1, (len(raw) + block_bytes - 1) // block_bytes)
        blocks = []
        for bi in range(n_blocks):
            chunk = raw[bi * block_bytes: (bi + 1) * block_bytes]
            fname = f"{ti:05d}_{bi:05d}.bin"
            with open(os.path.join(blocks_dir, fname), "wb") as f:
                f.write(chunk)
            blocks.append(fname)
        manifest["tensors"].append({
            "path": pth, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "nbytes": len(raw), "blocks": blocks})
    mpath = os.path.join(tmp_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)        # atomic commit

    _gc(ckpt_dir, keep_last)
    return step_dir


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d))
    for d in os.listdir(ckpt_dir):      # orphaned tmp dirs from crashes
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *,
            shardings=None):
    """Rebuild the pytree (optionally placing each tensor with a sharding
    from a pytree of NamedShardings -- the elastic-restore path: the
    target mesh may differ arbitrarily from the writer's)."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {t["path"]: t for t in manifest["tensors"]}
    paths, leaves, treedef = _flatten_with_paths(like_tree)
    shard_leaves = (None if shardings is None
                    else treedef.flatten_up_to(shardings))
    out = []
    for i, (pth, leaf) in enumerate(zip(paths, leaves)):
        t = by_path[pth]
        raw = b"".join(
            open(os.path.join(step_dir, "blocks", b), "rb").read()
            for b in t["blocks"])
        arr = np.frombuffer(raw, dtype=np.dtype(t["dtype"])).reshape(
            t["shape"]).copy()
        if shard_leaves is not None and shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out)
