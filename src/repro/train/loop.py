"""Fault-tolerant training loop.

Production discipline at any scale:
  * resume: the loop starts from ``checkpoint.latest_step`` and the data
    pipeline is step-addressable, so a killed job restarted with the
    same config reproduces the uninterrupted run EXACTLY (bitwise --
    asserted by tests/test_fault_tolerance.py);
  * periodic block-based checkpoints (atomic, keep-last-k);
  * straggler monitor: per-step wall times feed an EWMA watermark; steps
    slower than ``straggler_factor`` x the watermark are logged and
    counted (on a real cluster this feeds the reschedule/evict policy;
    the hook is ``on_straggler``);
  * NaN/overflow guard: non-finite loss aborts with a checkpoint of the
    last good state rather than corrupting the run.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.data.pipeline import DataConfig, PrefetchIterator, make_source
from repro.optim import adamw as OPT
from repro.train import checkpoint as CKPT


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma: float = 0.9


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    ewma: float = 0.9
    watermark: Optional[float] = None
    n_stragglers: int = 0
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def observe(self, step: int, dt: float) -> bool:
        slow = False
        if self.watermark is not None and dt > self.factor * self.watermark:
            self.n_stragglers += 1
            slow = True
            if self.on_straggler:
                self.on_straggler(step, dt, self.watermark)
        # EWMA update excludes straggler samples so one hiccup does not
        # poison the baseline
        if self.watermark is None:
            self.watermark = dt
        elif not slow:
            self.watermark = self.ewma * self.watermark + (1 - self.ewma) * dt
        return slow


def run(step_fn, params, opt_state, data_cfg: DataConfig,
        loop_cfg: TrainLoopConfig, *, like=None,
        shardings=None, log: Callable[[str], None] = print) -> Dict[str, Any]:
    """step_fn(params, opt_state, batch) -> (params, opt_state, metrics).

    Returns summary dict.  ``like``/``shardings`` support elastic restore
    (restore onto whatever mesh step_fn was built for).
    """
    start = CKPT.latest_step(loop_cfg.ckpt_dir)
    if start is not None:
        state = CKPT.restore(loop_cfg.ckpt_dir, start,
                             {"params": params, "opt": opt_state},
                             shardings=shardings)
        params, opt_state = state["params"], state["opt"]
        log(f"[resume] restored step {start}")
        first = start
    else:
        first = 0

    src = make_source(data_cfg)
    it = PrefetchIterator(src, start_step=first)
    mon = StragglerMonitor(loop_cfg.straggler_factor, loop_cfg.ewma)
    losses = []
    try:
        for _ in range(first, loop_cfg.total_steps):
            step, batch = next(it)
            t0 = time.time()
            params, opt_state, mets = step_fn(params, opt_state, batch)
            loss = float(mets["loss"])
            dt = time.time() - t0
            mon.observe(step, dt)
            losses.append(loss)
            if not np.isfinite(loss):
                CKPT.save(loop_cfg.ckpt_dir, step,
                          {"params": params, "opt": opt_state},
                          keep_last=loop_cfg.keep_last)
                raise FloatingPointError(f"non-finite loss at step {step}")
            done = step + 1
            if done % loop_cfg.log_every == 0:
                log(f"[step {done}] loss={loss:.4f} "
                    f"dt={dt*1e3:.0f}ms stragglers={mon.n_stragglers}")
            if done % loop_cfg.ckpt_every == 0 or \
                    done == loop_cfg.total_steps:
                CKPT.save(loop_cfg.ckpt_dir, done,
                          {"params": params, "opt": opt_state},
                          keep_last=loop_cfg.keep_last)
    finally:
        it.close()
    return {"params": params, "opt_state": opt_state,
            "losses": losses, "stragglers": mon.n_stragglers}
