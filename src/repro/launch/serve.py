"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Runs the layered serving stack (scheduler policy / swap store / engine
mechanism) over the paged pool with synthetic request traffic; reports
throughput, pool utilization, swap traffic and prefix-share hits.

``--shared-frac`` controls what fraction of requests reuse one of a few
base prompts (possibly extended), exercising COW prefix sharing the way
parallel sampling / few-shot serving does.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs.base import get_config
from repro.models.api import build_model
from repro.serve.engine import Engine, Request


def make_traffic(rng, n, vocab, max_seq, shared_frac=0.0, n_bases=2):
    """Synthetic prompts; ``shared_frac`` of them share block prefixes."""
    cap = min(32, max_seq // 2)
    bases = [rng.randint(2, vocab, size=int(rng.randint(cap // 2, cap)))
             for _ in range(n_bases)]
    prompts = []
    for _ in range(n):
        if rng.rand() < shared_frac:
            b = bases[int(rng.randint(len(bases)))]
            extra = int(rng.randint(0, 6))
            prompts.append(np.concatenate(
                [b, rng.randint(2, vocab, size=extra)]) if extra else b.copy())
        else:
            prompts.append(rng.randint(2, vocab,
                                       size=int(rng.randint(4, cap))))
    return prompts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--num-blocks", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--watermark", type=int, default=None,
                    help="free blocks kept as growth headroom (default: "
                         "adaptive from the observed growth EWMA)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="max prompt tokens prefilled per step")
    ap.add_argument("--no-overlap", action="store_true",
                    help="synchronous transfers (drain per enqueue) "
                         "instead of the double-buffered schedule")
    ap.add_argument("--shared-frac", type=float, default=0.25,
                    help="fraction of requests sharing a base prompt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, max_positions=args.max_seq)
    params, _ = model.init(jax.random.PRNGKey(args.seed))

    eng = Engine(model, params, slots=args.slots, max_seq=args.max_seq,
                 num_blocks=args.num_blocks, eos_id=-1,
                 watermark=args.watermark,
                 prefill_budget=args.prefill_budget,
                 overlap_transfers=not args.no_overlap)
    rng = np.random.RandomState(args.seed)
    prompts = make_traffic(rng, args.requests, cfg.vocab_size, args.max_seq,
                           shared_frac=args.shared_frac)
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, max_new=args.max_new))
    t0 = time.time()
    done = eng.run(max_steps=10_000)
    dt = time.time() - t0
    st = eng.stats
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)}/{args.requests} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks / max(dt, 1e-9):.1f} tok/s), "
          f"{eng.steps} engine steps, final pool util "
          f"{eng.mgr.utilization:.0%}")
    print(f"prefix-share hits {st['prefix_hits']}, COW copies "
          f"{st['cow_copies']}, preemptions {st['preemptions']}, "
          f"swap out/in {st['swap_out_bytes']}/{st['swap_in_bytes']} bytes")
    tr = st["transfers"]
    print(f"transfer plane: {tr['enqueued']} plans, "
          f"{tr['launches']} launches ({tr['coalesced']} coalesced), "
          f"{tr['overlapped']['d2h']} host copies + "
          f"{tr['overlapped']['h2d']} prefetch scatters overlapped decode "
          f"({st['prefetch_hits']} resumes served from prefetch), "
          f"effective watermark {st['watermark_effective']}")
    return done


if __name__ == "__main__":
    main()
