"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Thin driver over the continuous-batching request plane: builds a seeded
arrival trace (``repro.serve.traffic.make_trace``), feeds it to
``Engine.serve`` -- requests are admitted as they ARRIVE on the
engine's step clock and retired as they finish, the batch never drains
between requests -- and reports throughput, pool utilization, swap
traffic, prefix-share hits and per-tenant p50/p99 TTFT and inter-token
latency.

``--trace`` picks the arrival shape (poisson / bursty / heavytail /
static), ``--tenants`` spreads requests round-robin across tenants,
``--policy fair`` switches admission to per-tenant deficit-round-robin
fairness, and ``--deadline-slack`` attaches SLOs that steer the
deadline-cost preemption policy.  ``--shared-frac`` controls what
fraction of requests reuse one of a few base prompts (possibly
extended), exercising COW prefix sharing the way parallel sampling /
few-shot serving does.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.base import get_config
from repro.models.api import build_model
from repro.serve.engine import Engine
from repro.serve.scheduler import FairAdmission
from repro.serve.traffic import TRACE_KINDS, make_trace


def _budget(v: str):
    """``--prefill-budget``: a positive int, 'auto', or 'none'."""
    if v == "auto":
        return "auto"
    if v in ("none", "None"):
        return None
    return int(v)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--num-blocks", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--trace", choices=TRACE_KINDS, default="poisson",
                    help="arrival shape fed to Engine.serve (virtual "
                         "step-clock arrivals; seeded and replayable)")
    ap.add_argument("--mean-gap", type=float, default=2.0,
                    help="mean inter-arrival gap in engine steps")
    ap.add_argument("--tenants", type=int, default=2,
                    help="requests assigned round-robin across tenants")
    ap.add_argument("--policy", choices=("fcfs", "fair"), default="fcfs",
                    help="admission order: FCFS (pinned default) or "
                         "per-tenant deficit-round-robin fairness")
    ap.add_argument("--deadline-slack", type=float, default=None,
                    help="attach deadline = arrival + slack * max_new "
                         "(steers deadline-cost preemption)")
    ap.add_argument("--watermark", type=int, default=None,
                    help="free blocks kept as growth headroom (default: "
                         "adaptive from the observed growth EWMA)")
    ap.add_argument("--prefill-budget", type=_budget, default="auto",
                    help="max prompt tokens prefilled per step: an int, "
                         "'auto' (adaptive from measured latency; the "
                         "default) or 'none' (unlimited)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="synchronous transfers (drain per enqueue) "
                         "instead of the double-buffered schedule")
    ap.add_argument("--shared-frac", type=float, default=0.25,
                    help="fraction of requests sharing a base prompt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, max_positions=args.max_seq)
    params, _ = model.init(jax.random.PRNGKey(args.seed))

    eng = Engine(model, params, slots=args.slots, max_seq=args.max_seq,
                 num_blocks=args.num_blocks, eos_id=-1,
                 watermark=args.watermark,
                 prefill_budget=args.prefill_budget,
                 admission_policy=(FairAdmission() if args.policy == "fair"
                                   else None),
                 overlap_transfers=not args.no_overlap)
    source = make_trace(args.trace, args.requests, cfg.vocab_size,
                        seed=args.seed, mean_gap=args.mean_gap,
                        tenants=args.tenants, max_new=args.max_new,
                        prompt_cap=min(32, args.max_seq // 2),
                        shared_frac=args.shared_frac,
                        deadline_slack=args.deadline_slack)
    t0 = time.time()
    done = eng.serve(source, max_steps=100_000)
    dt = time.time() - t0
    st = eng.stats
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)}/{args.requests} requests "
          f"({args.trace} arrivals, {args.tenants} tenants, "
          f"{args.policy} admission), {toks} tokens in "
          f"{dt:.1f}s ({toks / max(dt, 1e-9):.1f} tok/s), "
          f"{eng.steps} engine steps, final pool util "
          f"{eng.mgr.utilization:.0%}")
    print(f"prefix-share hits {st['prefix_hits']}, COW copies "
          f"{st['cow_copies']}, preemptions {st['preemptions']}, "
          f"swap out/in {st['swap_out_bytes']}/{st['swap_in_bytes']} bytes")
    tr = st["transfers"]
    print(f"transfer plane: {tr['enqueued']} plans, "
          f"{tr['launches']} launches ({tr['coalesced']} coalesced), "
          f"{tr['overlapped']['d2h']} host copies + "
          f"{tr['overlapped']['h2d']} prefetch scatters overlapped decode "
          f"({st['prefetch_hits']} resumes served from prefetch), "
          f"effective watermark {st['watermark_effective']}")
    for tenant, row in eng.latency_report().items():
        def fmt(v):
            return "n/a" if v is None else f"{v:.1f}"
        print(f"  {tenant}: {row['requests']} requests, TTFT p50/p99 "
              f"{fmt(row['ttft_p50_ms'])}/{fmt(row['ttft_p99_ms'])} ms, "
              f"ITL p50/p99 {fmt(row['itl_p50_ms'])}/"
              f"{fmt(row['itl_p99_ms'])} ms")
    return done


if __name__ == "__main__":
    main()
