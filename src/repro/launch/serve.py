"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Runs the continuous-batching engine over the paged pool on host devices
with synthetic request traffic; reports throughput and pool utilization.
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro.configs.base import get_config
from repro.models.api import build_model
from repro.serve.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--num-blocks", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, max_positions=args.max_seq)
    params, _ = model.init(jax.random.PRNGKey(args.seed))

    eng = Engine(model, params, slots=args.slots, max_seq=args.max_seq,
                 num_blocks=args.num_blocks, eos_id=-1)
    rng = np.random.RandomState(args.seed)
    for i in range(args.requests):
        plen = int(rng.randint(4, min(32, args.max_seq // 2)))
        eng.submit(Request(rid=i,
                           prompt=rng.randint(2, cfg.vocab_size, size=plen),
                           max_new=args.max_new))
    t0 = time.time()
    done = eng.run(max_steps=10_000)
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)}/{args.requests} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks / max(dt, 1e-9):.1f} tok/s), "
          f"{eng.steps} engine steps, final pool util "
          f"{eng.mgr.utilization:.0%}")
    return done


if __name__ == "__main__":
    main()
