import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as its own process (``python -m repro.launch.dryrun``):
the XLA_FLAGS line above runs before any other import so the 512
placeholder host devices exist before jax locks the device count.

For each runnable cell (see configs/shapes.py):
  * train_4k      -> train_step (fwd+bwd+AdamW update)
  * prefill_32k   -> forward-only loss (inference prefill)
  * decode_32k / long_500k -> serve_step (one token, paged KV)

Outputs per cell: compile OK/FAIL, memory_analysis, cost_analysis, and
roofline terms (repro.roofline) appended to a JSONL report.
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, cell_is_runnable
from repro import roofline as RL
from repro.launch import shardings as SH
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.steps import (build_forward_step, build_serve_step,
                                build_train_step, dp_groups_for)
from repro.models.api import build_model, decode_specs, input_specs
from repro.optim import adamw as OPT


def lower_cell(arch: str, shape_name: str, mesh, *, remat: bool = True,
               rules=None, opt_overrides=None, cfg_transform=None):
    """Returns (lowered, model_flops, chips)."""
    cfg = get_config(arch)
    if opt_overrides:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **opt_overrides)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = SHAPES[shape_name]
    model = build_model(cfg, max_positions=max(4096, shape.seq_len
                                               if shape.kind == "train" else 4096))
    chips = mesh.devices.size
    if shape.kind == "train":
        specs = input_specs(cfg, shape)
        if shape_name.startswith("prefill"):
            step = build_forward_step(model, mesh, rules=rules, remat=False)
            pshapes, _ = model.param_specs()
            lowered = step.lower(pshapes, specs)
        else:
            opt_cfg = OPT.AdamWConfig()
            # ZeRO-1 is mandatory at 27B scale on 16 GB chips: replicated
            # AdamW moments alone (8 bytes/param over the 16-way model
            # shard) would exceed HBM.
            step = build_train_step(model, mesh, opt_cfg, rules=rules,
                                    remat=remat, zero1=True)
            pshapes, _ = model.param_specs()
            oshapes = OPT.state_specs(pshapes)
            lowered = step.lower(pshapes, oshapes, specs)
    else:
        dp = dp_groups_for(mesh, shape.global_batch)
        tokens, state = decode_specs(cfg, shape, model=model, dp_groups=dp)
        step = build_serve_step(model, mesh, state, rules=rules)
        pshapes, _ = model.param_specs()
        lowered = step.lower(pshapes, tokens, state)
    return lowered, RL.model_flops_for(cfg, shape), chips


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             compile_: bool = True, verbose: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_desc = "pod2x16x16" if multi_pod else "16x16"
    ok, why = cell_is_runnable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_desc,
                "status": "skipped", "reason": why}
    t0 = time.time()
    try:
        lowered, model_flops, chips = lower_cell(arch, shape_name, mesh)
        t_lower = time.time() - t0
        if not compile_:
            return {"arch": arch, "shape": shape_name, "mesh": mesh_desc,
                    "status": "lowered", "t_lower_s": t_lower}
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rl = RL.analyze(compiled, arch=arch, shape=shape_name,
                        mesh_desc=mesh_desc, chips=chips,
                        model_flops=model_flops)
        row = rl.row()
        row.update(status="ok", t_lower_s=round(t_lower, 1),
                   t_compile_s=round(t_compile, 1))
        if verbose:
            mem = compiled.memory_analysis()
            print(f"  memory_analysis: args={mem.argument_size_in_bytes/1e9:.2f}GB "
                  f"temp={mem.temp_size_in_bytes/1e9:.2f}GB "
                  f"out={mem.output_size_in_bytes/1e9:.2f}GB", flush=True)
        return row
    except Exception as e:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_desc,
                "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="dryrun_report.jsonl")
    ap.add_argument("--lower-only", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    done = set()
    if os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("status") in ("ok", "skipped", "lowered"):
                done.add((r["arch"], r["shape"], r["mesh"]))

    n_fail = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    key = (arch, shape, "pod2x16x16" if mp else "16x16")
                    if key in done:
                        continue
                    desc = f"{arch} x {shape} x {'multi' if mp else 'single'}"
                    print(f"[dryrun] {desc} ...", flush=True)
                    row = run_cell(arch, shape, mp,
                                   compile_=not args.lower_only)
                    status = row["status"]
                    if status == "ok":
                        print(f"  OK  bottleneck={row['bottleneck']} "
                              f"t=({row['t_compute_s']:.4f}, "
                              f"{row['t_memory_s']:.4f}, "
                              f"{row['t_collective_s']:.4f})s "
                              f"frac={row['roofline_fraction']:.3f}",
                              flush=True)
                    elif status == "FAIL":
                        n_fail += 1
                        print(f"  FAIL {row['error']}", flush=True)
                    else:
                        print(f"  {status}: {row.get('reason','')}",
                              flush=True)
                    row.pop("trace", None)
                    f.write(json.dumps(row) + "\n")
                    f.flush()
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
