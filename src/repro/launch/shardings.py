"""Logical-axis sharding: maps model-declared logical axes onto the mesh.

Models annotate activations via ``constrain(x, "batch", None, "model")``
and parameters via logical-axis trees (see models/common.py).  The
launcher activates a mesh + rule set with ``use_rules``; without one,
``constrain`` is the identity (single-device smoke tests).

Rules (logical axis -> mesh axes):
    batch  -> ("pod", "data")   activations' batch dim
    heads  -> "model"           attn heads / ffn hidden / expert hidden
    vocab  -> "model"
    embed  -> None (replicated) or ("data",) under FSDP-style ZeRO-3
    expert -> None (TP-in-expert baseline) or "model" (EP mode)
    seq    -> "model"           sequence parallelism (norms/residuals)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "heads": "model",
    "attn_heads": "model",  # set to None per-arch when heads % tp != 0
    "vocab": "model",
    "embed": None,
    "expert": None,
    "layers": None,
    # sequence-parallel residual stream (Megatron-SP): the saved remat
    # carry and all norms/elementwise work shard the seq dim over model
    "seq": "model",
    # context parallelism: attention for archs whose head count does not
    # divide the model axis (MQA gemma-2b, whisper 6H, internvl 14H,
    # minicpm3 40H) shards the QUERY SEQUENCE over "model" instead of
    # replicating the whole attention computation per model rank.
    "ctx": "model",
}


def _current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Optional[Dict[str, Any]] = None):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop mesh axes that don't exist (single-pod mesh has no "pod")
    axes = set(mesh.axis_names)

    def filt(v):
        if v is None:
            return None
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in axes)
            return kept if kept else None
        return v if v in axes else None

    merged = {k: filt(v) for k, v in merged.items()}
    prev = _current()
    _state.ctx = (mesh, merged)
    try:
        yield
    finally:
        _state.ctx = prev


def spec_for(logical: Tuple[Optional[str], ...],
             rules: Optional[Dict[str, Any]] = None) -> P:
    ctx = _current()
    if rules is None:
        rules = ctx[1] if ctx else DEFAULT_RULES
    return P(*(rules.get(a) if a else None for a in logical))


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Sharding-constrain an activation by logical axis names (no-op when
    no mesh rules are active)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = P(*(rules.get(a) if a else None for a in logical))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def active_mesh() -> Optional[Mesh]:
    ctx = _current()
    return ctx[0] if ctx else None


def tp_size() -> int:
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def use_ctx_parallel(num_heads: int) -> bool:
    """True when per-head sharding over 'model' is impossible and
    attention should be context-parallel instead."""
    tp = tp_size()
    return tp > 1 and num_heads % tp != 0


def param_shardings(axes_tree, mesh: Mesh,
                    rules: Optional[Dict[str, Any]] = None):
    """Map a logical-axes pytree (from model init) to NamedShardings."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    axes = set(mesh.axis_names)

    def one(t):
        spec = []
        for a in t:
            v = merged.get(a) if a else None
            if isinstance(v, tuple):
                v = tuple(x for x in v if x in axes) or None
            elif v is not None and v not in axes:
                v = None
            spec.append(v)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda t: isinstance(t, tuple))
