"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs the real fault-tolerant loop on the host devices (tests/examples) or
lowers for the production mesh (--dry-run delegates to dryrun.py).
Reduced configs (--reduced) make every arch runnable on CPU.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train_step
from repro.models.api import build_model
from repro.optim import adamw as OPT
from repro.train import checkpoint as CKPT
from repro.train.loop import TrainLoopConfig, run


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, max_positions=max(4096, args.seq))
    mesh = make_host_mesh(model=args.model_parallel)

    opt_cfg = OPT.AdamWConfig(lr_peak=args.lr, warmup_steps=10,
                              total_steps=args.steps)
    step = build_train_step(model, mesh, opt_cfg, zero1=args.zero1)

    params, _ = model.init(jax.random.PRNGKey(args.seed))
    opt_state = OPT.init_state(params)
    # place on mesh
    pshard, oshard = step.in_shardings
    params = jax.device_put(params, pshard)
    opt_state = jax.device_put(opt_state, oshard)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed,
                          source=args.data, path=args.data_path)
    loop_cfg = TrainLoopConfig(total_steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir)
    out = run(step, params, opt_state, data_cfg, loop_cfg,
              shardings={"params": pshard, "opt": oshard})
    print(f"final loss: {out['losses'][-1]:.4f} "
          f"(first: {out['losses'][0]:.4f}, "
          f"stragglers: {out['stragglers']})")
    return out


if __name__ == "__main__":
    main()
