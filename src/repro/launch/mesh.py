"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single pod: (data=16, model=16) = 256 chips
of TPU v5e; multi-pod: (pod=2, data=16, model=16) = 512 chips, with the
batch sharded over (pod, data) and parameters over model.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist -- used by tests
    and examples, never by the dry-run."""
    n = jax.device_count()
    if data is None:
        data = n // model
    assert data * model == n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
