"""Jitted, sharded train/serve steps.

``build_train_step`` / ``build_serve_step`` wire a model to a mesh:
parameters Megatron-style over "model" (from the logical-axis trees),
batch over ("pod", "data"), paged KV pools co-sharded with the batch
(dp-grouped block ids keep every table gather local -- see
PagedKVConfig.dp_groups), optimizer state sharded like the params
(optionally ZeRO-1 over the data axis).

The returned ``Step.lower(*specs)`` lowers under the sharding-rules
context so ``constrain()`` calls inside the models resolve; the result
feeds both real execution and the dry-run/roofline pipeline.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.core.paged_kv import PagedKVCache
from repro.launch import shardings as SH
from repro.launch.mesh import batch_axes
from repro.models.rwkv_lm import RWKVState
from repro.models.whisper import WhisperState
from repro.models.zamba2 import ZambaState
from repro.optim import adamw as OPT


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def rules_for(cfg: ModelConfig, mesh: Mesh,
              overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Arch-aware sharding rules: attention weights replicate (and the
    attention goes context-parallel) when heads don't divide the model
    axis -- gemma-2b MQA, whisper 6H, internvl 14H, minicpm3 40H."""
    rules: Dict[str, Any] = {}
    tp = mesh.shape.get("model", 1)
    if tp > 1 and cfg.num_heads % tp != 0:
        rules["attn_heads"] = None
    if tp > 1 and cfg.vocab_size % tp != 0:
        # jit in_shardings require divisibility; the replicated embed is
        # small for exactly these archs (internvl 272MB, minicpm 376MB,
        # whisper 38MB)
        rules["vocab"] = None
    if overrides:
        rules.update(overrides)
    return rules


def _div(n: int, mesh: Mesh, axes: Tuple[str, ...]) -> bool:
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
    return n % prod == 0 and n >= prod


def dp_groups_for(mesh: Mesh, global_batch: int) -> int:
    bax = batch_axes(mesh)
    prod = 1
    for a in bax:
        prod *= mesh.shape[a]
    return prod if global_batch % prod == 0 and global_batch >= prod else 1


# ---------------------------------------------------------------------------
# batch / state shardings
# ---------------------------------------------------------------------------
def batch_shardings(mesh: Mesh, batch_specs: Dict[str, Any],
                    global_batch: int):
    bax = batch_axes(mesh)
    b = bax if _div(global_batch, mesh, bax) else None

    def one(v):
        return _ns(mesh, b, *([None] * (len(v.shape) - 1)))

    return jax.tree.map(one, batch_specs)


def _kv_head_axis(mesh: Mesh, kvh: int) -> Optional[str]:
    return "model" if ("model" in mesh.axis_names and kvh >= mesh.shape["model"]
                       and kvh % mesh.shape["model"] == 0) else None


def paged_cache_shardings(mesh: Mesh, cache: PagedKVCache):
    cfg = cache.config
    bax = batch_axes(mesh)
    B = cache.block_tables.shape[0]
    b = bax if (cfg.dp_groups > 1 and _div(B, mesh, bax)) else None
    if cfg.latent and cfg.latent_rope:
        # latent TP: lora stream sharded over 'model' on its last dim
        la = ("model" if ("model" in mesh.axis_names and
                          cfg.head_dim % mesh.shape["model"] == 0) else None)
        kpool = _ns(mesh, None, b, None, la)
        vpool = _ns(mesh, None, b, None, None)
    elif cfg.latent:
        kpool = _ns(mesh, None, b, None, None)
        vpool = None
    else:
        ha = _kv_head_axis(mesh, cfg.kv_heads)
        kpool = _ns(mesh, None, b, None, ha, None)
        vpool = kpool
    return PagedKVCache(
        k_pool=kpool, v_pool=vpool,
        block_tables=_ns(mesh, b, None),
        seq_lens=_ns(mesh, b),
        config=cfg)


def state_shardings(mesh: Mesh, state, cfg: ModelConfig):
    bax = batch_axes(mesh)
    if isinstance(state, PagedKVCache):
        return paged_cache_shardings(mesh, state)
    if isinstance(state, RWKVState):
        B = state.mix_x.shape[1]
        b = bax if _div(B, mesh, bax) else None
        H = state.wkv.shape[2]
        ha = _kv_head_axis(mesh, H)
        return RWKVState(_ns(mesh, None, b, None), _ns(mesh, None, b, None),
                         _ns(mesh, None, b, ha, None, None))
    if isinstance(state, ZambaState):
        B = state.conv.shape[2]
        b = bax if _div(B, mesh, bax) else None
        H = state.ssd.shape[3]
        ha = _kv_head_axis(mesh, H)
        return ZambaState(_ns(mesh, None, None, b, None, None),
                          _ns(mesh, None, None, b, ha, None, None),
                          paged_cache_shardings(mesh, state.kv))
    if isinstance(state, WhisperState):
        B = state.cross_k.shape[1]
        b = bax if _div(B, mesh, bax) else None
        ha = _kv_head_axis(mesh, state.cross_k.shape[3])
        cross = _ns(mesh, None, b, None, ha, None)
        return WhisperState(paged_cache_shardings(mesh, state.self_kv),
                            cross, cross)
    raise TypeError(type(state))


def opt_shardings(mesh: Mesh, param_shard, param_shapes,
                  zero1: bool = False) -> OPT.AdamWState:
    """Moments shard like params; ZeRO-1 additionally shards the first
    replicated, data-divisible dim of each moment over 'data'."""

    def moment(ns: NamedSharding, shape):
        spec = list(ns.spec) + [None] * (len(shape.shape) - len(ns.spec))
        if zero1 and "data" in mesh.axis_names:
            for i, (s, dim) in enumerate(zip(spec, shape.shape)):
                if s is None and dim % mesh.shape["data"] == 0 and \
                        dim >= mesh.shape["data"]:
                    spec[i] = "data"
                    break
        return _ns(mesh, *spec)

    mu = jax.tree.map(moment, param_shard, param_shapes)
    return OPT.AdamWState(step=_ns(mesh), mu=mu, nu=mu)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Step:
    jitted: Any
    mesh: Mesh
    rules: Optional[Dict[str, Any]]
    in_shardings: Any
    out_shardings: Any

    def lower(self, *arg_specs):
        with self.mesh, SH.use_rules(self.mesh, self.rules):
            return self.jitted.lower(*arg_specs)

    def __call__(self, *args):
        with self.mesh, SH.use_rules(self.mesh, self.rules):
            return self.jitted(*args)


def build_train_step(model, mesh: Mesh, opt_cfg: OPT.AdamWConfig, *,
                     rules: Optional[Dict[str, Any]] = None,
                     remat: bool = True, zero1: bool = False,
                     donate: bool = True) -> Step:
    rules = rules_for(model.cfg, mesh, rules)
    pshapes, axes = model.param_specs()
    pshard = SH.param_shardings(axes, mesh, rules)
    oshard = opt_shardings(mesh, pshard, pshapes, zero1=zero1)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch, remat=remat)

        (loss, mets), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, om = OPT.apply_updates(opt_cfg, params, grads,
                                                  opt_state)
        mets = {**mets, **om, "loss": loss}
        return params, opt_state, mets

    jitted = jax.jit(
        train_step,
        in_shardings=(pshard, oshard, None),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1) if donate else ())
    return Step(jitted, mesh, rules, (pshard, oshard), (pshard, oshard))


def build_serve_step(model, mesh: Mesh, state_example, *,
                     rules: Optional[Dict[str, Any]] = None,
                     donate: bool = True) -> Step:
    """state_example: state pytree (arrays or ShapeDtypeStructs) used to
    derive shardings."""
    cfg = model.cfg
    rules = rules_for(cfg, mesh, rules)
    pshapes, axes = model.param_specs()
    pshard = SH.param_shardings(axes, mesh, rules)
    sshard = state_shardings(mesh, state_example, cfg)
    tokens_b = None
    B = (state_example.block_tables.shape[0]
         if isinstance(state_example, PagedKVCache) else None)
    if B is None:
        B = jax.tree.leaves(state_example)[0].shape[1]
    bax = batch_axes(mesh)
    tshard = _ns(mesh, bax if _div(B, mesh, bax) else None)

    def serve_step(params, tokens, state):
        logits, state = model.decode_step(params, tokens, state)
        return logits, state

    jitted = jax.jit(
        serve_step,
        in_shardings=(pshard, tshard, sshard),
        out_shardings=(_ns(mesh, tshard.spec[0],
                           rules.get("vocab", "model")), sshard),
        donate_argnums=(2,) if donate else ())
    return Step(jitted, mesh, rules, (pshard, tshard, sshard), sshard)


def build_prefill_step(model, mesh: Mesh, state_example, global_batch: int, *,
                       rules: Optional[Dict[str, Any]] = None) -> Step:
    rules = rules_for(model.cfg, mesh, rules)
    pshapes, axes = model.param_specs()
    pshard = SH.param_shardings(axes, mesh, rules)
    sshard = state_shardings(mesh, state_example, model.cfg)
    bax = batch_axes(mesh)
    b = bax if _div(global_batch, mesh, bax) else None

    def prefill_step(params, batch, state, lengths):
        return model.prefill(params, batch, state, lengths)

    jitted = jax.jit(
        prefill_step,
        in_shardings=(pshard, None, sshard, _ns(mesh, b)),
        out_shardings=(_ns(mesh, b, rules.get("vocab", "model")), sshard))
    return Step(jitted, mesh, rules, None, None)


def build_forward_step(model, mesh: Mesh, *, rules=None,
                       remat: bool = False) -> Step:
    """Forward-only (inference-prefill shape): logits + loss metrics."""
    rules = rules_for(model.cfg, mesh, rules)
    pshapes, axes = model.param_specs()
    pshard = SH.param_shardings(axes, mesh, rules)

    def fwd(params, batch):
        loss, mets = model.loss(params, batch, remat=remat)
        return loss, mets

    jitted = jax.jit(fwd, in_shardings=(pshard, None))
    return Step(jitted, mesh, rules, pshard, None)
