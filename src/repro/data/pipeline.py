"""Deterministic, shard-aware, step-addressable data pipeline.

Fault-tolerance requirement: after a restart at step k the pipeline must
reproduce exactly the batches that would have been consumed -- so batches
are a pure function of (seed, step, shard).  Two sources:

  * SyntheticLM  -- deterministic token streams (markov-ish mixture so
    the loss actually decreases during the e2e example).
  * MemmapTokens -- np.memmap over a flat token file, blocked into the
    paper's fixed-size quanta: the document index is a TreeArray over
    32 KB blocks rather than one giant contiguous index array.

Both produce {tokens, targets} with next-token targets; the host->device
path prefetches one step ahead.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.core.treearray import TreeArray


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | memmap
    path: Optional[str] = None


class SyntheticLM:
    """Deterministic mixture of repeated n-grams + noise; batches are a
    pure function of (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.RandomState(cfg.seed)
        self._motifs = base.randint(
            0, cfg.vocab_size, size=(64, 16)).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % 2**31)
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.randint(0, cfg.vocab_size, size=(B, S + 1)).astype(np.int32)
        # overwrite most positions with repeated motifs => learnable signal
        for b in range(B):
            pos = 0
            while pos < S + 1 - 16:
                m = self._motifs[rng.randint(0, 64)]
                toks[b, pos: pos + 16] = m
                pos += 16 + rng.randint(0, 4)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class MemmapTokens:
    """Flat token file + TreeArray-backed sequence index.

    The index (start offset of each sequence) lives in 32 KB TreeArray
    blocks -- no contiguous index allocation, per the paper.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        n_seqs = (len(self.tokens) - 1) // cfg.seq_len
        starts = (np.arange(n_seqs) * cfg.seq_len).astype(np.float32)
        self.index = TreeArray.from_dense(starts, leaf_size=8192)
        self.n_seqs = n_seqs

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % 2**31)
        idx = rng.randint(0, self.n_seqs, size=cfg.global_batch)
        starts = np.asarray(self.index.get_naive(
            jax.numpy.asarray(idx))).astype(np.int64)
        out = np.stack([self.tokens[s: s + cfg.seq_len + 1]
                        for s in starts]).astype(np.int32)
        return {"tokens": out[:, :-1], "targets": out[:, 1:]}


def make_source(cfg: DataConfig):
    return MemmapTokens(cfg) if cfg.source == "memmap" else SyntheticLM(cfg)


class PrefetchIterator:
    """Background-thread prefetch of ``depth`` steps, resumable at any
    step (the fault-tolerant train loop hands it the restored step)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
