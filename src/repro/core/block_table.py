"""Block-table utilities: deep tables, compaction, swap manifests.

A per-sequence block table is a depth-1 tree.  When a sequence's table
itself no longer fits one block (long_500k: 524288 tokens / 64-token
blocks = 8192 ids = exactly one 32 KB block of int32 -- the paper's
magnitude argument holds up remarkably well), tables become depth-2
trees; ``deep_table``/``resolve_deep`` implement that without changing
the pool.

Compaction: with fixed blocks there is NO external fragmentation (the
paper's point), so "defrag" here only means migrating live blocks to a
dense prefix so a shrinking pool can return arena memory -- a pure block
copy plan plus a table rewrite, never a data-structure rebuild.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.blockpool import NULL_BLOCK, BlockAllocator


def pack_table(blocks: Sequence[int], capacity: int) -> np.ndarray:
    t = np.full(capacity, NULL_BLOCK, np.int32)
    t[: len(blocks)] = np.asarray(blocks, np.int32)
    return t


def deep_table(blocks: Sequence[int], ids_per_block: int,
               allocator: BlockAllocator) -> Tuple[np.ndarray, List[int]]:
    """Split a long table into table-blocks; return (root, table_block_ids).

    root[i] = id of the table-block holding ids [i*ipb, (i+1)*ipb).
    Table blocks are drawn from the same allocator as data blocks -- one
    arena, one block size, as in the paper.
    """
    ipb = ids_per_block
    n = (len(blocks) + ipb - 1) // ipb
    tb_ids = allocator.alloc_many(max(1, n))
    root = np.asarray(tb_ids, np.int32)
    return root, tb_ids


def resolve_deep(root: np.ndarray, table_storage: np.ndarray,
                 logical_block: np.ndarray, ids_per_block: int) -> np.ndarray:
    """Two-level resolve: logical block no -> physical data block id.

    table_storage: (num_blocks, ids_per_block) int32 view of the arena's
    table blocks.  Vectorized -- this is the same walk TreeArray does.
    """
    tb = root[logical_block // ids_per_block]
    return table_storage[tb, logical_block % ids_per_block]


def compaction_plan(live_blocks: Sequence[int]) -> List[Tuple[int, int]]:
    """Plan (src, dst) copies moving live blocks to the dense prefix.

    Returns a minimal move list: blocks already inside the prefix stay.
    """
    live = sorted(set(int(b) for b in live_blocks))
    n = len(live)
    prefix = set(b for b in live if b < n)
    holes = [i for i in range(n) if i not in prefix]
    movers = [b for b in live if b >= n]
    assert len(holes) == len(movers)
    return list(zip(movers, holes))


def apply_compaction(tables: Dict[int, List[int]],
                     plan: List[Tuple[int, int]]) -> None:
    """Rewrite host tables after the device executed the copy plan."""
    remap = dict(plan)
    for seq, blocks in tables.items():
        tables[seq] = [remap.get(b, b) for b in blocks]
