"""BlockStack: the paper's split stack as a runtime data structure.

gcc's split-stack is x86 codegen; the *mechanism* is: on every push
(function call), a ~3-instruction check asks "does the current block have
room?"; almost always yes -> bump pointer; rarely no -> link a fresh
fixed-size block from the allocator.  Pop unlinks when a block empties.

In this framework the BlockStack backs host-side runtime structures that
grow unpredictably -- the serving scheduler's per-request scratch, swap
manifests, and the data pipeline's shard queues -- so that NOTHING in the
runtime ever asks the allocator for a large contiguous region.  The
benchmark ``bench_stack.py`` reproduces Fig. 3's claim (check-on-push is
~2% typical, ~15% pathological) against a plain contiguous list.

There is also a device-side variant (``DeviceBlockStack``) used for
fixed-capacity LIFO free-lists inside jitted serving code.
"""

from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.mem import Arena, BlockAllocator, Mapping, OutOfBlocksError


class BlockStack:
    """Host-side stack of Python scalars/objects in fixed-size blocks.

    Blocks are plain Python lists addressed by ids drawn from a shared
    allocator (ids only -- storage is per-stack), so many stacks share
    one arena without any contiguity assumption.  Pass ``arena`` (plus a
    registered ``pool_class``) to account the linked blocks against the
    unified ``repro.mem.Arena`` through a flat ``Mapping``; the legacy
    ``allocator`` argument draws raw ids instead.
    """

    __slots__ = ("block_size", "_alloc", "_mapping", "_blocks",
                 "_block_ids", "_top", "_cur", "_off")

    def __init__(self, block_size: int = 4096,
                 allocator: Optional[BlockAllocator] = None,
                 arena: Optional[Arena] = None,
                 pool_class: str = "stack", owner="stack"):
        self.block_size = int(block_size)
        self._alloc = allocator
        self._mapping: Optional[Mapping] = (
            arena.mapping(pool_class, owner) if arena is not None else None)
        self._blocks: List[list] = []
        self._block_ids: List[int] = []
        self._top = 0          # total element count
        self._cur: Optional[list] = None   # cached current leaf (the
        self._off = 0          # paper's iterator/split-stack fast path)

    def __len__(self) -> int:
        return self._top

    def _grow(self) -> None:
        # the "rare path": link a new fixed-size block
        if self._mapping is not None:
            self._block_ids.append(self._mapping.append_blocks(1)[0])
        elif self._alloc is not None:
            self._block_ids.append(self._alloc.alloc())
        blk = [None] * self.block_size
        self._blocks.append(blk)
        self._cur = blk
        self._off = 0

    def _unlink_last(self) -> None:
        self._blocks.pop()
        if self._mapping is not None:
            self._mapping.pop_block()
            self._block_ids.pop()
        elif self._alloc is not None:
            self._alloc.free(self._block_ids.pop())

    def push(self, item: Any) -> None:
        # fast path: one compare (the split-stack space check) + store
        off = self._off
        if off == self.block_size or self._cur is None:
            blk_no = self._top // self.block_size
            if blk_no == len(self._blocks):
                self._grow()
            else:
                self._cur = self._blocks[blk_no]
                self._off = 0
            off = self._off
        self._cur[off] = item
        self._off = off + 1
        self._top += 1

    def pop(self) -> Any:
        if self._top == 0:
            raise IndexError("pop from empty BlockStack")
        off = self._off
        if off == 0:   # rare: step back into the previous block
            blk_no = (self._top - 1) // self.block_size
            # unlink emptied trailing blocks (one block hysteresis)
            while len(self._blocks) > blk_no + 1:
                self._unlink_last()
            self._cur = self._blocks[blk_no]
            off = self._top - blk_no * self.block_size
        item = self._cur[off - 1]
        self._cur[off - 1] = None
        self._off = off - 1
        self._top -= 1
        if self._top == 0:
            # fully drained: drop the hysteresis block too, so shared
            # arenas see a quiescent stack (leak invariant in tests)
            while self._blocks:
                self._unlink_last()
            self._cur = None
            self._off = 0
        return item

    def peek(self) -> Any:
        if self._top == 0:
            raise IndexError("peek of empty BlockStack")
        if self._off > 0:
            return self._cur[self._off - 1]
        blk, off = divmod(self._top - 1, self.block_size)
        return self._blocks[blk][off]

    def peek_n(self, k: int) -> List[Any]:
        """Top ``k`` items, top-of-stack first, without popping (the
        speculative resume window's read-only view).  Returns fewer when
        the stack holds fewer."""
        out = []
        for i in range(min(k, self._top)):
            blk, off = divmod(self._top - 1 - i, self.block_size)
            out.append(self._blocks[blk][off])
        return out

    @property
    def num_blocks(self) -> int:
        return len(self._blocks)


class DeviceBlockStack:
    """Fixed-capacity int32 LIFO as JAX arrays, for jitted free-lists.

    Functional: ``push``/``pop`` return new instances.  Used by the
    serving engine's on-device block free-list so block alloc/free can
    happen inside a jitted decode step without host round-trips.
    """

    def __init__(self, data: jax.Array, top: jax.Array):
        self.data = data
        self.top = top

    @classmethod
    def full_of(cls, values: jax.Array) -> "DeviceBlockStack":
        values = jnp.asarray(values, jnp.int32)
        return cls(values, jnp.asarray(values.shape[0], jnp.int32))

    @classmethod
    def empty(cls, capacity: int) -> "DeviceBlockStack":
        return cls(jnp.zeros(capacity, jnp.int32), jnp.asarray(0, jnp.int32))

    def push(self, v: jax.Array) -> "DeviceBlockStack":
        return DeviceBlockStack(self.data.at[self.top].set(v), self.top + 1)

    def pop(self):
        v = self.data[self.top - 1]
        return v, DeviceBlockStack(self.data, self.top - 1)


jax.tree_util.register_pytree_node(
    DeviceBlockStack,
    lambda s: ((s.data, s.top), None),
    lambda aux, ch: DeviceBlockStack(*ch),
)
