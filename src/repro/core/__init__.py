"""Core: the paper's software memory management as composable JAX modules."""

from repro.core.blockpool import (BlockAllocator, BlockPool, NULL_BLOCK,
                                  OutOfBlocksError)
from repro.core.treearray import TreeArray, tree_depth_for
from repro.core.paged_kv import PagedKVCache, PagedKVConfig, PagedKVManager
from repro.core.stack import BlockStack, DeviceBlockStack

__all__ = [
    "BlockAllocator", "BlockPool", "NULL_BLOCK", "OutOfBlocksError",
    "TreeArray", "tree_depth_for",
    "PagedKVCache", "PagedKVConfig", "PagedKVManager",
    "BlockStack", "DeviceBlockStack",
]
