"""Paged KV cache: the paper's block-allocated memory applied to serving.

A decoding sequence's KV cache is the canonical "large, growing array"
that virtual memory used to make contiguous.  Here it is stored the
paper's way: fixed-size blocks of ``block_tokens`` tokens drawn from a
shared pool, addressed through a per-sequence **block table** (a depth-1
tree; ``TreeArray`` provides deeper tables when max_blocks_per_seq
exceeds one table block -- see ``block_table.py``).

Pools are stacked over layers (leading L axis) so the per-layer slice
threads through ``lax.scan`` over the model's layers.  One block id is
valid across all layers/heads -- the pool's trailing dims carry
(kv_heads, head_dim), which also gives the natural sharding:

    (L, num_blocks[data], block_tokens, kv_heads[model], head_dim)

Standard (k,v) pools and MLA latent pools (single compressed c_kv stream,
DeepSeek-V2/MiniCPM3) are both supported; MLA's latent blocks are ~4x
smaller per token -- the paper's "choose your own block quantum" argument
in action.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockpool import BlockAllocator, NULL_BLOCK


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    num_layers: int
    kv_heads: int          # 0 for MLA latent mode
    head_dim: int          # per-head dim; for MLA: latent_dim = kv_lora + rope
    block_tokens: int = 64
    num_blocks: int = 1024
    max_blocks_per_seq: int = 16
    latent: bool = False   # MLA: single stream, no separate V pool
    # split-latent mode (latent TP): k_pool holds the kv_lora stream
    # (head_dim = kv_lora, shardable over 'model'), v_pool holds the
    # shared rope keys of width latent_rope.
    latent_rope: int = 0
    dtype: jnp.dtype = jnp.bfloat16
    # data-parallel pool groups: the pool's block dim is split into
    # dp_groups contiguous ranges co-sharded with the batch, and block
    # tables hold GROUP-LOCAL ids.  This makes every table gather/scatter
    # structurally local (a batched gather), so GSPMD never needs to move
    # pool blocks across the data axis.
    dp_groups: int = 1

    @property
    def max_seq_len(self) -> int:
        return self.max_blocks_per_seq * self.block_tokens

    def token_shape(self) -> Tuple[int, ...]:
        return (self.head_dim,) if self.latent else (self.kv_heads, self.head_dim)

    def pool_shape(self) -> Tuple[int, ...]:
        return (self.num_layers, self.num_blocks, self.block_tokens,
                *self.token_shape())

    def bytes_per_token_per_layer(self) -> int:
        streams = 1 if self.latent else 2
        per = int(np.prod(self.token_shape()))
        return streams * per * jnp.dtype(self.dtype).itemsize

    def swap_nbytes_per_block(self) -> int:
        """Device<->host bytes to move ONE block (all layers, all streams).

        This is the unit the serving swap path is held to: a preempted
        sequence holding n blocks moves exactly n * this many bytes --
        never a function of num_blocks (pool size).
        """
        per = int(np.prod(self.token_shape()))   # k (or latent) stream
        width = per if self.latent else 2 * per
        if self.latent and self.latent_rope:
            width += self.latent_rope            # shared rope-key stream
        return (self.num_layers * self.block_tokens * width
                * jnp.dtype(self.dtype).itemsize)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """Functional paged KV state threaded through decode steps."""

    k_pool: jax.Array            # (L, NB, BT, KVH, HD) or (L, NB, BT, LAT) for MLA
    v_pool: Optional[jax.Array]  # None in latent (MLA) mode
    block_tables: jax.Array      # (B, max_blocks_per_seq) int32
    seq_lens: jax.Array          # (B,) int32 -- tokens already cached
    config: PagedKVConfig = dataclasses.field(metadata=dict(static=True))

    def tree_flatten(self):
        return (self.k_pool, self.v_pool, self.block_tables, self.seq_lens), self.config

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch, aux)

    # -- constructors --------------------------------------------------
    @classmethod
    def create(cls, config: PagedKVConfig, batch: int) -> "PagedKVCache":
        k = jnp.zeros(config.pool_shape(), config.dtype)
        if config.latent:
            v = (jnp.zeros((*config.pool_shape()[:-1], config.latent_rope),
                           config.dtype) if config.latent_rope else None)
        else:
            v = jnp.zeros(config.pool_shape(), config.dtype)
        tables = jnp.full((batch, config.max_blocks_per_seq), NULL_BLOCK, jnp.int32)
        lens = jnp.zeros((batch,), jnp.int32)
        return cls(k, v, tables, lens, config)

    @classmethod
    def specs(cls, config: PagedKVConfig, batch: int) -> "PagedKVCache":
        """ShapeDtypeStruct stand-in for the dry-run (no allocation)."""
        sds = jax.ShapeDtypeStruct
        k = sds(config.pool_shape(), config.dtype)
        if config.latent:
            v = (sds((*config.pool_shape()[:-1], config.latent_rope),
                     config.dtype) if config.latent_rope else None)
        else:
            v = sds(config.pool_shape(), config.dtype)
        tables = sds((batch, config.max_blocks_per_seq), jnp.int32)
        lens = sds((batch,), jnp.int32)
        return cls(k, v, tables, lens, config)

    @property
    def batch(self) -> int:
        return self.block_tables.shape[0]

    # -- addressing ------------------------------------------------------
    def _addr(self, pos: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Logical position -> (physical block id per seq, offset)."""
        blk_no = pos // self.config.block_tokens
        off = pos % self.config.block_tokens
        b = jnp.arange(self.batch)
        phys = self.block_tables[b, blk_no]
        return phys, off

    # -- writes ---------------------------------------------------------
    def append_token(self, k_new: jax.Array,
                     v_new: Optional[jax.Array]) -> "PagedKVCache":
        """Write one new token's KV for ALL layers at position seq_lens.

        k_new: (L, B, KVH, HD) (or (L, B, LAT) latent).  Returns cache
        with seq_lens advanced by 1.
        """
        phys, off = self._addr(self.seq_lens)
        k_pool = self.k_pool.at[:, phys, off].set(
            k_new.astype(self.config.dtype))
        v_pool = self.v_pool
        if v_new is not None:
            v_pool = self.v_pool.at[:, phys, off].set(v_new.astype(self.config.dtype))
        return dataclasses.replace(
            self, k_pool=k_pool, v_pool=v_pool, seq_lens=self.seq_lens + 1)

    def write_layer_token(self, layer_kv, layer: jax.Array):
        """Per-layer single-token write, for use inside lax.scan bodies.

        layer_kv: (k (B,KVH,HD), v or None).  Positions taken from
        seq_lens (NOT advanced here -- call ``advance`` once per step).
        Returns updated per-layer pool slices to be re-stacked by scan.
        """
        raise NotImplementedError("use pool slices via scan xs; see models/")

    def _scatter_blocks(self, pool, tbl, payload):
        """pool (L, NB, BT, ...) .at[:, tbl].set(payload) with dp-group
        local block ids when dp_groups > 1 (see PagedKVConfig)."""
        dp = self.config.dp_groups
        if dp <= 1:
            return pool.at[:, tbl].set(payload)
        L, NB = pool.shape[:2]
        B = tbl.shape[0]
        pg = pool.reshape(L, dp, NB // dp, *pool.shape[2:])
        tg = tbl.reshape(dp, B // dp, tbl.shape[1])
        pay = payload.reshape(payload.shape[0], dp, B // dp,
                              *payload.shape[2:])
        out = jax.vmap(lambda pl, tb, pp: pl.at[:, tb].set(pp),
                       in_axes=(1, 0, 1), out_axes=1)(pg, tg, pay)
        return out.reshape(pool.shape)

    def write_prefill(self, k: jax.Array, v: Optional[jax.Array],
                      lengths: jax.Array) -> "PagedKVCache":
        """Bulk-write prompts.  k: (L, B, S, KVH, HD); positions 0..S-1.

        Tokens beyond ``lengths[b]`` are written too (harmless -- masked
        by seq_lens at read time), keeping the write dense/regular.
        """
        L, B, S = k.shape[:3]
        bt = self.config.block_tokens
        assert S % bt == 0, "prefill length must be block-aligned"
        nblk = S // bt
        tbl = jnp.maximum(self.block_tables[:, :nblk], 0)       # (B, nblk)
        kb = k.reshape(L, B, nblk, bt, *k.shape[3:]).astype(self.config.dtype)
        k_pool = self._scatter_blocks(self.k_pool, tbl, kb)
        v_pool = self.v_pool
        if v is not None:
            vb = v.reshape(L, B, nblk, bt, *v.shape[3:]).astype(self.config.dtype)
            v_pool = self._scatter_blocks(self.v_pool, tbl, vb)
        return dataclasses.replace(self, k_pool=k_pool, v_pool=v_pool,
                                   seq_lens=lengths.astype(jnp.int32))

    def advance(self, n: int = 1) -> "PagedKVCache":
        return dataclasses.replace(self, seq_lens=self.seq_lens + n)

    # -- reads ----------------------------------------------------------
    def gather_layer(self, layer_k: jax.Array, layer_v: Optional[jax.Array]):
        """Materialize (B, S_max, ...) views of one layer's pool slices.

        This is the *reference* read path (the Pallas paged_attention
        kernel streams blocks instead).  Invalid table entries are
        clipped; callers mask by seq_lens.
        """
        tbl = jnp.maximum(self.block_tables, 0)  # clip NULL
        k = layer_k[tbl]            # (B, nblk, BT, ...)
        k = k.reshape(k.shape[0], -1, *k.shape[3:])
        if layer_v is None:
            return k, None
        v = layer_v[tbl]
        v = v.reshape(v.shape[0], -1, *v.shape[3:])
        return k, v


class PagedKVManager:
    """Host-side allocator policy for the cache (the 'OS').

    Owns a BlockAllocator over the pool; grows/frees per-sequence tables
    as the engine admits, extends, preempts, and finishes requests.  The
    manager deals ONLY in block ids -- payload transfers (swap-out/in at
    block granularity, COW block copies) are the caller's job, so that
    bytes moved always scale with blocks held, never with pool size
    (see ``serve/swap.py`` and ``kernels/block_copy.py``).
    """

    def __init__(self, config: PagedKVConfig):
        self.config = config
        self.allocator = BlockAllocator(config.num_blocks)
        # block ids per live sequence (host view of the device tables)
        self.tables: dict[int, List[int]] = {}
        # seq_id -> number of blocks held at swap-out time (payload lives
        # in the caller's host block store)
        self.swapped: dict[int, int] = {}

    # -- admission/extension ------------------------------------------
    def blocks_needed(self, tokens: int) -> int:
        bt = self.config.block_tokens
        return (tokens + bt - 1) // bt

    def can_admit(self, tokens: int) -> bool:
        return self.allocator.num_free >= self.blocks_needed(tokens)

    def admit(self, seq_id: int, tokens: int) -> List[int]:
        blocks = self.allocator.alloc_many(self.blocks_needed(tokens))
        self.tables[seq_id] = blocks
        return blocks

    def extend(self, seq_id: int, new_total_tokens: int) -> List[int]:
        """Ensure capacity for new_total_tokens; returns newly added ids."""
        have = self.tables[seq_id]
        need = self.blocks_needed(new_total_tokens)
        fresh = self.allocator.alloc_many(max(0, need - len(have)))
        have.extend(fresh)
        return fresh

    def release(self, seq_id: int) -> None:
        self.allocator.free_many(self.tables.pop(seq_id))

    def reserve_block(self) -> int:
        """Permanently claim one block (never handed to a sequence).

        The engine points masked prefill-table entries at this 'sink'
        block so padded rows and COW-aliased prefixes have a harmless
        scatter target.
        """
        return self.allocator.alloc()

    # -- COW prefix sharing ---------------------------------------------
    def fork(self, parent_id: int, child_id: int,
             shared_tokens: int) -> List[int]:
        """COW: child aliases EVERY parent block covering shared_tokens.

        A trailing partially-filled block is aliased too; the first
        divergent write into it goes through ``ensure_writable`` which
        fulfils the copy-on-write (paper Table 1 row 'Copy-on-Write').
        Callers that only want fully-shared blocks pass shared_tokens
        rounded down to a block multiple.
        """
        bt = self.config.block_tokens
        nshared = -(-shared_tokens // bt)
        parent = self.tables[parent_id]
        if nshared > len(parent):
            raise ValueError(
                f"fork of {shared_tokens} tokens needs {nshared} blocks, "
                f"parent holds {len(parent)}")
        child = [self.allocator.share(b) for b in parent[:nshared]]
        self.tables[child_id] = child
        return child

    def ensure_writable(self, seq_id: int,
                        token_pos: int) -> Optional[Tuple[int, int]]:
        """COW write barrier for the block covering ``token_pos``.

        If that block is shared (refcount > 1) the sequence gets a fresh
        private block in its table and ``(src, dst)`` is returned -- the
        caller MUST copy the payload src -> dst on device (one
        ``block_copy`` DMA) before writing.  Returns None when the block
        is already exclusively owned.
        """
        tb = token_pos // self.config.block_tokens
        blk = self.tables[seq_id][tb]
        if self.allocator.refcount(blk) == 1:
            return None
        fresh, _ = self.allocator.fork_for_write(blk)
        self.tables[seq_id][tb] = fresh
        return blk, fresh

    # -- swapping ---------------------------------------------------------
    def swap_out(self, seq_id: int) -> List[int]:
        """Release a preempted sequence's device blocks; return their ids.

        Payload transfer is the caller's job (gather the returned ids
        BEFORE reusing the pool -- ``serve/swap.py`` does both in one
        motion).  Only the block COUNT is remembered here.
        """
        blocks = self.tables.pop(seq_id)
        self.allocator.free_many(blocks)
        self.swapped[seq_id] = len(blocks)
        return blocks

    def swap_in(self, seq_id: int) -> List[int]:
        """Reallocate (anywhere!) and return the new block ids to fill.

        The new physical blocks need not match the old ones -- block
        tables absorb the relocation, which is the paper's 'Relocation /
        Migration' row implemented in software.
        """
        new_ids = self.allocator.alloc_many(self.swapped.pop(seq_id))
        self.tables[seq_id] = new_ids
        return new_ids

    def device_table(self, seq_id: int) -> np.ndarray:
        t = np.full(self.config.max_blocks_per_seq, NULL_BLOCK, np.int32)
        blocks = self.tables[seq_id]
        t[: len(blocks)] = blocks
        return t

    @property
    def utilization(self) -> float:
        return self.allocator.num_used / self.allocator.num_blocks
