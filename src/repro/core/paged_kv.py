"""Paged KV cache: the paper's block-allocated memory applied to serving.

A decoding sequence's KV cache is the canonical "large, growing array"
that virtual memory used to make contiguous.  Here it is stored the
paper's way: fixed-size blocks of ``block_tokens`` tokens drawn from a
shared pool, addressed through a per-sequence **block table** (a depth-1
tree; ``TreeArray`` provides deeper tables when max_blocks_per_seq
exceeds one table block -- see ``block_table.py``).

Pools are stacked over layers (leading L axis) so the per-layer slice
threads through ``lax.scan`` over the model's layers.  One block id is
valid across all layers/heads -- the pool's trailing dims carry
(kv_heads, head_dim), which also gives the natural sharding:

    (L, num_blocks[data], block_tokens, kv_heads[model], head_dim)

Standard (k,v) pools and MLA latent pools (single compressed c_kv stream,
DeepSeek-V2/MiniCPM3) are both supported; MLA's latent blocks are ~4x
smaller per token -- the paper's "choose your own block quantum" argument
in action.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.mem import Arena, Mapping, NULL_BLOCK, OutOfBlocksError


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    num_layers: int
    kv_heads: int          # 0 for MLA latent mode
    head_dim: int          # per-head dim; for MLA: latent_dim = kv_lora + rope
    block_tokens: int = 64
    num_blocks: int = 1024
    max_blocks_per_seq: int = 16
    latent: bool = False   # MLA: single stream, no separate V pool
    # split-latent mode (latent TP): k_pool holds the kv_lora stream
    # (head_dim = kv_lora, shardable over 'model'), v_pool holds the
    # shared rope keys of width latent_rope.
    latent_rope: int = 0
    dtype: jnp.dtype = jnp.bfloat16
    # data-parallel pool groups: the pool's block dim is split into
    # dp_groups contiguous ranges co-sharded with the batch, and block
    # tables hold GROUP-LOCAL ids.  This makes every table gather/scatter
    # structurally local (a batched gather), so GSPMD never needs to move
    # pool blocks across the data axis.
    dp_groups: int = 1

    @property
    def max_seq_len(self) -> int:
        return self.max_blocks_per_seq * self.block_tokens

    def token_shape(self) -> Tuple[int, ...]:
        return (self.head_dim,) if self.latent else (self.kv_heads, self.head_dim)

    def pool_shape(self) -> Tuple[int, ...]:
        return (self.num_layers, self.num_blocks, self.block_tokens,
                *self.token_shape())

    def bytes_per_token_per_layer(self) -> int:
        streams = 1 if self.latent else 2
        per = int(np.prod(self.token_shape()))
        return streams * per * jnp.dtype(self.dtype).itemsize

    def swap_nbytes_per_block(self) -> int:
        """Device<->host bytes to move ONE block (all layers, all streams).

        This is the unit the serving swap path is held to: a preempted
        sequence holding n blocks moves exactly n * this many bytes --
        never a function of num_blocks (pool size).
        """
        per = int(np.prod(self.token_shape()))   # k (or latent) stream
        width = per if self.latent else 2 * per
        if self.latent and self.latent_rope:
            width += self.latent_rope            # shared rope-key stream
        return (self.num_layers * self.block_tokens * width
                * jnp.dtype(self.dtype).itemsize)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedKVCache:
    """Functional paged KV state threaded through decode steps."""

    k_pool: jax.Array            # (L, NB, BT, KVH, HD) or (L, NB, BT, LAT) for MLA
    v_pool: Optional[jax.Array]  # None in latent (MLA) mode
    block_tables: jax.Array      # (B, max_blocks_per_seq) int32
    seq_lens: jax.Array          # (B,) int32 -- tokens already cached
    config: PagedKVConfig = dataclasses.field(metadata=dict(static=True))

    def tree_flatten(self):
        return (self.k_pool, self.v_pool, self.block_tables, self.seq_lens), self.config

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch, aux)

    # -- constructors --------------------------------------------------
    @classmethod
    def create(cls, config: PagedKVConfig, batch: int) -> "PagedKVCache":
        k = jnp.zeros(config.pool_shape(), config.dtype)
        if config.latent:
            v = (jnp.zeros((*config.pool_shape()[:-1], config.latent_rope),
                           config.dtype) if config.latent_rope else None)
        else:
            v = jnp.zeros(config.pool_shape(), config.dtype)
        tables = jnp.full((batch, config.max_blocks_per_seq), NULL_BLOCK, jnp.int32)
        lens = jnp.zeros((batch,), jnp.int32)
        return cls(k, v, tables, lens, config)

    @classmethod
    def specs(cls, config: PagedKVConfig, batch: int) -> "PagedKVCache":
        """ShapeDtypeStruct stand-in for the dry-run (no allocation)."""
        sds = jax.ShapeDtypeStruct
        k = sds(config.pool_shape(), config.dtype)
        if config.latent:
            v = (sds((*config.pool_shape()[:-1], config.latent_rope),
                     config.dtype) if config.latent_rope else None)
        else:
            v = sds(config.pool_shape(), config.dtype)
        tables = sds((batch, config.max_blocks_per_seq), jnp.int32)
        lens = sds((batch,), jnp.int32)
        return cls(k, v, tables, lens, config)

    @property
    def batch(self) -> int:
        return self.block_tables.shape[0]

    # -- addressing ------------------------------------------------------
    def _addr(self, pos: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Logical position -> (physical block id per seq, offset)."""
        blk_no = pos // self.config.block_tokens
        off = pos % self.config.block_tokens
        b = jnp.arange(self.batch)
        phys = self.block_tables[b, blk_no]
        return phys, off

    # -- writes ---------------------------------------------------------
    def append_token(self, k_new: jax.Array,
                     v_new: Optional[jax.Array]) -> "PagedKVCache":
        """Write one new token's KV for ALL layers at position seq_lens.

        k_new: (L, B, KVH, HD) (or (L, B, LAT) latent).  Returns cache
        with seq_lens advanced by 1.
        """
        phys, off = self._addr(self.seq_lens)
        k_pool = self.k_pool.at[:, phys, off].set(
            k_new.astype(self.config.dtype))
        v_pool = self.v_pool
        if v_new is not None:
            v_pool = self.v_pool.at[:, phys, off].set(v_new.astype(self.config.dtype))
        return dataclasses.replace(
            self, k_pool=k_pool, v_pool=v_pool, seq_lens=self.seq_lens + 1)

    def write_layer_token(self, layer_kv, layer: jax.Array):
        """Per-layer single-token write, for use inside lax.scan bodies.

        layer_kv: (k (B,KVH,HD), v or None).  Positions taken from
        seq_lens (NOT advanced here -- call ``advance`` once per step).
        Returns updated per-layer pool slices to be re-stacked by scan.
        """
        raise NotImplementedError("use pool slices via scan xs; see models/")

    def _scatter_blocks(self, pool, tbl, payload):
        """pool (L, NB, BT, ...) .at[:, tbl].set(payload) with dp-group
        local block ids when dp_groups > 1 (see PagedKVConfig)."""
        dp = self.config.dp_groups
        if dp <= 1:
            return pool.at[:, tbl].set(payload)
        L, NB = pool.shape[:2]
        B = tbl.shape[0]
        pg = pool.reshape(L, dp, NB // dp, *pool.shape[2:])
        tg = tbl.reshape(dp, B // dp, tbl.shape[1])
        pay = payload.reshape(payload.shape[0], dp, B // dp,
                              *payload.shape[2:])
        out = jax.vmap(lambda pl, tb, pp: pl.at[:, tb].set(pp),
                       in_axes=(1, 0, 1), out_axes=1)(pg, tg, pay)
        return out.reshape(pool.shape)

    def write_prefill(self, k: jax.Array, v: Optional[jax.Array],
                      lengths: jax.Array) -> "PagedKVCache":
        """Bulk-write prompts.  k: (L, B, S, KVH, HD); positions 0..S-1.

        Tokens beyond ``lengths[b]`` are written too (harmless -- masked
        by seq_lens at read time), keeping the write dense/regular.
        """
        L, B, S = k.shape[:3]
        bt = self.config.block_tokens
        assert S % bt == 0, "prefill length must be block-aligned"
        nblk = S // bt
        tbl = jnp.maximum(self.block_tables[:, :nblk], 0)       # (B, nblk)
        kb = k.reshape(L, B, nblk, bt, *k.shape[3:]).astype(self.config.dtype)
        k_pool = self._scatter_blocks(self.k_pool, tbl, kb)
        v_pool = self.v_pool
        if v is not None:
            vb = v.reshape(L, B, nblk, bt, *v.shape[3:]).astype(self.config.dtype)
            v_pool = self._scatter_blocks(self.v_pool, tbl, vb)
        return dataclasses.replace(self, k_pool=k_pool, v_pool=v_pool,
                                   seq_lens=lengths.astype(jnp.int32))

    def advance(self, n: int = 1) -> "PagedKVCache":
        return dataclasses.replace(self, seq_lens=self.seq_lens + n)

    # -- reads ----------------------------------------------------------
    def gather_layer(self, layer_k: jax.Array, layer_v: Optional[jax.Array]):
        """Materialize (B, S_max, ...) views of one layer's pool slices.

        This is the *reference* read path (the Pallas paged_attention
        kernel streams blocks instead).  Invalid table entries are
        clipped; callers mask by seq_lens.
        """
        tbl = jnp.maximum(self.block_tables, 0)  # clip NULL
        k = layer_k[tbl]            # (B, nblk, BT, ...)
        k = k.reshape(k.shape[0], -1, *k.shape[3:])
        if layer_v is None:
            return k, None
        v = layer_v[tbl]
        v = v.reshape(v.shape[0], -1, *v.shape[3:])
        return k, v


class PagedKVManager:
    """Host-side allocator policy for the cache -- a thin Arena client.

    The manager used to own its own ``BlockAllocator`` and dict-of-lists
    tables; it is now a facade over ``repro.mem``: one ``Mapping`` per
    live sequence drawn from a shared ``Arena`` pool class, so the KV
    cache, TreeArrays, BlockStacks and the host swap tier all account
    against ONE address space.  The manager still deals ONLY in block
    ids at its boundary -- payload transfers (swap-out/in at block
    granularity, COW block copies) are the caller's job, so that bytes
    moved always scale with blocks held, never with pool size (see
    ``serve/swap.py`` and ``kernels/block_copy.py``).
    """

    def __init__(self, config: PagedKVConfig, arena: Optional[Arena] = None,
                 pool_class: str = "kv"):
        self.config = config
        self.arena = arena if arena is not None else Arena()
        self.pool_class = self.arena.register_class(
            pool_class, num_blocks=config.num_blocks,
            block_nbytes=config.swap_nbytes_per_block(),
            dp_groups=config.dp_groups)
        self._maps: dict[int, Mapping] = {}

    # -- compat views over the Arena -----------------------------------
    @property
    def allocator(self):
        """The pool class's raw allocator (legacy/test escape hatch)."""
        return self.arena.allocator(self.pool_class)

    @property
    def tables(self) -> dict:
        """seq_id -> block-id list of every DEVICE-resident sequence."""
        return {sid: m.block_ids() for sid, m in self._maps.items()
                if m.placement == "device"}

    @property
    def swapped(self) -> dict:
        """seq_id -> blocks held at swap-out (host-tier residency)."""
        return self.arena.host_counts(self.pool_class)

    def mapping(self, seq_id: int) -> Mapping:
        return self._maps[seq_id]

    def has_seq(self, seq_id: int) -> bool:
        """Device-resident? (O(1) -- prefer over the ``tables`` view,
        which materializes every live table on each access)."""
        m = self._maps.get(seq_id)
        return m is not None and m.placement == "device"

    def block_ids(self, seq_id: int) -> List[int]:
        return self._maps[seq_id].block_ids()

    # -- admission/extension ------------------------------------------
    def blocks_needed(self, tokens: int) -> int:
        bt = self.config.block_tokens
        return (tokens + bt - 1) // bt

    @property
    def free_blocks(self) -> int:
        """Leases currently grantable -- the scheduler's admission view."""
        return self.arena.num_free(self.pool_class)

    def can_admit(self, tokens: int) -> bool:
        return self.free_blocks >= self.blocks_needed(tokens)

    def admit(self, seq_id: int, tokens: int,
              tenant: str = "default") -> List[int]:
        need = self.blocks_needed(tokens)
        if need > self.free_blocks:
            # atomic: don't leave an empty mapping behind on failure
            raise OutOfBlocksError(
                f"requested {need} blocks, only {self.free_blocks} free")
        m = self.arena.mapping(self.pool_class, seq_id, tenant=tenant)
        self._maps[seq_id] = m
        return m.ensure_capacity(need)

    def extend(self, seq_id: int, new_total_tokens: int) -> List[int]:
        """Ensure capacity for new_total_tokens; returns newly added ids.

        Allocates under pressure: on exhaustion the Arena's reclaimer
        (LIFO preemption when serving) evicts victims; if the victim is
        this sequence itself, ``LeaseRevokedError`` propagates.
        """
        return self._maps[seq_id].ensure_capacity(
            self.blocks_needed(new_total_tokens))

    def release(self, seq_id: int) -> None:
        self._maps.pop(seq_id).free()

    def adopt(self, seq_id: int, mapping: Mapping) -> None:
        """Register an existing Arena mapping under this manager (the
        restart path: ``Arena.restore`` rebuilds host-resident mappings
        and the engine re-adopts them so preempted sequences resume)."""
        if mapping.pool_class != self.pool_class:
            raise ValueError(
                f"adopt of mapping in pool class {mapping.pool_class!r}; "
                f"this manager allocates in {self.pool_class!r}")
        if seq_id in self._maps:
            raise ValueError(f"sequence {seq_id} already tracked")
        self._maps[seq_id] = mapping

    def disown(self, seq_id: int) -> Mapping:
        """Stop tracking ``seq_id`` WITHOUT freeing its mapping -- the
        inverse of ``adopt``.  The disaggregation handoff: a prefill
        worker disowns the finished sequence so ``export_mapping`` can
        gather its blocks into a bundle and release them."""
        return self._maps.pop(seq_id)

    def reserve_sink(self):
        """Pin one block (never handed to a sequence).

        The engine points masked prefill-table entries at this 'sink'
        block so padded rows and COW-aliased prefixes have a harmless
        scatter target.  Returns the pinned ``Lease`` -- read
        ``lease.block`` for the current physical id (compaction may
        relocate it).
        """
        return self.arena.pin(self.pool_class, owner="sink")

    def reserve_block(self) -> int:
        """Legacy form of ``reserve_sink``: the pinned id as an int."""
        return self.reserve_sink().block

    # -- COW prefix sharing ---------------------------------------------
    def fork(self, parent_id: int, child_id: int, shared_tokens: int,
             tenant: Optional[str] = None) -> List[int]:
        """COW: child aliases EVERY parent block covering shared_tokens.

        A trailing partially-filled block is aliased too; the first
        divergent write into it goes through ``ensure_writable`` which
        fulfils the copy-on-write (paper Table 1 row 'Copy-on-Write').
        Callers that only want fully-shared blocks pass shared_tokens
        rounded down to a block multiple.
        """
        bt = self.config.block_tokens
        nshared = -(-shared_tokens // bt)
        parent = self._maps[parent_id]
        if nshared > len(parent):
            raise ValueError(
                f"fork of {shared_tokens} tokens needs {nshared} blocks, "
                f"parent holds {len(parent)}")
        child = parent.fork(child_id, nshared, tenant=tenant)
        self._maps[child_id] = child
        return child.block_ids()

    def ensure_writable(self, seq_id: int,
                        token_pos: int) -> Optional[Tuple[int, int]]:
        """COW write barrier for the block covering ``token_pos``.

        If that block is shared (refcount > 1) the sequence gets a fresh
        private block in its table and the fulfilment copy is ENQUEUED
        on the Arena's transfer plane (the fresh block stays in-flight
        until the engine dispatches the queue); ``(src, dst)`` is
        returned for copy-traffic accounting, None when the block is
        already exclusively owned.  The fresh block is a deferred claim
        allocated under pressure (see ``Mapping.ensure_writable``).
        """
        return self._maps[seq_id].ensure_writable(
            token_pos // self.config.block_tokens)

    # -- swapping ---------------------------------------------------------
    def swap_out(self, seq_id: int) -> List[int]:
        """Migrate a preempted sequence to the host tier; return the
        vacated device ids.

        The payload move is a d2h plan on the Arena's transfer plane:
        the vacated ids stay held until its gather is dispatched, and
        the host copy lands at the next fence (``serve/swap.py`` keeps
        the byte ledger).
        """
        return self._maps[seq_id].migrate("host")

    def swap_in(self, seq_id: int) -> List[int]:
        """Migrate back: reallocate (anywhere!) and return the new block
        ids, with the scatter of the saved payload enqueued as an h2d
        plan.

        The new physical blocks need not match the old ones -- block
        tables absorb the relocation, which is the paper's 'Relocation /
        Migration' row implemented in software.
        """
        return self._maps[seq_id].migrate("device")

    # -- speculative swap-in (prefetch) ---------------------------------
    def prefetch(self, seq_id: int) -> List[int]:
        """Speculatively swap a preempted sequence back in on the
        BACKGROUND h2d lane: fresh blocks are allocated and the scatter
        enqueued, but host residency and payload stay intact until
        ``commit_prefetch`` -- so the speculation costs nothing to
        cancel (``Mapping.prefetch``)."""
        return self._maps[seq_id].prefetch()

    def is_prefetched(self, seq_id: int) -> bool:
        m = self._maps.get(seq_id)
        return m is not None and m.prefetched

    def prefetched_ids(self) -> List[int]:
        """Sequences with an uncommitted speculative swap-in (the
        pressure path's cheapest reclaim victims)."""
        return [sid for sid, m in self._maps.items() if m.prefetched]

    def commit_prefetch(self, seq_id: int) -> Tuple[List[int], bool]:
        """Promote the speculation to the real resume; returns
        ``(new_ids, served_from_completed_prefetch)``."""
        return self._maps[seq_id].commit_prefetch()

    def cancel_prefetch(self, seq_id: int) -> None:
        """Withdraw the speculation (candidate evicted/freed or memory
        tightened): blocks release, host state stays resumable."""
        self._maps[seq_id].cancel_prefetch()

    @property
    def speculative_blocks(self) -> int:
        """Device blocks held by uncommitted prefetches.  Admission
        counts these as FREE (they cancel instantly under pressure), so
        scheduling decisions are identical with and without
        speculation."""
        return sum(m.spec_blocks for m in self._maps.values())

    def device_table(self, seq_id: int) -> np.ndarray:
        return self._maps[seq_id].packed_table(self.config.max_blocks_per_seq)

    @property
    def utilization(self) -> float:
        return (self.arena.num_used(self.pool_class)
                / self.arena.num_blocks(self.pool_class))
