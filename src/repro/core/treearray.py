"""Arrays-as-trees (Siebert-style), the paper's replacement for large
contiguous arrays, as a JAX pytree.

Layout (paper Fig. 1): data lives ONLY in fixed-size leaf blocks; interior
nodes are fixed-size blocks of ``int32`` child ids.  A tree of depth ``d``
has ``d - 1`` levels of interior nodes.  With the paper's 32 KB nodes a
depth-3 tree addresses ~536 GB; we keep depth static per TreeArray so that
all JAX control flow is trace-time (no dynamic tree walks in HLO).

Two access disciplines, mirroring the paper's Table 2:

  * **naive** -- every element access walks root -> leaf (depth memory
    gathers per element).
  * **iterator** -- the paper's software-PTW-cache: a cursor caches the
    current leaf id; the tree is re-walked only when crossing a leaf
    boundary.  In vectorized JAX form this becomes: resolve each *leaf*
    once, then stream ``leaf_size`` elements with pure pointer
    arithmetic.  (The Pallas ``tree_gather`` kernel implements the same
    schedule with scalar-prefetched tables driving DMA.)

Indices are int64-safe: leaf/node ids are int32 (the pool is < 2^31
blocks) but element indices may exceed 2^31 for long_500k-scale arrays,
so index math is done in int64 when needed.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.mem import Arena, BlockAllocator, NULL_BLOCK


def tree_depth_for(length: int, leaf_size: int, fanout: int) -> int:
    """Minimum depth covering ``length`` elements (paper footnote 1)."""
    if length <= leaf_size:
        return 1
    leaves = math.ceil(length / leaf_size)
    depth = 1
    cover = 1
    while cover < leaves:
        cover *= fanout
        depth += 1
    return depth


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TreeArray:
    """A 1-D array of ``length`` elements stored as a radix tree of blocks.

    Fields
    ------
    leaves : (num_leaf_blocks, leaf_size) data pool (only ``length``
        elements are meaningful).
    nodes  : list over interior levels, root first.  ``nodes[0]`` has
        shape (1, fanout); level ``l`` has shape (n_l, fanout) of int32
        child ids into level ``l+1`` (or into ``leaves`` for the last
        interior level).  Empty list when depth == 1.
    root_leaf : int32 scalar leaf id, used only when depth == 1.
    """

    leaves: jax.Array
    nodes: List[jax.Array]
    root_leaf: jax.Array
    length: int = dataclasses.field(metadata=dict(static=True))
    leaf_size: int = dataclasses.field(metadata=dict(static=True))
    fanout: int = dataclasses.field(metadata=dict(static=True))
    depth: int = dataclasses.field(metadata=dict(static=True))

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        children = (self.leaves, self.nodes, self.root_leaf)
        aux = (self.length, self.leaf_size, self.fanout, self.depth)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        leaves, nodes, root_leaf = children
        length, leaf_size, fanout, depth = aux
        return cls(leaves, nodes, root_leaf, length, leaf_size, fanout, depth)

    # -- constructors --------------------------------------------------
    @classmethod
    def from_dense(cls, x: jax.Array, leaf_size: int = 8192,
                   fanout: int = 8192,
                   allocator: Optional[BlockAllocator] = None,
                   arena: Optional[Arena] = None,
                   pool_class: str = "tree",
                   owner=None,
                   shuffle_seed: Optional[int] = None) -> "TreeArray":
        """Build a tree holding ``x`` (1-D).

        ``leaf_size`` is in *elements*; the paper's 32 KB block with f32
        data is leaf_size=8192 (and fanout 8192 for 4-byte ids).  If
        ``arena`` is given, leaf blocks are drawn from that pool class
        of the shared ``repro.mem.Arena`` through a radix ``Mapping``
        (so the tree coexists with every other block-backed tenant; the
        mapping is attached as ``tree.arena_mapping`` -- a host-side
        handle, NOT carried through jit -- and can be ``free()``d).  The
        legacy ``allocator`` argument draws raw ids instead.
        ``shuffle_seed`` permutes leaf placement to emulate a fragmented
        physical memory (the paper's whole point is that this must not
        matter).
        """
        x = jnp.asarray(x).reshape(-1)
        n = x.shape[0]
        depth = tree_depth_for(max(n, 1), leaf_size, fanout)
        num_leaves = max(1, math.ceil(n / leaf_size))

        mapping = None
        if arena is not None:
            if pool_class not in arena.pool_classes:
                raise KeyError(
                    f"register pool class {pool_class!r} on the arena "
                    f"before building trees from it")
            mapping = arena.mapping(pool_class,
                                    owner if owner is not None else "tree",
                                    kind="radix")
            leaf_ids = np.array(mapping.append_blocks(num_leaves),
                                dtype=np.int32)
            pool_blocks = arena.num_blocks(pool_class)
        elif allocator is not None:
            leaf_ids = np.array(allocator.alloc_many(num_leaves), dtype=np.int32)
            pool_blocks = allocator.num_blocks
        else:
            leaf_ids = np.arange(num_leaves, dtype=np.int32)
            pool_blocks = num_leaves
            if shuffle_seed is not None:
                rng = np.random.RandomState(shuffle_seed)
                leaf_ids = rng.permutation(pool_blocks)[:num_leaves].astype(np.int32)

        pad = num_leaves * leaf_size - n
        xp = jnp.pad(x, (0, pad))
        leaves = jnp.zeros((pool_blocks, leaf_size), x.dtype)
        leaves = leaves.at[jnp.asarray(leaf_ids)].set(
            xp.reshape(num_leaves, leaf_size))

        nodes: List[jax.Array] = []
        if depth == 1:
            root_leaf = jnp.asarray(leaf_ids[0], jnp.int32)
        else:
            root_leaf = jnp.asarray(NULL_BLOCK, jnp.int32)
            # Build interior levels bottom-up: ids of level l+1 grouped by
            # fanout form level l.
            child_ids = leaf_ids
            levels: List[np.ndarray] = []
            for _ in range(depth - 1):
                n_nodes = max(1, math.ceil(len(child_ids) / fanout))
                padded = np.full(n_nodes * fanout, NULL_BLOCK, dtype=np.int32)
                padded[: len(child_ids)] = child_ids
                level = padded.reshape(n_nodes, fanout)
                levels.append(level)
                child_ids = np.arange(n_nodes, dtype=np.int32)
            levels.reverse()  # root first
            assert levels[0].shape[0] == 1
            nodes = [jnp.asarray(l) for l in levels]

        tree = cls(leaves, nodes, root_leaf, n, leaf_size, fanout, depth)
        if mapping is not None:
            tree.arena_mapping = mapping
        return tree

    # -- address resolution ----------------------------------------------
    def _leaf_of(self, elem_idx: jax.Array) -> jax.Array:
        """Walk the tree: logical element index -> physical leaf id.

        This is the software page-table walk.  ``elem_idx`` may be any
        shape; the walk vectorizes.  Cost: ``depth - 1`` gathers.
        """
        idx = elem_idx.astype(jnp.int32) // self.leaf_size  # logical leaf no.
        if self.depth == 1:
            return jnp.broadcast_to(self.root_leaf, idx.shape)
        node = jnp.zeros(idx.shape, jnp.int32)  # root is node 0 of level 0
        for level in range(self.depth - 1):
            # stride of one child subtree at this level, in logical leaves
            stride = self.fanout ** (self.depth - 2 - level)
            digit = (idx // stride) % self.fanout
            table = self.nodes[level]
            node = table[node, digit.astype(jnp.int32)]
        return node  # leaf id

    # -- element access ----------------------------------------------------
    def get_naive(self, elem_idx: jax.Array) -> jax.Array:
        """Full tree walk per access (paper's 'Naive' rows)."""
        elem_idx = jnp.asarray(elem_idx)
        leaf = self._leaf_of(elem_idx)
        off = (elem_idx.astype(jnp.int32) % self.leaf_size).astype(jnp.int32)
        return self.leaves[leaf, off]

    def set(self, elem_idx: jax.Array, value: jax.Array) -> "TreeArray":
        elem_idx = jnp.asarray(elem_idx)
        leaf = self._leaf_of(elem_idx)
        off = (elem_idx.astype(jnp.int32) % self.leaf_size).astype(jnp.int32)
        return dataclasses.replace(
            self, leaves=self.leaves.at[leaf, off].set(value))

    def add(self, elem_idx: jax.Array, value: jax.Array) -> "TreeArray":
        """Scatter-add (GUPS update)."""
        elem_idx = jnp.asarray(elem_idx)
        leaf = self._leaf_of(elem_idx)
        off = (elem_idx.astype(jnp.int32) % self.leaf_size).astype(jnp.int32)
        return dataclasses.replace(
            self, leaves=self.leaves.at[leaf, off].add(value))

    # -- iterator discipline -------------------------------------------
    def leaf_table(self) -> jax.Array:
        """Resolve every logical leaf id once: (num_logical_leaves,) int32.

        This is the iterator optimization hoisted to its limit -- the
        flattened 'page table' that sequential/strided kernels stream
        through SMEM.  Cost: one tree walk per *leaf*, amortized over
        leaf_size elements.
        """
        num_leaves = max(1, math.ceil(self.length / self.leaf_size))
        first_elems = jnp.arange(num_leaves, dtype=jnp.int32) * self.leaf_size
        return self._leaf_of(first_elems)

    def to_dense(self) -> jax.Array:
        """Gather the logical array (iterator-ordered full scan)."""
        table = self.leaf_table()
        blocks = self.leaves[table]  # (num_leaves, leaf_size)
        return blocks.reshape(-1)[: self.length]

    def scan_sum_iter(self) -> jax.Array:
        """Linear scan (sum) with the iterator discipline: one walk per
        leaf, then streaming reads.  Mirrors paper Table 2 'Linear Scan:
        Iter'."""
        table = self.leaf_table()
        num_leaves = table.shape[0]

        def body(carry, leaf_id):
            blk = self.leaves[leaf_id]
            return carry + jnp.sum(blk, dtype=jnp.float64 if
                                   self.leaves.dtype == jnp.float64 else
                                   jnp.float32), None

        # zero out tail padding once (cheap): mask final partial leaf
        tail = self.length - (num_leaves - 1) * self.leaf_size
        if tail == self.leaf_size:
            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), table)
        else:
            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                    table[:-1])
            last = self.leaves[table[-1]]
            mask = jnp.arange(self.leaf_size) < tail
            total = total + jnp.sum(jnp.where(mask, last, 0), dtype=jnp.float32)
        return total

    def scan_sum_naive(self) -> jax.Array:
        """Linear scan (sum) with a full tree walk per element (paper
        Table 2 'Linear Scan: Naive').  Implemented as a fori_loop so the
        per-element walk is really sequential in the HLO."""

        def body(i, acc):
            return acc + self.get_naive(i).astype(jnp.float32)

        return jax.lax.fori_loop(0, self.length, body, jnp.zeros((), jnp.float32))

    def gather_iter(self, elem_idx: jax.Array) -> jax.Array:
        """Vectorized random gather: the 'accelerated tree traversal' the
        paper suggests in §4.4 -- resolves leaves in bulk (one vector walk)
        instead of per element.  Same result as get_naive."""
        return self.get_naive(elem_idx)  # vector walk is already bulk

    # -- stats --------------------------------------------------------
    @property
    def num_logical_leaves(self) -> int:
        return max(1, math.ceil(self.length / self.leaf_size))

    @property
    def overhead_bytes(self) -> int:
        return sum(int(np.prod(n.shape)) * 4 for n in self.nodes)
