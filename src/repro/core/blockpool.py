"""Compatibility shim: the block allocator now lives in ``repro.mem``.

The unified software address-space subsystem (`repro.mem`) owns the
physical-memory layer -- ``BlockAllocator`` (free list + refcounts) and
``BlockPool`` (device arena of fixed blocks).  Every block-backed client
(PagedKVManager, TreeArray, BlockStack, HostBlockStore) allocates
through one shared ``repro.mem.Arena``; this module only re-exports the
names so existing imports keep working.  Do NOT construct
``BlockAllocator``/``BlockPool`` directly outside ``repro.mem`` -- the
grep-enforced test ``tests/test_mem_api.py`` pins that rule.
"""

from repro.mem.blockpool import (NULL_BLOCK, BlockAllocator, BlockPool,
                                 OutOfBlocksError)

__all__ = ["BlockAllocator", "BlockPool", "NULL_BLOCK", "OutOfBlocksError"]
