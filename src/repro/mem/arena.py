"""Arena: ONE software address space behind every block-backed subsystem.

The paper's central bet is that a single, simple software memory manager
-- fixed blocks, id-based page tables, no contiguity promises -- can
serve every client an OS with virtual memory would.  This class is that
manager as one artifact: the paged KV cache, ``TreeArray``,
``BlockStack`` and the serving host store all allocate here, so an
experiment can measure "the allocator" instead of five re-implementations
of it.

Shape of the API:

  * one **pool class** per (block_shape, dtype) family -- the paper's
    "choose your own block quantum" argument: KV blocks, tree leaves and
    host-side metadata blocks coexist as separately sized classes of the
    same address space, each backed by a ``BlockAllocator``;
  * the **host swap tier** is a first-class second placement level, not
    a side table: a ``Mapping`` migrated to host keeps its identity (and
    its payload, deposited by the transfer layer) and re-materializes on
    any free device blocks later;
  * clients hold typed ``Lease`` handles and ``Mapping`` tables, never
    raw ints, so compaction can relocate physical blocks without any
    client seeing a stale id;
  * allocation **under pressure** consults a registered *reclaimer*
    (the serving engine's LIFO preemption) instead of failing -- the COW
    barrier and growth fallback that used to live inline in
    ``serve/engine.py`` are Arena policy now, and the scheduler
    negotiates admission against ``free_blocks`` of this one arena;
  * ``compact()`` is the ROADMAP's defrag pass: when free blocks are
    plentiful but table locality has degraded, it emits a
    relocation plan moving live blocks to a dense prefix and
    rewrites every lease in place (paper Table 1 row 'Relocation /
    Migration': tables absorb the move, no client pointer updates);
  * every payload move -- migrate, swap, COW fulfilment, compaction --
    is a **plan on the arena's ``TransferQueue``** (``mem/transfer.py``):
    enqueue now, dispatch/fence when the consumer schedules it.  The
    queue holds vacated DMA sources in the allocator and flags copy
    targets ``in_flight`` until fenced, so the discipline is provable
    (``assert_quiescent`` requires an empty queue);
  * ``snapshot()/restore()`` checkpoint the host tier (payloads +
    residency) and mappings so a serving process restarts with its swap
    state intact.
"""

from __future__ import annotations

import collections
import json
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.mem.blockpool import BlockAllocator, OutOfBlocksError
from repro.mem.lease import Lease
from repro.mem.mapping import DEVICE, FLAT, HOST, Mapping
from repro.mem.stats import ArenaStats, PoolClassStats
from repro.mem.transfer import QueueSet

#: reclaimer signature: called with the requesting owner when a pool
#: class is exhausted; must free blocks (e.g. preempt a victim) and
#: return the reclaimed owner, or None when nothing can be reclaimed.
Reclaimer = Callable[[object], Optional[object]]


class LeaseRevokedError(OutOfBlocksError):
    """Pressure reclaim chose the requester itself: its blocks were
    migrated out mid-request, so the allocation is moot.  Subclasses
    ``OutOfBlocksError`` so legacy callers that catch the base class
    keep working."""


class _PoolClass:
    """Internal per-(block_shape, dtype) state."""

    __slots__ = ("name", "num_blocks", "block_shape", "dtype",
                 "block_nbytes", "allocator", "leases", "pinned",
                 "mappings", "dp_groups", "quota_by_tenant")

    def __init__(self, name: str, num_blocks: int, block_shape: Tuple,
                 dtype, block_nbytes: int, dp_groups: int = 1):
        self.name = name
        self.num_blocks = num_blocks
        self.block_shape = block_shape
        self.dtype = dtype
        self.block_nbytes = block_nbytes
        self.dp_groups = dp_groups
        self.allocator = BlockAllocator(num_blocks)
        self.leases: Dict[int, List[Lease]] = {}
        self.pinned: List[Lease] = []
        self.mappings: List[Mapping] = []
        #: per-tenant block ceilings enforced at ADMISSION (scheduler
        #: policy), not at allocation -- an admitted sequence may always
        #: grow to the footprint it was admitted under
        self.quota_by_tenant: Dict[str, int] = {}

    def group_range(self, g: int) -> Tuple[int, int]:
        """Contiguous id range of dp pool group ``g`` (co-sharded with
        the pool's block dim -- see ``PagedKVConfig.dp_groups``)."""
        per = self.num_blocks // self.dp_groups
        lo = g * per
        hi = (g + 1) * per if g < self.dp_groups - 1 else self.num_blocks
        return lo, hi


class Arena:
    """The unified software address space (see module docstring)."""

    def __init__(self):
        self._classes: Dict[str, _PoolClass] = {}
        self._reclaimer: Optional[Reclaimer] = None
        # per-pool-class reclaimers (heterogeneous serving: each
        # engine handles pressure for ITS classes); the global
        # reclaimer stays the single-engine default.
        self._reclaimers: Dict[str, Reclaimer] = {}
        # host tier: residency counts (owned by Mapping.migrate) and
        # payloads (deposited/taken by the transfer plane) are separate
        # so migrate("device") can reallocate ids before the scatter.
        self._host_counts: Dict[Tuple[str, object], int] = {}
        self._host_payload: Dict[Tuple[str, object], Tuple[object, int]] = {}
        #: the asynchronous transfer plane: every payload move (swap,
        #: COW copy, compaction, migrate) is a plan enqueued here --
        #: one TransferEngine per direction behind a QueueSet front-end.
        self.transfers = QueueSet(self)
        self.compactions = 0
        self.blocks_compacted = 0

    # ---------------- pool classes ----------------
    def register_class(self, name: str, *, num_blocks: int,
                       block_shape: Tuple = (), dtype=jnp.float32,
                       block_nbytes: Optional[int] = None,
                       dp_groups: int = 1,
                       quota_by_tenant: Optional[Dict[str, int]] = None
                       ) -> str:
        """Declare (or re-attach to) one (block_shape, dtype) pool class.

        Registration is idempotent for an identical spec -- many clients
        of one engine attach to the same class -- and loud on conflict.
        ``dp_groups`` partitions the id space into contiguous ranges for
        per-group accounting (``ArenaStats`` reports blocks held/free
        per group).  ``quota_by_tenant`` sets per-tenant block ceilings
        enforced at admission time; it is operator-updatable metadata,
        not part of the conflict-checked spec (re-registering with a new
        quota replaces it).  Returns ``name`` so callers can chain.
        """
        if block_nbytes is None:
            block_nbytes = (int(np.prod(block_shape)) if block_shape else 1
                            ) * jnp.dtype(dtype).itemsize
        if name in self._classes:
            st = self._classes[name]
            if (st.num_blocks != num_blocks
                    or st.block_nbytes != block_nbytes
                    or st.block_shape != tuple(block_shape)
                    or st.dtype != dtype
                    or st.dp_groups != dp_groups):
                raise ValueError(
                    f"pool class {name!r} re-registered with a different "
                    f"spec: {num_blocks}x{block_nbytes}B "
                    f"{tuple(block_shape)}/{dtype}/g{dp_groups} vs existing "
                    f"{st.num_blocks}x{st.block_nbytes}B "
                    f"{st.block_shape}/{st.dtype}/g{st.dp_groups}")
            if quota_by_tenant is not None:
                st.quota_by_tenant = dict(quota_by_tenant)
            return name
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        if dp_groups < 1 or dp_groups > num_blocks:
            raise ValueError(f"dp_groups must be in [1, num_blocks], "
                             f"got {dp_groups}")
        st = _PoolClass(name, num_blocks, tuple(block_shape),
                        dtype, int(block_nbytes), int(dp_groups))
        if quota_by_tenant is not None:
            st.quota_by_tenant = dict(quota_by_tenant)
        self._classes[name] = st
        return name

    def _cls(self, name: str) -> _PoolClass:
        try:
            return self._classes[name]
        except KeyError:
            raise KeyError(f"unregistered pool class {name!r}; call "
                           f"Arena.register_class first") from None

    @property
    def pool_classes(self) -> List[str]:
        return list(self._classes)

    # ---------------- queries ----------------
    def num_blocks(self, cls: str) -> int:
        return self._cls(cls).num_blocks

    def num_free(self, cls: str) -> int:
        return self._cls(cls).allocator.num_free

    def num_used(self, cls: str) -> int:
        return self._cls(cls).allocator.num_used

    def refcount(self, cls: str, block: int) -> int:
        return self._cls(cls).allocator.refcount(block)

    def block_nbytes(self, cls: str) -> int:
        return self._cls(cls).block_nbytes

    def tenant_quota(self, cls: str, tenant: str) -> Optional[int]:
        """The tenant's block ceiling in ``cls`` (None = unlimited)."""
        return self._cls(cls).quota_by_tenant.get(str(tenant))

    def blocks_by_tenant(self, cls: str) -> Dict[str, int]:
        """Blocks currently charged to each tenant in ``cls``: device
        leases plus host-tier residency of every tenant-tagged mapping.
        Untagged allocations (pinned sinks, raw leases) are unbilled."""
        out: collections.Counter = collections.Counter()
        for m in self._cls(cls).mappings:
            if m.placement == HOST:
                out[str(m.tenant)] += int(m._host_blocks)
            else:
                out[str(m.tenant)] += len(m.leases)
        return dict(out)

    def find_mapping(self, cls: str, owner) -> Optional[Mapping]:
        """The live mapping of ``owner`` in ``cls``, if any (used by the
        engine to adopt restored host-resident mappings)."""
        for m in self._cls(cls).mappings:
            if m.owner == owner:
                return m
        return None

    def allocator(self, cls: str) -> BlockAllocator:
        """The raw allocator -- a compat escape hatch for tests that poke
        free-list state.  Blocks allocated here bypass the lease registry
        and make the class ineligible for ``compact()``."""
        return self._cls(cls).allocator

    # ---------------- pressure protocol ----------------
    def set_reclaimer(self, fn: Optional[Reclaimer],
                      pool_class: Optional[str] = None) -> None:
        """Register the pressure-time reclaim callback.

        With ``pool_class`` the reclaimer handles exhaustion of THAT
        class only -- the heterogeneous-serving shape, where each engine
        owns pressure for its own pool classes and many engines share
        one address space.  Without it, the callback is the arena-wide
        default (single-engine shape).  Either way exactly one reclaimer
        per scope: silently displacing an earlier registrant would
        reroute its pressure handling, so that conflict is loud.  Pass
        None to clear before handing the scope to a new owner.
        """
        if pool_class is not None:
            prev = self._reclaimers.get(pool_class)
            if fn is not None and prev is not None and prev is not fn:
                raise ValueError(
                    f"pool class {pool_class!r} already has a reclaimer "
                    f"registered; call set_reclaimer(None, "
                    f"pool_class={pool_class!r}) first")
            if fn is None:
                self._reclaimers.pop(pool_class, None)
            else:
                self._reclaimers[pool_class] = fn
            return
        if (fn is not None and self._reclaimer is not None
                and self._reclaimer is not fn):
            raise ValueError(
                "arena already has a reclaimer registered; call "
                "set_reclaimer(None) first to transfer ownership")
        self._reclaimer = fn

    def _alloc_ids(self, cls: str, n: int, *, pressure: bool,
                   requester) -> List[int]:
        """Atomically allocate ``n`` ids, reclaiming under pressure.

        This loop is the LIFO-preemption fallback that used to live in
        ``serve/engine.py``: on exhaustion the reclaimer evicts victims
        (newest admission first) until the request fits -- or until the
        requester itself is the victim, which surfaces as
        ``LeaseRevokedError`` (the requester's blocks are already on the
        host tier; the allocation is moot, not failed).
        """
        st = self._cls(cls)
        reclaimer = self._reclaimers.get(cls, self._reclaimer)
        while True:
            if st.allocator.num_free >= n:
                return [st.allocator.alloc() for _ in range(n)]
            if self.transfers.has_undispatched:
                # undispatched plans hold vacated blocks; DISPATCH
                # releases the holds without blocking on host copies
                # (those stay overlapped), so pressure-path allocation
                # never degenerates to the synchronous schedule
                self.transfers.dispatch()
                continue
            if not pressure or reclaimer is None:
                raise OutOfBlocksError(
                    f"pool class {cls!r}: requested {n} blocks, "
                    f"only {st.allocator.num_free} free")
            victim = reclaimer(requester)
            if victim is None:
                raise OutOfBlocksError(
                    f"pool class {cls!r}: exhausted and nothing left "
                    f"to reclaim")
            if victim == requester:
                raise LeaseRevokedError(
                    f"pool class {cls!r}: owner {requester!r} was "
                    f"reclaimed to satisfy its own request")

    # ---------------- leases ----------------
    def lease_blocks(self, cls: str, owner, n: int = 1, *,
                     pressure: bool = False,
                     requester=None) -> List[Lease]:
        """Allocate ``n`` exclusive leases for ``owner``."""
        ids = self._alloc_ids(cls, n, pressure=pressure,
                              requester=owner if requester is None
                              else requester)
        st = self._cls(cls)
        out = []
        for b in ids:
            lease = Lease(self, cls, b, owner)
            st.leases.setdefault(b, []).append(lease)
            out.append(lease)
        return out

    def share(self, lease: Lease, owner) -> Lease:
        """COW-alias: a new lease on the same block (refcount++)."""
        if not lease.live:
            raise ValueError("share of a released lease")
        if lease.pinned:
            raise ValueError("pinned blocks cannot be shared")
        st = self._cls(lease.pool_class)
        st.allocator.share(lease.block)
        new = Lease(self, lease.pool_class, lease.block, owner)
        st.leases[lease.block].append(new)
        return new

    def release(self, lease: Lease) -> None:
        if not lease.live:
            raise ValueError(f"double release of {lease!r}")
        lease.live = False
        st = self._cls(lease.pool_class)
        holders = st.leases[lease.block]
        holders.remove(lease)
        if not holders:
            del st.leases[lease.block]
        st.allocator.free(lease.block)

    def pin(self, cls: str, owner="pinned") -> Lease:
        """Permanently claim one block (e.g. the engine's write sink:
        masked table entries scatter here instead of into live blocks).
        Pinned blocks survive ``assert_quiescent`` and may still be
        relocated by ``compact()`` -- holders read ``lease.block``."""
        [lease] = self.lease_blocks(cls, owner)
        lease.pinned = True
        self._cls(cls).pinned.append(lease)
        return lease

    def unpin(self, lease: Lease) -> None:
        self._cls(lease.pool_class).pinned.remove(lease)
        lease.pinned = False
        self.release(lease)

    # ---------------- mappings ----------------
    def mapping(self, cls: str, owner, kind: str = FLAT,
                tenant: str = "default") -> Mapping:
        m = Mapping(self, cls, owner, kind=kind, tenant=tenant)
        self._cls(cls).mappings.append(m)
        return m

    def _forget_mapping(self, m: Mapping) -> None:
        self._cls(m.pool_class).mappings.remove(m)

    # ---------------- host swap tier ----------------
    def _host_register(self, cls: str, owner, nblocks: int) -> None:
        key = (cls, owner)
        if key in self._host_counts:
            raise ValueError(f"{owner!r} already host-resident in {cls!r}")
        self._host_counts[key] = nblocks

    def _host_unregister(self, cls: str, owner) -> int:
        return self._host_counts.pop((cls, owner))

    def host_deposit(self, cls: str, owner, payload, nbytes: int) -> None:
        """Attach a migrated mapping's payload (one compact gathered
        array per stream -- see ``serve/swap.py``)."""
        self._host_payload[(cls, owner)] = (payload, int(nbytes))

    def host_take(self, cls: str, owner):
        payload, _ = self._host_payload.pop((cls, owner))
        return payload

    def host_peek(self, cls: str, owner):
        """Read a payload WITHOUT consuming it -- the speculative
        swap-in path: a prefetch scatters the payload to device but the
        host copy stays authoritative until ``commit_prefetch`` (so a
        cancelled prefetch costs nothing to undo)."""
        payload, _ = self._host_payload[(cls, owner)]
        return payload

    def host_discard(self, cls: str, owner) -> None:
        self._host_payload.pop((cls, owner), None)

    def host_contains(self, cls: str, owner) -> bool:
        return (cls, owner) in self._host_payload

    def host_len(self, cls: str) -> int:
        return sum(1 for (c, _) in self._host_payload if c == cls)

    def host_counts(self, cls: str) -> Dict[object, int]:
        return {o: n for (c, o), n in self._host_counts.items() if c == cls}

    # ---------------- fragmentation / compaction ----------------
    def fragmentation(self, cls: str) -> float:
        """1 - used/span over the id space; 0.0 = dense prefix.

        With fixed blocks there is no *external* fragmentation (the
        paper's point) -- this measures how far live blocks have
        scattered from the dense prefix, which is what degrades
        table-gather locality and what ``compact()`` restores.
        """
        st = self._cls(cls)
        used = st.allocator.num_used
        if used == 0:
            return 0.0
        span = int(st.allocator.used_ids().max()) + 1
        return 1.0 - used / span

    def table_locality(self, cls: str) -> float:
        """Mean ``Mapping.locality()`` over device-resident mappings."""
        vals = [m.locality() for m in self._cls(cls).mappings
                if m.placement == DEVICE and len(m.leases) >= 2]
        return float(np.mean(vals)) if vals else 1.0

    def should_compact(self, cls: str, *, min_free_frac: float = 0.25,
                       frag_threshold: float = 0.25) -> bool:
        """Defrag policy: free blocks are plentiful (the copy plan is
        cheap and nothing is starving) but locality has degraded."""
        st = self._cls(cls)
        if st.allocator.num_free < min_free_frac * st.num_blocks:
            return False
        return self.fragmentation(cls) > frag_threshold

    def compact(self, cls: str) -> Tuple[np.ndarray, np.ndarray]:
        """Move live blocks to the dense prefix; the (src, dst) copy
        plan is ENQUEUED on the arena's ``TransferQueue`` (the moved
        leases stay ``in_flight`` and the vacated sources HELD until the
        consumer dispatches it) and also returned for accounting.

        Compaction is a fence point: pending transfers are drained first
        so the relocation plan sees settled block contents and no held
        ids.  Every lease is rewritten in place (tables built afterwards
        see only new ids) and the allocator's free list is rebuilt.
        Refuses to run when any live block is not lease-tracked
        (raw-allocator escape hatch in use) -- relocating a block
        nobody's table names would lose data silently.
        """
        self.transfers.drain()
        st = self._cls(cls)
        live = [int(b) for b in st.allocator.used_ids()]
        untracked = [b for b in live if b not in st.leases]
        if untracked:
            raise RuntimeError(
                f"cannot compact {cls!r}: blocks {untracked} were "
                f"allocated outside the lease registry")
        from repro.core.block_table import compaction_plan
        plan = compaction_plan(live)
        if not plan:
            return np.zeros(0, np.int32), np.zeros(0, np.int32)
        st.allocator.relocate(plan)
        for s, d in plan:
            moved = st.leases.pop(s)
            for lease in moved:
                lease.block = d
            st.leases[d] = moved
        self.compactions += 1
        self.blocks_compacted += len(plan)
        src = np.asarray([s for s, _ in plan], np.int32)
        dst = np.asarray([d for _, d in plan], np.int32)
        self.transfers.enqueue_copy(cls, src, dst, kind="compact")
        return src, dst

    # ---------------- stats / invariants ----------------
    def stats(self) -> ArenaStats:
        classes = {}
        for name, st in self._classes.items():
            by_owner: collections.Counter = collections.Counter()
            in_flight = 0
            for holders in st.leases.values():
                for lease in holders:
                    by_owner[str(lease.owner)] += 1
                    in_flight += int(lease.in_flight)
            host = {str(o): n for (c, o), n in self._host_counts.items()
                    if c == name}
            kinds: collections.Counter = collections.Counter(
                m.kind for m in st.mappings)
            groups = []
            if st.dp_groups > 1:
                used = set(int(b) for b in st.allocator.used_ids())
                # transfer-plane-held blocks are not allocatable: count
                # them out of 'free' so per-group headroom sums to the
                # class-level num_free even mid-flight
                held_ids = st.allocator.held_ids()
                for g in range(st.dp_groups):
                    lo, hi = st.group_range(g)
                    u = sum(1 for b in used if lo <= b < hi)
                    h = sum(1 for b in held_ids if lo <= b < hi)
                    groups.append({"group": g, "used": u,
                                   "free": (hi - lo) - u - h})
            classes[name] = PoolClassStats(
                name=name,
                num_blocks=st.num_blocks,
                num_free=st.allocator.num_free,
                num_used=st.allocator.num_used,
                pinned=len(st.pinned),
                blocks_by_owner=dict(by_owner),
                host_blocks_by_owner=host,
                refcount_histogram=[int(x) for x in
                                    st.allocator.refcount_histogram()],
                fragmentation=round(self.fragmentation(name), 4),
                table_locality=round(self.table_locality(name), 4),
                mappings_by_kind=dict(kinds),
                in_flight=in_flight,
                held=st.allocator.num_held,
                held_by_engine=st.allocator.held_by_engine(),
                groups=groups,
                quota_by_tenant=dict(st.quota_by_tenant),
                blocks_by_tenant=self.blocks_by_tenant(name),
            )
        return ArenaStats(classes=classes, compactions=self.compactions,
                          blocks_compacted=self.blocks_compacted,
                          transfers=self.transfers.stats.to_dict())

    def check_registry(self, cls: str) -> None:
        """Invariant: every allocated block's refcount equals its lease
        count (no bookkeeping drift between allocator and handles)."""
        st = self._cls(cls)
        for b in st.allocator.used_ids():
            b = int(b)
            n = len(st.leases.get(b, []))
            assert n == st.allocator.refcount(b), (
                f"pool class {cls!r} block {b}: {n} leases vs refcount "
                f"{st.allocator.refcount(b)}")

    def assert_quiescent(self) -> None:
        """Leak invariant: nothing but pinned blocks is allocated, the
        host tier is empty, and the transfer plane is fenced (no pending
        plans, no held blocks).  Every engine test ends on this."""
        assert self.transfers.pending == 0, (
            f"unfenced transfers at quiescence: "
            f"{self.transfers.pending_by_direction()}")
        for name, st in self._classes.items():
            assert st.allocator.num_held == 0, (
                f"pool class {name!r}: {st.allocator.num_held} blocks "
                f"still held by the transfer plane")
            pinned_ids = {l.block for l in st.pinned}
            for b in st.allocator.used_ids():
                b = int(b)
                assert b in pinned_ids, (
                    f"leak in pool class {name!r}: block {b} "
                    f"(refcount {st.allocator.refcount(b)}, leases "
                    f"{st.leases.get(b)}) still allocated")
                assert st.allocator.refcount(b) == 1, (
                    f"pinned block {b} of {name!r} has refcount "
                    f"{st.allocator.refcount(b)} != 1")
            hist = st.allocator.refcount_histogram()
            assert int(hist[1:].sum()) == len(pinned_ids), (
                f"refcount histogram of {name!r} not all-zeros beyond "
                f"pinned: {hist.tolist()}")
        assert not self._host_counts, (
            f"host tier residency leaked: {self._host_counts}")
        assert not self._host_payload, (
            f"host tier payload leaked: {list(self._host_payload)}")

    def check_consistency(self) -> None:
        """Cross-layer invariants over the device registry AND the host
        tier -- the post-``restore()`` health check (every allocated
        block's refcount equals its lease count, the lease registry's
        total mass matches the refcount histogram, host-resident
        mappings agree with registered residency, and landed payloads
        cover exactly the blocks they claim).  Cheap enough to run after
        every snapshot/restore roundtrip; raises ``AssertionError`` on
        the first drifted counter."""
        for name, st in self._classes.items():
            self.check_registry(name)
            total_leases = sum(len(v) for v in st.leases.values())
            hist = st.allocator.refcount_histogram()
            mass = int(sum(r * int(c) for r, c in enumerate(hist)))
            assert total_leases == mass, (
                f"pool class {name!r}: {total_leases} leases vs refcount "
                f"mass {mass}")
            for m in st.mappings:
                if m.placement != HOST:
                    continue
                key = (name, m.owner)
                assert self._host_counts.get(key) == m._host_blocks, (
                    f"host mapping {m.owner!r} in {name!r}: "
                    f"{m._host_blocks} blocks vs registered "
                    f"{self._host_counts.get(key)}")
        for (cls, owner), n in self._host_counts.items():
            entry = self._host_payload.get((cls, owner))
            if entry is None:
                # residency without a landed payload is only legal while
                # the swap-out is still in transit on the d2h queue (or
                # for metadata-only classes, which never carry payloads)
                assert (not self.transfers.has_executor(cls)
                        or owner in self.transfers.in_transit(cls)), (
                    f"host residency of {owner!r} in {cls!r} has no "
                    f"payload and no in-transit swap-out")
                continue
            if self.transfers.has_executor(cls):
                layered = self.transfers.is_layered(cls)
                for s in entry[0]:
                    if s is None:
                        continue
                    saved = s.shape[1] if layered else s.shape[0]
                    assert saved == n, (
                        f"host payload of {owner!r} in {cls!r} covers "
                        f"{saved} blocks, residency says {n}")

    # ---------------- checkpoint (host tier + mappings) ----------------
    @staticmethod
    def _tag_owner(owner) -> str:
        if isinstance(owner, (bool, float)):
            raise TypeError(f"unsupported owner type for snapshot: "
                            f"{type(owner).__name__}")
        if isinstance(owner, (int, np.integer)):
            return f"i:{int(owner)}"
        if isinstance(owner, str):
            return f"s:{owner}"
        raise TypeError(f"unsupported owner type for snapshot: "
                        f"{type(owner).__name__}")

    @staticmethod
    def _untag_owner(tag: str):
        kind, _, val = tag.partition(":")
        return int(val) if kind == "i" else val

    @staticmethod
    def _np_dtype(name: str):
        try:
            return np.dtype(name)
        except TypeError:
            # extension dtypes (bfloat16) resolve through jax
            return np.dtype(getattr(jnp, name))

    def gather_device_payload(self, cls: str, *, lane=None,
                              kind: str = "migrate-out"):
        """Gather ALL live mapped device blocks of ``cls`` to the host in
        one transfer-plane pass; returns ``(ids, streams, gens)`` or None
        when the class has no device-resident mapping (or no executor --
        metadata-only classes carry no payload).

        The gather is a pure read: live blocks (refcount > 0) take no
        allocator holds, so decode can keep running against them -- the
        building block of both one-shot device snapshots and the
        migration pre-copy rounds (which pass ``lane=BACKGROUND``).
        """
        from repro.mem.transfer import URGENT
        st = self._cls(cls)
        if not self.transfers.has_executor(cls):
            return None
        ids: List[int] = []
        seen = set()
        for m in st.mappings:
            if m.placement != DEVICE:
                continue
            for b in m.block_ids():
                if b not in seen:
                    seen.add(b)
                    ids.append(b)
        if not ids:
            return None
        gens = [st.allocator.write_gen(b) for b in ids]
        owner = f"__snapshot__/{cls}"
        self.transfers.enqueue_swap_out(
            cls, owner, ids, kind=kind,
            lane=URGENT if lane is None else lane)
        self.transfers.drain()
        streams = self.host_take(cls, owner)
        return ids, streams, gens

    def snapshot(self, path: str, *, include_device: bool = False,
                 device_payloads: Optional[Dict[str, tuple]] = None
                 ) -> None:
        """Checkpoint the arena's survivable state to one ``.npz``:
        pool-class specs, host-tier residency + payloads (the swapped
        sequences' KV), and every mapping's table.

        The transfer plane is drained first (in-flight payloads land).
        By default device pool CONTENTS are not captured -- a restart
        loses device memory by definition; the swap tier is exactly the
        state that survives.  ``include_device=True`` is the migration
        path: every executor-backed class's live mapped blocks are
        gathered through the transfer plane and stored alongside the
        mapping tables, preserving COW aliasing exactly (restore
        re-leases one physical block per distinct saved id and re-shares
        it across every mapping that named it).  ``device_payloads``
        lets a ``MigrationSession`` hand over pre-copied payloads
        (``{cls: (ids, streams, gens)}``) so the stop-and-copy pause
        only re-gathers the dirty tail, not the whole pool.
        """
        self.transfers.drain()
        device: Dict[str, tuple] = dict(device_payloads or {})
        if include_device:
            for name in self._classes:
                if name not in device:
                    got = self.gather_device_payload(name)
                    if got is not None:
                        device[name] = got
        # host-tier residency is NOT serialized separately: each
        # host-resident mapping entry carries its block count, and
        # restore() rebuilds _host_counts from those -- one source of
        # truth in the checkpoint.
        meta: dict = {"classes": {}, "mappings": [], "payloads": [],
                      "device": {}}
        arrays: Dict[str, np.ndarray] = {}
        for name, st in self._classes.items():
            meta["classes"][name] = {
                "num_blocks": st.num_blocks,
                "block_nbytes": st.block_nbytes,
                "block_shape": list(st.block_shape),
                "dtype": str(jnp.dtype(st.dtype)),
                "dp_groups": st.dp_groups,
            }
        for name, st in self._classes.items():
            for m in st.mappings:
                meta["mappings"].append({
                    "cls": name, "owner": self._tag_owner(m.owner),
                    "kind": m.kind, "placement": m.placement,
                    "tenant": self._tag_owner(m.tenant),
                    "blocks": (m.block_ids() if m.placement == DEVICE
                               else int(m._host_blocks)),
                })
        for name, (ids, streams, gens) in device.items():
            entry = {"blocks": [int(b) for b in ids],
                     "gens": [int(g) for g in gens], "streams": []}
            for j, arr in enumerate(streams):
                if arr is None:
                    entry["streams"].append(None)
                    continue
                key = f"device_{name}_{j}"
                arr = np.ascontiguousarray(arr)
                arrays[key] = np.frombuffer(arr.tobytes(), np.uint8)
                entry["streams"].append({"key": key,
                                         "shape": list(arr.shape),
                                         "dtype": str(arr.dtype)})
            meta["device"][name] = entry
        for i, ((cls, owner), (payload, nbytes)) in enumerate(
                self._host_payload.items()):
            streams = []
            for j, arr in enumerate(payload):
                if arr is None:
                    streams.append(None)
                    continue
                key = f"payload_{i}_{j}"
                arr = np.ascontiguousarray(arr)
                arrays[key] = np.frombuffer(arr.tobytes(), np.uint8)
                streams.append({"key": key, "shape": list(arr.shape),
                                "dtype": str(arr.dtype)})
            meta["payloads"].append({"cls": cls,
                                     "owner": self._tag_owner(owner),
                                     "nbytes": int(nbytes),
                                     "streams": streams})
        arrays["__meta__"] = np.frombuffer(
            json.dumps(meta).encode(), np.uint8)
        np.savez(path, **arrays)

    def restore(self, path: str) -> Dict[Tuple[str, object], Mapping]:
        """Rebuild host-tier residency, payloads and mappings from a
        ``snapshot()``.

        Pool classes are re-registered when absent (idempotent-or-loud
        when present, so restoring into an engine-built arena verifies
        the specs match).  HOST-resident mappings always come back.
        DEVICE-resident mappings come back when the snapshot carries
        device payloads (``include_device=True`` / a migration
        finalize): each distinct saved block id gets one fresh lease and
        every further mapping that named it re-shares that lease, so
        refcounts and COW aliasing survive the roundtrip exactly; the
        payload is then scattered through the transfer plane onto the
        (relocated) fresh ids -- block tables absorb the move, as
        everywhere else.  Returns ``{(pool_class, owner): Mapping}`` for
        the caller to re-adopt (``PagedKVManager.adopt``).
        """
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"].tobytes()).decode())
            for name, spec in meta["classes"].items():
                self.register_class(
                    name, num_blocks=spec["num_blocks"],
                    block_shape=tuple(spec["block_shape"]),
                    dtype=jnp.dtype(spec["dtype"]),
                    block_nbytes=spec["block_nbytes"],
                    dp_groups=spec["dp_groups"])
            device_meta = meta.get("device", {})
            restored: Dict[Tuple[str, object], Mapping] = {}
            # old physical id -> the first lease re-materializing it (the
            # COW alias anchor); later mappings share it instead of
            # allocating
            alias: Dict[Tuple[str, int], Lease] = {}
            for entry in meta["mappings"]:
                cls = entry["cls"]
                owner = self._untag_owner(entry["owner"])
                tenant = (self._untag_owner(entry["tenant"])
                          if "tenant" in entry else "default")
                if entry["placement"] == HOST:
                    m = self.mapping(cls, owner, kind=entry["kind"],
                                     tenant=tenant)
                    m.placement = HOST
                    m._host_blocks = int(entry["blocks"])
                    self._host_register(cls, owner, m._host_blocks)
                    restored[(cls, owner)] = m
                    continue
                if cls not in device_meta:
                    # no device payload in the snapshot: a restarted
                    # process lost device memory by definition --
                    # re-submit those requests
                    continue
                if not self.transfers.has_executor(cls):
                    raise RuntimeError(
                        f"snapshot carries device payload for pool class "
                        f"{cls!r} but the restoring arena has no "
                        f"executor; restore into an engine-built arena")
                m = self.mapping(cls, owner, kind=entry["kind"],
                                 tenant=tenant)
                for old in entry["blocks"]:
                    key = (cls, int(old))
                    if key in alias:
                        m.leases.append(self.share(alias[key], owner))
                    else:
                        [lease] = self.lease_blocks(cls, owner, 1)
                        m.leases.append(lease)
                        alias[key] = lease
                restored[(cls, owner)] = m
            # scatter the device payloads onto the fresh ids, in the
            # saved gather order
            for cls, dev in device_meta.items():
                dst = []
                for old in dev["blocks"]:
                    lease = alias.get((cls, int(old)))
                    if lease is None:
                        raise RuntimeError(
                            f"device payload of {cls!r} names block "
                            f"{old} that no snapshotted mapping holds")
                    dst.append(lease.block)
                streams = tuple(
                    None if s is None else np.frombuffer(
                        z[s["key"]].tobytes(),
                        self._np_dtype(s["dtype"])).reshape(s["shape"])
                    for s in dev["streams"])
                owner = f"__snapshot__/{cls}"
                nbytes = int(sum(s.nbytes for s in streams
                                 if s is not None))
                self.host_deposit(cls, owner, streams, nbytes)
                self.transfers.enqueue_swap_in(cls, owner, dst,
                                               kind="migrate-in")
                self.transfers.drain()
            for p in meta["payloads"]:
                cls, owner = p["cls"], self._untag_owner(p["owner"])
                streams = tuple(
                    None if s is None else np.frombuffer(
                        z[s["key"]].tobytes(),
                        self._np_dtype(s["dtype"])).reshape(s["shape"])
                    for s in p["streams"])
                self.host_deposit(cls, owner, streams, p["nbytes"])
        return restored
