"""Arena: ONE software address space behind every block-backed subsystem.

The paper's central bet is that a single, simple software memory manager
-- fixed blocks, id-based page tables, no contiguity promises -- can
serve every client an OS with virtual memory would.  This class is that
manager as one artifact: the paged KV cache, ``TreeArray``,
``BlockStack`` and the serving host store all allocate here, so an
experiment can measure "the allocator" instead of five re-implementations
of it.

Shape of the API:

  * one **pool class** per (block_shape, dtype) family -- the paper's
    "choose your own block quantum" argument: KV blocks, tree leaves and
    host-side metadata blocks coexist as separately sized classes of the
    same address space, each backed by a ``BlockAllocator``;
  * the **host swap tier** is a first-class second placement level, not
    a side table: a ``Mapping`` migrated to host keeps its identity (and
    its payload, deposited by the transfer layer) and re-materializes on
    any free device blocks later;
  * clients hold typed ``Lease`` handles and ``Mapping`` tables, never
    raw ints, so compaction can relocate physical blocks without any
    client seeing a stale id;
  * allocation **under pressure** consults a registered *reclaimer*
    (the serving engine's LIFO preemption) instead of failing -- the COW
    barrier and growth fallback that used to live inline in
    ``serve/engine.py`` are Arena policy now, and the scheduler
    negotiates admission against ``free_blocks`` of this one arena;
  * ``compact()`` is the ROADMAP's defrag pass: when free blocks are
    plentiful but table locality has degraded, it emits a
    ``kernels/block_copy`` plan moving live blocks to a dense prefix and
    rewrites every lease in place (paper Table 1 row 'Relocation /
    Migration': tables absorb the move, no client pointer updates).
"""

from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.mem.blockpool import BlockAllocator, OutOfBlocksError
from repro.mem.lease import Lease
from repro.mem.mapping import DEVICE, FLAT, HOST, Mapping
from repro.mem.stats import ArenaStats, PoolClassStats

#: reclaimer signature: called with the requesting owner when a pool
#: class is exhausted; must free blocks (e.g. preempt a victim) and
#: return the reclaimed owner, or None when nothing can be reclaimed.
Reclaimer = Callable[[object], Optional[object]]


class LeaseRevokedError(OutOfBlocksError):
    """Pressure reclaim chose the requester itself: its blocks were
    migrated out mid-request, so the allocation is moot.  Subclasses
    ``OutOfBlocksError`` so legacy callers that catch the base class
    keep working."""


class _PoolClass:
    """Internal per-(block_shape, dtype) state."""

    __slots__ = ("name", "num_blocks", "block_shape", "dtype",
                 "block_nbytes", "allocator", "leases", "pinned",
                 "mappings")

    def __init__(self, name: str, num_blocks: int, block_shape: Tuple,
                 dtype, block_nbytes: int):
        self.name = name
        self.num_blocks = num_blocks
        self.block_shape = block_shape
        self.dtype = dtype
        self.block_nbytes = block_nbytes
        self.allocator = BlockAllocator(num_blocks)
        self.leases: Dict[int, List[Lease]] = {}
        self.pinned: List[Lease] = []
        self.mappings: List[Mapping] = []


class Arena:
    """The unified software address space (see module docstring)."""

    def __init__(self):
        self._classes: Dict[str, _PoolClass] = {}
        self._reclaimer: Optional[Reclaimer] = None
        # host tier: residency counts (owned by Mapping.migrate) and
        # payloads (deposited/taken by the transfer layer) are separate
        # so migrate("device") can reallocate ids before the scatter.
        self._host_counts: Dict[Tuple[str, object], int] = {}
        self._host_payload: Dict[Tuple[str, object], Tuple[object, int]] = {}
        self.compactions = 0
        self.blocks_compacted = 0

    # ---------------- pool classes ----------------
    def register_class(self, name: str, *, num_blocks: int,
                       block_shape: Tuple = (), dtype=jnp.float32,
                       block_nbytes: Optional[int] = None) -> str:
        """Declare (or re-attach to) one (block_shape, dtype) pool class.

        Registration is idempotent for an identical spec -- many clients
        of one engine attach to the same class -- and loud on conflict.
        Returns ``name`` so callers can chain.
        """
        if block_nbytes is None:
            block_nbytes = (int(np.prod(block_shape)) if block_shape else 1
                            ) * jnp.dtype(dtype).itemsize
        if name in self._classes:
            st = self._classes[name]
            if (st.num_blocks != num_blocks
                    or st.block_nbytes != block_nbytes
                    or st.block_shape != tuple(block_shape)
                    or st.dtype != dtype):
                raise ValueError(
                    f"pool class {name!r} re-registered with a different "
                    f"spec: {num_blocks}x{block_nbytes}B "
                    f"{tuple(block_shape)}/{dtype} vs existing "
                    f"{st.num_blocks}x{st.block_nbytes}B "
                    f"{st.block_shape}/{st.dtype}")
            return name
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        self._classes[name] = _PoolClass(name, num_blocks, tuple(block_shape),
                                         dtype, int(block_nbytes))
        return name

    def _cls(self, name: str) -> _PoolClass:
        try:
            return self._classes[name]
        except KeyError:
            raise KeyError(f"unregistered pool class {name!r}; call "
                           f"Arena.register_class first") from None

    @property
    def pool_classes(self) -> List[str]:
        return list(self._classes)

    # ---------------- queries ----------------
    def num_blocks(self, cls: str) -> int:
        return self._cls(cls).num_blocks

    def num_free(self, cls: str) -> int:
        return self._cls(cls).allocator.num_free

    def num_used(self, cls: str) -> int:
        return self._cls(cls).allocator.num_used

    def refcount(self, cls: str, block: int) -> int:
        return self._cls(cls).allocator.refcount(block)

    def allocator(self, cls: str) -> BlockAllocator:
        """The raw allocator -- a compat escape hatch for tests that poke
        free-list state.  Blocks allocated here bypass the lease registry
        and make the class ineligible for ``compact()``."""
        return self._cls(cls).allocator

    # ---------------- pressure protocol ----------------
    def set_reclaimer(self, fn: Optional[Reclaimer]) -> None:
        """Register the pressure-time reclaim callback.

        Exactly one reclaimer per arena: silently displacing an earlier
        registrant (e.g. two engines sharing one address space) would
        reroute its pressure handling, so that conflict is loud.  Pass
        None to clear before handing the arena to a new owner.
        """
        if (fn is not None and self._reclaimer is not None
                and self._reclaimer is not fn):
            raise ValueError(
                "arena already has a reclaimer registered; call "
                "set_reclaimer(None) first to transfer ownership")
        self._reclaimer = fn

    def _alloc_ids(self, cls: str, n: int, *, pressure: bool,
                   requester) -> List[int]:
        """Atomically allocate ``n`` ids, reclaiming under pressure.

        This loop is the LIFO-preemption fallback that used to live in
        ``serve/engine.py``: on exhaustion the reclaimer evicts victims
        (newest admission first) until the request fits -- or until the
        requester itself is the victim, which surfaces as
        ``LeaseRevokedError`` (the requester's blocks are already on the
        host tier; the allocation is moot, not failed).
        """
        st = self._cls(cls)
        while True:
            if st.allocator.num_free >= n:
                return [st.allocator.alloc() for _ in range(n)]
            if not pressure or self._reclaimer is None:
                raise OutOfBlocksError(
                    f"pool class {cls!r}: requested {n} blocks, "
                    f"only {st.allocator.num_free} free")
            victim = self._reclaimer(requester)
            if victim is None:
                raise OutOfBlocksError(
                    f"pool class {cls!r}: exhausted and nothing left "
                    f"to reclaim")
            if victim == requester:
                raise LeaseRevokedError(
                    f"pool class {cls!r}: owner {requester!r} was "
                    f"reclaimed to satisfy its own request")

    # ---------------- leases ----------------
    def lease_blocks(self, cls: str, owner, n: int = 1, *,
                     pressure: bool = False,
                     requester=None) -> List[Lease]:
        """Allocate ``n`` exclusive leases for ``owner``."""
        ids = self._alloc_ids(cls, n, pressure=pressure,
                              requester=owner if requester is None
                              else requester)
        st = self._cls(cls)
        out = []
        for b in ids:
            lease = Lease(self, cls, b, owner)
            st.leases.setdefault(b, []).append(lease)
            out.append(lease)
        return out

    def share(self, lease: Lease, owner) -> Lease:
        """COW-alias: a new lease on the same block (refcount++)."""
        if not lease.live:
            raise ValueError("share of a released lease")
        if lease.pinned:
            raise ValueError("pinned blocks cannot be shared")
        st = self._cls(lease.pool_class)
        st.allocator.share(lease.block)
        new = Lease(self, lease.pool_class, lease.block, owner)
        st.leases[lease.block].append(new)
        return new

    def release(self, lease: Lease) -> None:
        if not lease.live:
            raise ValueError(f"double release of {lease!r}")
        lease.live = False
        st = self._cls(lease.pool_class)
        holders = st.leases[lease.block]
        holders.remove(lease)
        if not holders:
            del st.leases[lease.block]
        st.allocator.free(lease.block)

    def pin(self, cls: str, owner="pinned") -> Lease:
        """Permanently claim one block (e.g. the engine's write sink:
        masked table entries scatter here instead of into live blocks).
        Pinned blocks survive ``assert_quiescent`` and may still be
        relocated by ``compact()`` -- holders read ``lease.block``."""
        [lease] = self.lease_blocks(cls, owner)
        lease.pinned = True
        self._cls(cls).pinned.append(lease)
        return lease

    def unpin(self, lease: Lease) -> None:
        self._cls(lease.pool_class).pinned.remove(lease)
        lease.pinned = False
        self.release(lease)

    # ---------------- mappings ----------------
    def mapping(self, cls: str, owner, kind: str = FLAT) -> Mapping:
        m = Mapping(self, cls, owner, kind=kind)
        self._cls(cls).mappings.append(m)
        return m

    def _forget_mapping(self, m: Mapping) -> None:
        self._cls(m.pool_class).mappings.remove(m)

    # ---------------- host swap tier ----------------
    def _host_register(self, cls: str, owner, nblocks: int) -> None:
        key = (cls, owner)
        if key in self._host_counts:
            raise ValueError(f"{owner!r} already host-resident in {cls!r}")
        self._host_counts[key] = nblocks

    def _host_unregister(self, cls: str, owner) -> int:
        return self._host_counts.pop((cls, owner))

    def host_deposit(self, cls: str, owner, payload, nbytes: int) -> None:
        """Attach a migrated mapping's payload (one compact gathered
        array per stream -- see ``serve/swap.py``)."""
        self._host_payload[(cls, owner)] = (payload, int(nbytes))

    def host_take(self, cls: str, owner):
        payload, _ = self._host_payload.pop((cls, owner))
        return payload

    def host_discard(self, cls: str, owner) -> None:
        self._host_payload.pop((cls, owner), None)

    def host_contains(self, cls: str, owner) -> bool:
        return (cls, owner) in self._host_payload

    def host_len(self, cls: str) -> int:
        return sum(1 for (c, _) in self._host_payload if c == cls)

    def host_counts(self, cls: str) -> Dict[object, int]:
        return {o: n for (c, o), n in self._host_counts.items() if c == cls}

    # ---------------- fragmentation / compaction ----------------
    def fragmentation(self, cls: str) -> float:
        """1 - used/span over the id space; 0.0 = dense prefix.

        With fixed blocks there is no *external* fragmentation (the
        paper's point) -- this measures how far live blocks have
        scattered from the dense prefix, which is what degrades
        table-gather locality and what ``compact()`` restores.
        """
        st = self._cls(cls)
        used = st.allocator.num_used
        if used == 0:
            return 0.0
        span = int(st.allocator.used_ids().max()) + 1
        return 1.0 - used / span

    def table_locality(self, cls: str) -> float:
        """Mean ``Mapping.locality()`` over device-resident mappings."""
        vals = [m.locality() for m in self._cls(cls).mappings
                if m.placement == DEVICE and len(m.leases) >= 2]
        return float(np.mean(vals)) if vals else 1.0

    def should_compact(self, cls: str, *, min_free_frac: float = 0.25,
                       frag_threshold: float = 0.25) -> bool:
        """Defrag policy: free blocks are plentiful (the copy plan is
        cheap and nothing is starving) but locality has degraded."""
        st = self._cls(cls)
        if st.allocator.num_free < min_free_frac * st.num_blocks:
            return False
        return self.fragmentation(cls) > frag_threshold

    def compact(self, cls: str) -> Tuple[np.ndarray, np.ndarray]:
        """Move live blocks to the dense prefix; returns the (src, dst)
        copy plan the caller MUST execute against the device pool
        (``kernels.block_copy.copy_pool_blocks``) before the next read.

        Every lease is rewritten in place (tables built afterwards see
        only new ids) and the allocator's free list is rebuilt.  Refuses
        to run when any live block is not lease-tracked (raw-allocator
        escape hatch in use) -- relocating a block nobody's table names
        would lose data silently.
        """
        st = self._cls(cls)
        live = [int(b) for b in st.allocator.used_ids()]
        untracked = [b for b in live if b not in st.leases]
        if untracked:
            raise RuntimeError(
                f"cannot compact {cls!r}: blocks {untracked} were "
                f"allocated outside the lease registry")
        from repro.core.block_table import compaction_plan
        plan = compaction_plan(live)
        if not plan:
            return np.zeros(0, np.int32), np.zeros(0, np.int32)
        st.allocator.relocate(plan)
        for s, d in plan:
            moved = st.leases.pop(s)
            for lease in moved:
                lease.block = d
            st.leases[d] = moved
        self.compactions += 1
        self.blocks_compacted += len(plan)
        src = np.asarray([s for s, _ in plan], np.int32)
        dst = np.asarray([d for _, d in plan], np.int32)
        return src, dst

    # ---------------- stats / invariants ----------------
    def stats(self) -> ArenaStats:
        classes = {}
        for name, st in self._classes.items():
            by_owner: collections.Counter = collections.Counter()
            for holders in st.leases.values():
                for lease in holders:
                    by_owner[str(lease.owner)] += 1
            host = {str(o): n for (c, o), n in self._host_counts.items()
                    if c == name}
            kinds: collections.Counter = collections.Counter(
                m.kind for m in st.mappings)
            classes[name] = PoolClassStats(
                name=name,
                num_blocks=st.num_blocks,
                num_free=st.allocator.num_free,
                num_used=st.allocator.num_used,
                pinned=len(st.pinned),
                blocks_by_owner=dict(by_owner),
                host_blocks_by_owner=host,
                refcount_histogram=[int(x) for x in
                                    st.allocator.refcount_histogram()],
                fragmentation=round(self.fragmentation(name), 4),
                table_locality=round(self.table_locality(name), 4),
                mappings_by_kind=dict(kinds),
            )
        return ArenaStats(classes=classes, compactions=self.compactions,
                          blocks_compacted=self.blocks_compacted)

    def check_registry(self, cls: str) -> None:
        """Invariant: every allocated block's refcount equals its lease
        count (no bookkeeping drift between allocator and handles)."""
        st = self._cls(cls)
        for b in st.allocator.used_ids():
            b = int(b)
            n = len(st.leases.get(b, []))
            assert n == st.allocator.refcount(b), (
                f"pool class {cls!r} block {b}: {n} leases vs refcount "
                f"{st.allocator.refcount(b)}")

    def assert_quiescent(self) -> None:
        """Leak invariant: nothing but pinned blocks is allocated and the
        host tier is empty.  Every engine test ends on this."""
        for name, st in self._classes.items():
            pinned_ids = {l.block for l in st.pinned}
            for b in st.allocator.used_ids():
                b = int(b)
                assert b in pinned_ids, (
                    f"leak in pool class {name!r}: block {b} "
                    f"(refcount {st.allocator.refcount(b)}, leases "
                    f"{st.leases.get(b)}) still allocated")
                assert st.allocator.refcount(b) == 1, (
                    f"pinned block {b} of {name!r} has refcount "
                    f"{st.allocator.refcount(b)} != 1")
            hist = st.allocator.refcount_histogram()
            assert int(hist[1:].sum()) == len(pinned_ids), (
                f"refcount histogram of {name!r} not all-zeros beyond "
                f"pinned: {hist.tolist()}")
        assert not self._host_counts, (
            f"host tier residency leaked: {self._host_counts}")
        assert not self._host_payload, (
            f"host tier payload leaked: {list(self._host_payload)}")
