"""ArenaStats: the observability surface of the software address space.

One struct, three consumers: ``benchmarks/bench_serve.py`` embeds it in
``BENCH_serve.json``, ``repro.report`` renders it as a table, and tests
use it for leak invariants (every engine test must end with zero
non-pinned blocks used and an all-zero refcount histogram).

Per pool class:

  * blocks by owner and by placement (device leases vs host swap tier),
  * the refcount histogram (``histogram[r]`` = blocks at refcount ``r``;
    entries at r >= 2 are live COW sharing),
  * ``fragmentation``: ``1 - used / span`` where span is the highest
    used id + 1 -- 0.0 means the live blocks form a dense prefix (the
    state ``Arena.compact()`` restores),
  * ``table_locality``: mean over mappings of the fraction of logically
    adjacent block pairs that are physically adjacent -- the quantity
    that degrades as preemption/swap-in scatters tables, and the trigger
    (together with plentiful free blocks) for the defrag pass,
  * ``in_flight`` / ``held``: the transfer plane's discipline counters
    (leases awaiting an unfenced copy; vacated DMA sources the
    allocator may not reuse yet),
  * ``groups``: blocks used/free per dp pool group (contiguous id
    ranges) when the class was registered with ``dp_groups > 1`` -- the
    measurement surface for group-partitioned allocation.

``ArenaStats.transfers`` embeds the ``TransferStats`` snapshot (plans
per direction, bytes moved, coalesced launches, overlapped host copies).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class PoolClassStats:
    name: str
    num_blocks: int
    num_free: int
    num_used: int
    pinned: int
    blocks_by_owner: Dict[str, int]
    host_blocks_by_owner: Dict[str, int]
    refcount_histogram: List[int]
    fragmentation: float
    table_locality: float
    mappings_by_kind: Dict[str, int]
    in_flight: int = 0
    held: int = 0
    #: outstanding holds attributed to the DMA engine (direction)
    #: responsible for them -- which queue is pinning vacated blocks
    held_by_engine: Dict[str, int] = dataclasses.field(default_factory=dict)
    groups: List[Dict[str, int]] = dataclasses.field(default_factory=list)
    #: admission-enforced per-tenant block ceilings (empty = unlimited)
    quota_by_tenant: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: device + host blocks currently charged to each tenant
    blocks_by_tenant: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def host_blocks(self) -> int:
        return sum(self.host_blocks_by_owner.values())

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["host_blocks"] = self.host_blocks
        return d


@dataclasses.dataclass
class ArenaStats:
    classes: Dict[str, PoolClassStats]
    compactions: int = 0
    blocks_compacted: int = 0
    transfers: Optional[Dict] = None

    def __getitem__(self, name: str) -> PoolClassStats:
        return self.classes[name]

    def to_dict(self) -> dict:
        return {
            "compactions": self.compactions,
            "blocks_compacted": self.blocks_compacted,
            "transfers": self.transfers,
            "classes": {k: v.to_dict() for k, v in self.classes.items()},
        }
