"""The asynchronous transfer plane: every block copy is a schedulable plan.

The paper's closing argument is that once software manages physical
blocks directly, data movement stops being an implicit side effect of
address translation and becomes an explicit, schedulable resource -- it
names "chips with multiple DMA devices" as exactly the hardware this
buys leverage on.  This module is that idea as an API: all four movement
producers of the address space (``Mapping.migrate`` swap-out/in, the COW
``ensure_writable`` copy, ``Arena.compact()`` relocation) stop copying
inline and instead enqueue ``TransferPlan`` descriptors onto the Arena's
``TransferQueue``.  Nothing outside this module touches the block-copy
kernels or the host tier's payload verbs -- a grep-enforced test pins
the rule (``tests/test_transfer.py``).

Shape of the plane:

  * **directions** -- ``d2d`` (COW fulfilment, compaction relocation),
    ``d2h`` (swap-out gather + host copy), ``h2d`` (swap-in scatter).
    Plans carry a global FIFO ``seqno``; per-direction queues are views
    for accounting and batching, execution order is enqueue order.
  * **``TransferPlan``** -- one batched block-copy descriptor: the
    generalization of the compaction plan (``src``/``dst`` id vectors,
    pool class, byte count, producing verb).
  * **``Fence``** -- an epoch completion token: ``fence.done`` is true
    once every plan enqueued at or before it has executed;
    ``fence.wait()`` drains exactly that prefix.
  * **two-phase d2h** -- ``dispatch()`` launches the device-side gather
    (async under jax) and *releases the held source blocks*; the
    blocking host copy (``np.asarray``) is deferred until the fence.
    The serving engine dispatches at step N and fences at step N+1, so
    the host copy overlaps the decode in between (double buffering).
  * **discipline** -- a plan's freed source blocks are HELD in the
    allocator (unallocatable) until the gather is dispatched, and its
    destination leases are ``in_flight`` until it executes; reading a
    block while a transfer targeting it is unfenced raises
    ``UnfencedReadError`` (``Mapping.assert_settled``).
  * **``drain()``** -- the synchronous fallback: execute everything
    now.  Token-identical behavior between the overlapped and drained
    schedules is pinned by a property test and by ``bench_serve``'s
    byte-equivalence assertion.

Execution needs device arrays: clients register an *executor* per pool
class (``register_executor``) exposing the current device streams (the
KV k/v pools) functionally -- get returns the streams, set writes the
updated ones back.  Pool classes with no executor (metadata-only arenas,
e.g. unit tests without a device pool) complete their plans immediately
as residency-only moves.
"""

from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional,
                    Sequence, Tuple)

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.mem.arena import Arena

D2D = "d2d"   # device -> device: COW fulfilment, compaction relocation
D2H = "d2h"   # device -> host:   swap-out (gather + host copy)
H2D = "h2d"   # host -> device:   swap-in (scatter)
DIRECTIONS = (D2D, D2H, H2D)

#: plan lifecycle
PENDING = "pending"        # enqueued, device work not started
DISPATCHED = "dispatched"  # d2h only: gather launched, host copy deferred
DONE = "done"


class UnfencedReadError(RuntimeError):
    """A block was read (table built for decode) while a transfer
    targeting it was still unfenced.  The engine's read barrier
    (``TransferQueue.dispatch`` before ``_sync_device_state``) makes
    this unreachable in the step loop; reaching it means a client
    skipped the fence."""


class Fence:
    """Epoch completion token: covers every plan with seqno <= epoch."""

    __slots__ = ("queue", "epoch")

    def __init__(self, queue: "TransferQueue", epoch: int):
        self.queue = queue
        self.epoch = epoch

    @property
    def done(self) -> bool:
        return self.queue._prefix_done(self.epoch)

    def wait(self) -> None:
        """Synchronously execute every plan this fence covers."""
        self.queue.stats.fences += 1
        self.queue.drain(upto=self.epoch)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Fence(epoch={self.epoch} done={self.done})"


@dataclasses.dataclass(eq=False)          # identity semantics: plans are
class TransferPlan:                        # queue entries, not values
    """One batched block-copy descriptor (the compaction plan,
    generalized to every movement verb and both placement tiers)."""

    direction: str                     # d2d | d2h | h2d
    pool_class: str
    kind: str                          # producing verb: cow|compact|swap-out|swap-in|...
    src: Optional[np.ndarray] = None   # device ids read (d2d, d2h)
    dst: Optional[np.ndarray] = None   # device ids written (d2d, h2d)
    owner: object = None               # host-tier payload key (d2h, h2d)
    nbytes: int = 0                    # known at enqueue for d2d, measured for d2h/h2d
    seqno: int = -1                    # global FIFO position
    state: str = PENDING
    dispatch_mark: int = -1            # compute-mark count at gather launch
    # internal: launched-but-uncopied device gathers, holds, in-flight marks
    _gathered: Optional[list] = dataclasses.field(default=None, repr=False)
    _held: list = dataclasses.field(default_factory=list, repr=False)
    _flagged: list = dataclasses.field(default_factory=list, repr=False)


def _zeroed() -> Dict[str, int]:
    return {d: 0 for d in DIRECTIONS}


@dataclasses.dataclass
class TransferStats:
    """Observability of the transfer plane (rendered by ``repro.report``
    and embedded in ``BENCH_serve.json`` / ``BENCH_transfers.json``)."""

    enqueued: Dict[str, int] = dataclasses.field(default_factory=_zeroed)
    completed: Dict[str, int] = dataclasses.field(default_factory=_zeroed)
    bytes_moved: Dict[str, int] = dataclasses.field(default_factory=_zeroed)
    launches: int = 0          # device kernel launches / host transfers
    coalesced: int = 0         # plans merged into a shared launch
    dispatches: int = 0
    drains: int = 0
    fences: int = 0            # fence phases (complete_dispatched / wait)
    #: d2h host copies that landed only AFTER a compute step ran between
    #: their gather launch and their completion (``note_compute`` marks
    #: each decode) -- the genuine double-buffer wins, not mere
    #: later-queue-op completions
    overlapped: int = 0
    max_pending: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class TransferQueue:
    """Per-direction transfer queues with global FIFO execution order
    (see module docstring)."""

    def __init__(self, arena: "Arena", eager: bool = False):
        self.arena = arena
        #: eager=True is the synchronous fallback: every enqueue drains
        #: immediately, pinning token-identical behavior for tests/CI.
        self.eager = eager
        self.stats = TransferStats()
        self._pending: List[TransferPlan] = []
        self._dispatched: List[TransferPlan] = []
        self._seq = 0
        self._compute_marks = 0
        # pool class -> (get_streams, set_streams, layered)
        self._executors: Dict[str, Tuple[Callable, Callable, bool]] = {}
        self._observers: Dict[object, Callable[[TransferPlan], None]] = {}

    # ---------------- wiring ----------------
    def register_executor(self, pool_class: str, get_streams: Callable,
                          set_streams: Callable,
                          layered: bool = True) -> None:
        """Bind the device streams of one pool class.

        ``get_streams()`` returns the current list of device arrays
        (layered: ``(L, NB, *block)``; flat: ``(NB, *block)``);
        ``set_streams(list)`` writes the updated arrays back.  The last
        registration wins (an arena handed to a new engine re-binds).
        """
        self._executors[pool_class] = (get_streams, set_streams, layered)

    def add_observer(self, fn: Callable[[TransferPlan], None],
                     key: Optional[str] = None) -> None:
        """Called once per completed plan (byte ledgers, e.g.
        ``serve/swap.HostBlockStore``).

        A ``key``ed registration REPLACES any earlier observer with the
        same key -- the same last-wins rule as ``register_executor``, so
        re-handing an arena to a new engine does not accumulate (and
        retain) dead ledgers.
        """
        self._observers[key if key is not None else object()] = fn

    def unregister_executor(self, pool_class: str) -> None:
        """Symmetric teardown: drop the executor binding (refuses while
        plans that would need it are outstanding)."""
        if any(p.pool_class == pool_class
               for p in self._pending + self._dispatched):
            raise ValueError(
                f"pool class {pool_class!r} has outstanding plans; "
                f"drain() before unregistering its executor")
        self._executors.pop(pool_class, None)

    def remove_observer(self, key: str) -> None:
        self._observers.pop(key, None)

    def note_compute(self) -> None:
        """Mark that a compute step (decode) ran: a d2h host copy whose
        gather launched before this mark and completes after it
        genuinely overlapped compute (the ``overlapped`` stat)."""
        self._compute_marks += 1

    # ---------------- queries ----------------
    @property
    def pending(self) -> int:
        """Plans not yet fully executed (pending + dispatched)."""
        return len(self._pending) + len(self._dispatched)

    @property
    def has_undispatched(self) -> bool:
        """Plans whose device work has not launched (these may hold
        freed blocks; ``dispatch()`` releases the holds non-blocking)."""
        return bool(self._pending)

    def pending_by_direction(self) -> Dict[str, int]:
        out = _zeroed()
        for p in self._pending + self._dispatched:
            out[p.direction] += 1
        return out

    def in_transit(self, pool_class: str) -> List[object]:
        """Owners whose swap-out payload has not reached the host tier
        yet (enqueued or dispatched d2h)."""
        return [p.owner for p in self._pending + self._dispatched
                if p.direction == D2H and p.pool_class == pool_class]

    def in_flight_blocks(self, pool_class: str) -> set:
        """Device ids named as destination by any unexecuted plan."""
        out = set()
        for p in self._pending:
            if p.pool_class == pool_class and p.dst is not None:
                out.update(int(b) for b in p.dst)
        return out

    def last_reference(self, pool_class: str, ids) -> Optional[int]:
        """Highest seqno of a PENDING plan that reads or writes one of
        ``ids``, or None.

        Dispatched d2h plans have already captured their sources, so
        only undispatched plans pin device state.  ``Mapping.free``
        consults this: releasing blocks a pending plan still names
        would let reuse race the plan's execution -- a
        ``drain(upto=<this seqno>)`` settles exactly the FIFO prefix
        that matters and leaves later plans overlapped.
        """
        ids = set(int(b) for b in ids)
        last = None
        for p in self._pending:
            if p.pool_class != pool_class:
                continue
            for vec in (p.src, p.dst):
                if vec is not None and any(int(b) in ids for b in vec):
                    last = p.seqno
        return last

    def last_transit(self, pool_class: str, owner) -> Optional[int]:
        """Highest seqno of an unfenced d2h plan of ``owner`` (payload
        still in transit), or None -- the fence target for teardown."""
        last = None
        for p in self._pending + self._dispatched:
            if p.direction == D2H and p.pool_class == pool_class \
                    and p.owner == owner:
                last = max(p.seqno, last if last is not None else p.seqno)
        return last

    def _prefix_done(self, epoch: int) -> bool:
        return not any(p.seqno <= epoch
                       for p in self._pending + self._dispatched)

    def fence(self) -> Fence:
        """Epoch token covering everything enqueued so far."""
        return Fence(self, self._seq - 1)

    def _done_fence(self) -> Fence:
        """An already-complete fence (empty/no-op plans): waiting on it
        must not serialize unrelated pending transfers."""
        return Fence(self, -1)

    # ---------------- producer API ----------------
    def enqueue_copy(self, pool_class: str, src, dst,
                     kind: str = "cow") -> Fence:
        """d2d: copy block src[i] -> dst[i] on every stream."""
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        if src.size == 0:
            return self._done_fence()
        nbytes = int(src.size) * self.arena.block_nbytes(pool_class)
        return self._enqueue(TransferPlan(D2D, pool_class, kind,
                                          src=src, dst=dst, nbytes=nbytes))

    def enqueue_swap_out(self, pool_class: str, owner, src,
                         kind: str = "swap-out") -> Fence:
        """d2h: gather ``src`` on device, deposit the compact payload in
        the arena host tier under ``owner`` at the fence."""
        src = np.asarray(src, np.int32).reshape(-1)
        if src.size == 0:
            return self._done_fence()
        return self._enqueue(TransferPlan(D2H, pool_class, kind,
                                          src=src, owner=owner))

    def enqueue_swap_in(self, pool_class: str, owner, dst,
                        kind: str = "swap-in") -> Fence:
        """h2d: scatter ``owner``'s host payload into fresh ids ``dst``."""
        dst = np.asarray(dst, np.int32).reshape(-1)
        if dst.size == 0:
            return self._done_fence()
        return self._enqueue(TransferPlan(H2D, pool_class, kind,
                                          dst=dst, owner=owner))

    # ---------------- enqueue internals ----------------
    def _enqueue(self, plan: TransferPlan) -> Fence:
        plan.seqno = self._seq
        self._seq += 1
        self.stats.enqueued[plan.direction] += 1
        if plan.pool_class not in self._executors:
            # metadata-only arena: no device payload exists, so the plan
            # completes immediately as a residency-only move
            plan.state = DONE
            self.stats.completed[plan.direction] += 1
            self._notify(plan)
            return Fence(self, plan.seqno)
        self._mark(plan)
        self._pending.append(plan)
        self.stats.max_pending = max(self.stats.max_pending, self.pending)
        fence = Fence(self, plan.seqno)
        if self.eager:
            self.drain()
        return fence

    def _mark(self, plan: TransferPlan) -> None:
        """Discipline marks: HOLD freed source blocks (a DMA reads them
        after the allocator let go -- they must not be reallocated
        before the gather launches) and flag destination leases
        ``in_flight`` (their payload is not there yet)."""
        st = self.arena._cls(plan.pool_class)
        if plan.src is not None:
            for b in plan.src:
                b = int(b)
                if st.allocator.refcount(b) == 0:
                    if st.allocator.is_held(b):
                        # an earlier pending plan already holds it; move
                        # the hold to this (later) reader so it survives
                        # until the LAST gather over the block launches
                        for p in self._pending:
                            if (p.pool_class == plan.pool_class
                                    and b in p._held):
                                p._held.remove(b)
                                break
                    else:
                        st.allocator.hold(b)
                    plan._held.append(b)
        if plan.dst is not None:
            for b in plan.dst:
                for lease in st.leases.get(int(b), []):
                    if not lease.in_flight:
                        lease.in_flight = True
                        plan._flagged.append(lease)

    def _release_holds(self, plan: TransferPlan) -> None:
        st = self.arena._cls(plan.pool_class)
        for b in plan._held:
            st.allocator.release_hold(b)
        plan._held = []

    def _clear_flags(self, plan: TransferPlan) -> None:
        for lease in plan._flagged:
            lease.in_flight = False
        plan._flagged = []

    def _notify(self, plan: TransferPlan) -> None:
        for fn in self._observers.values():
            fn(plan)

    # ---------------- execution ----------------
    def dispatch(self, upto: Optional[int] = None) -> None:
        """Execute d2d/h2d plans; LAUNCH d2h gathers, deferring their
        host copies to the next ``complete_dispatched``/``drain`` (the
        double-buffer half of the step loop)."""
        self.stats.dispatches += 1
        self._run_dispatch(upto)

    def complete_dispatched(self, upto: Optional[int] = None) -> None:
        """Fence phase: land every launched-but-uncopied d2h payload."""
        self.stats.fences += 1
        self._run_complete(upto)

    def drain(self, upto: Optional[int] = None) -> None:
        """Synchronous fallback: execute everything (or the fenced
        prefix) now, in enqueue order."""
        self.stats.drains += 1
        self._run_dispatch(upto)
        self._run_complete(upto)

    def _covered(self, plan: TransferPlan, upto: Optional[int]) -> bool:
        return upto is None or plan.seqno <= upto

    def _run_dispatch(self, upto: Optional[int] = None) -> None:
        while self._pending and self._covered(self._pending[0], upto):
            plan = self._pending.pop(0)
            if plan.direction == D2D:
                self._exec_copies(self._take_batch(plan, upto))
            elif plan.direction == D2H:
                self._dispatch_gathers(self._take_batch(plan, upto))
            else:
                self._exec_swap_in(plan)

    def _take_batch(self, head: TransferPlan,
                    upto: Optional[int]) -> List[TransferPlan]:
        """Coalesce consecutive same-direction same-class plans into one
        launch (the batched multi-plan gather/copy).  A d2d plan whose
        sources overlap an earlier destination in the batch depends on
        that copy and must not share its snapshot -- the batch breaks
        there."""
        batch = [head]
        dsts = set() if head.dst is None else set(int(b) for b in head.dst)
        while self._pending:
            nxt = self._pending[0]
            if (nxt.direction != head.direction
                    or nxt.pool_class != head.pool_class
                    or not self._covered(nxt, upto)):
                break
            if nxt.src is not None and any(int(b) in dsts for b in nxt.src):
                break
            batch.append(self._pending.pop(0))
            if nxt.dst is not None:
                dsts.update(int(b) for b in nxt.dst)
        self.stats.coalesced += len(batch) - 1
        return batch

    def _streams(self, pool_class: str):
        get, set_, layered = self._executors[pool_class]
        return get(), set_, layered

    def _exec_copies(self, batch: List[TransferPlan]) -> None:
        from repro.kernels import ops
        import jax.numpy as jnp
        src = jnp.asarray(np.concatenate([p.src for p in batch]), jnp.int32)
        dst = jnp.asarray(np.concatenate([p.dst for p in batch]), jnp.int32)
        streams, set_, layered = self._streams(batch[0].pool_class)
        copy = ops.copy_pool_blocks if layered else ops.block_copy
        set_([copy(s, src, dst) for s in streams])
        self.stats.launches += 1
        for plan in batch:
            self._release_holds(plan)
            self._clear_flags(plan)
            plan.state = DONE
            self.stats.completed[D2D] += 1
            self.stats.bytes_moved[D2D] += plan.nbytes
            self._notify(plan)

    def _dispatch_gathers(self, batch: List[TransferPlan]) -> None:
        """Launch ONE device gather over the batch's concatenated ids
        (multi-plan) and slice per plan; the blocking host copies wait
        for the fence.  Holds release here: the gather has captured the
        functional snapshot, so the ids are safely reusable."""
        from repro.kernels import ops
        import jax.numpy as jnp
        ids = jnp.asarray(np.concatenate([p.src for p in batch]), jnp.int32)
        streams, _, layered = self._streams(batch[0].pool_class)
        gathered = [ops.gather_blocks(s, ids) if layered else s[ids]
                    for s in streams]
        self.stats.launches += 1
        off = 0
        for plan in batch:
            n = plan.src.size
            plan._gathered = [(g[:, off:off + n] if layered
                               else g[off:off + n]) for g in gathered]
            off += n
            self._release_holds(plan)
            plan.state = DISPATCHED
            plan.dispatch_mark = self._compute_marks
            self._dispatched.append(plan)

    def _run_complete(self, upto: Optional[int] = None) -> None:
        for plan in [p for p in self._dispatched if self._covered(p, upto)]:
            self._dispatched.remove(plan)
            self._complete(plan)

    def _complete(self, plan: TransferPlan) -> None:
        host = tuple(np.asarray(g) for g in plan._gathered)
        plan._gathered = None
        plan.nbytes = int(sum(h.nbytes for h in host))
        self.arena.host_deposit(plan.pool_class, plan.owner, host,
                                plan.nbytes)
        plan.state = DONE
        self.stats.launches += 1                 # the host copy itself
        self.stats.completed[D2H] += 1
        self.stats.bytes_moved[D2H] += plan.nbytes
        if self._compute_marks > plan.dispatch_mark:
            self.stats.overlapped += 1           # a decode ran in between
        self._notify(plan)

    def _exec_swap_in(self, plan: TransferPlan) -> None:
        from repro.kernels import ops
        import jax.numpy as jnp
        cls, owner = plan.pool_class, plan.owner
        if not self.arena.host_contains(cls, owner):
            # the payload is still in a dispatched d2h of the same owner
            # (preempt + immediate resume): land it first, in FIFO order
            for p in [p for p in self._dispatched
                      if p.pool_class == cls and p.owner == owner]:
                self._dispatched.remove(p)
                self._complete(p)
        payload = self.arena.host_take(cls, owner)
        idx = jnp.asarray(plan.dst, jnp.int32)
        streams, set_, layered = self._streams(cls)
        if len(payload) != len(streams):
            raise ValueError(
                f"swap-in of {owner!r}: payload has {len(payload)} "
                f"streams, executor exposes {len(streams)}")
        n = int(plan.dst.size)
        for h in payload:
            saved = (h.shape[1] if layered else h.shape[0]) \
                if h is not None else n
            if saved != n:
                raise ValueError(
                    f"swap-in of {owner!r}: {saved} saved blocks into "
                    f"{n} fresh ids")
        out = [s if h is None
               else ops.scatter_blocks(s, idx, jnp.asarray(h)) if layered
               else s.at[idx].set(jnp.asarray(h))
               for s, h in zip(streams, payload)]
        set_(out)
        plan.nbytes = int(sum(h.nbytes for h in payload if h is not None))
        self._clear_flags(plan)
        plan.state = DONE
        self.stats.launches += 1
        self.stats.completed[H2D] += 1
        self.stats.bytes_moved[H2D] += plan.nbytes
        self._notify(plan)
