"""The multi-queue transfer plane: one DMA engine per direction.

The paper's closing argument is that once software manages physical
blocks directly, data movement stops being an implicit side effect of
address translation and becomes an explicit, schedulable resource -- it
names "chips with multiple DMA devices" as exactly the hardware this
buys leverage on.  PR 4 built the single-queue version of that idea;
this module is the multi-DMA version: one ``TransferEngine`` per
direction, each with its own FIFO and priority lanes, coordinated by
cross-queue fences -- the shape a chip with separate d2d / d2h / h2d
DMA devices actually has.

Shape of the plane:

  * **engines** -- ``d2d`` (COW fulfilment, compaction relocation),
    ``d2h`` (swap-out gather + host copy), ``h2d`` (swap-in scatter,
    speculative prefetch).  Each ``TransferEngine`` owns a FIFO with a
    per-engine ``seqno`` clock and two lanes: ``urgent`` (the step
    loop's critical path) and ``background`` (speculative work that may
    be cancelled).
  * **``QueueSet``** -- the front-end every producer talks to.  It
    preserves the PR 4 producer API (``enqueue_copy`` /
    ``enqueue_swap_out`` / ``enqueue_swap_in`` / ``dispatch`` /
    ``complete_dispatched`` / ``drain``) so ``Mapping.migrate``,
    ``ensure_writable`` and ``Arena.compact`` did not change shape --
    only the execution substrate under them did.  ``TransferQueue`` is
    kept as an alias.
  * **cross-queue fences** -- a ``Fence`` is an *epoch vector* over
    engines (one seqno per direction), done only when every engine has
    settled its prefix.  Plans carry explicit cross-queue dependencies,
    computed at enqueue against the other engines' pending plans:

      - *launch-strength* (``deps``): a plan that writes blocks an
        earlier plan in another engine still names may not execute
        until that plan has at least launched (a dispatched d2h gather
        has captured its functional snapshot, so launch suffices);
      - *complete-strength* (``fdeps``): an h2d swap-in of owner ``O``
        may not execute until the unfenced d2h of the same owner has
        fully completed (its payload must be ON the host tier).

    Execution is an iterative fixpoint over engines: each pass runs
    every plan whose dependencies are settled and skips the rest;
    skipped plans become eligible as the engines they wait on progress.
    Dependencies always point backwards in global enqueue time, so the
    fixpoint terminates.
  * **d2h reorder window** -- because skipped plans *block only the
    plans that actually conflict with them* (write-read / read-write /
    write-write on the same pool class), independent d2h gathers
    coalesce into one launch ACROSS an intervening dependency: d2h
    plans enqueued on either side of a d2d copy share a gather when the
    dependency check against the copy's destinations passes, and split
    into two launches when it does not (``stats.reordered`` counts the
    wins; the old single-FIFO plane could only batch consecutive
    plans).
  * **speculative plans** -- ``enqueue_swap_in(..., speculative=True)``
    rides the background h2d lane, reads the host payload WITHOUT
    consuming it, and may be cancelled while pending
    (``cancel_plan``): holds release, in-flight flags clear, and the
    payload stays on the host tier for a later real swap-in.  The
    serving engine uses this for LIFO resume prefetch
    (``Mapping.prefetch``/``commit_prefetch``/``cancel_prefetch``).
  * **two-phase d2h** -- unchanged from PR 4: ``dispatch()`` launches
    the device gather and releases the held source blocks; the blocking
    host copy (``np.asarray``) is deferred until the fence, overlapping
    the decode in between.
  * **``drain()``** -- the pinned synchronous fallback: execute
    everything (or a fenced epoch-vector prefix, expanded to its
    dependency closure) now.  Token- and byte-identical behavior
    between the overlapped multi-queue schedule and the drained one is
    pinned by the property test in ``tests/test_transfer.py`` and by
    ``bench_serve``'s equivalence assertions.

Execution needs device arrays: clients register an *executor* per pool
class (``register_executor``) exposing the current device streams (the
KV k/v pools) functionally -- get returns the streams, set writes the
updated ones back.  Pool classes with no executor (metadata-only
arenas, e.g. unit tests without a device pool) complete their plans
immediately as residency-only moves.
"""

from __future__ import annotations

import dataclasses
from typing import (TYPE_CHECKING, Callable, Dict, Iterable, List, Optional,
                    Set, Tuple)

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.mem.arena import Arena

D2D = "d2d"   # device -> device: COW fulfilment, compaction relocation
D2H = "d2h"   # device -> host:   swap-out (gather + host copy)
H2D = "h2d"   # host -> device:   swap-in (scatter), speculative prefetch
DIRECTIONS = (D2D, D2H, H2D)

#: priority lanes within one engine
URGENT = "urgent"          # the step loop's critical path
BACKGROUND = "background"  # speculative work; cancellable while pending
LANES = (URGENT, BACKGROUND)

#: plan lifecycle
PENDING = "pending"        # enqueued, device work not started
DISPATCHED = "dispatched"  # d2h only: gather launched, host copy deferred
DONE = "done"
CANCELLED = "cancelled"    # speculative plan withdrawn before execution


class UnfencedReadError(RuntimeError):
    """A block was read (table built for decode) while a transfer
    targeting it was still unfenced.  The engine's read barrier
    (``QueueSet.dispatch`` before ``_sync_device_state``) makes this
    unreachable in the step loop; reaching it means a client skipped
    the fence."""


class Fence:
    """Cross-queue completion token: an epoch vector over engines.

    ``done`` is true once EVERY engine has settled all plans with
    seqno <= its epoch; ``wait()`` drains exactly those prefixes (plus
    their cross-queue dependency closure).  A fence minted at enqueue
    time covers the new plan AND everything enqueued before it on every
    engine -- the same prefix the PR 4 global-FIFO fence covered.
    """

    __slots__ = ("queues", "epochs")

    def __init__(self, queues: "QueueSet", epochs: Dict[str, int]):
        self.queues = queues
        self.epochs = dict(epochs)

    @property
    def done(self) -> bool:
        return all(self.queues.engines[d].prefix_done(e)
                   for d, e in self.epochs.items())

    def wait(self) -> None:
        """Synchronously execute every plan this fence covers."""
        self.queues.stats.fences += 1
        self.queues.drain(upto=self.epochs)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Fence({self.epochs} done={self.done})"


@dataclasses.dataclass(eq=False)          # identity semantics: plans are
class TransferPlan:                        # queue entries, not values
    """One batched block-copy descriptor (the compaction plan,
    generalized to every movement verb and both placement tiers)."""

    direction: str                     # d2d | d2h | h2d
    pool_class: str
    kind: str                          # producing verb: cow|compact|swap-out|swap-in|...
    src: Optional[np.ndarray] = None   # device ids read (d2d, d2h)
    dst: Optional[np.ndarray] = None   # device ids written (d2d, h2d)
    owner: object = None               # host-tier payload key (d2h, h2d)
    nbytes: int = 0                    # known at enqueue for d2d, measured for d2h/h2d
    seqno: int = -1                    # PER-ENGINE FIFO position
    lane: str = URGENT
    speculative: bool = False          # prefetch: peek payload, cancellable
    committed: bool = False            # prefetch promoted to the real resume
    abandoned: bool = False            # executed prefetch written off
    state: str = PENDING
    dispatch_mark: int = -1            # compute-mark count at device launch
    #: cross-queue dependencies, computed at enqueue: direction ->
    #: highest seqno in that engine this plan must wait for.  ``deps``
    #: is launch-strength (the dep must no longer be PENDING); ``fdeps``
    #: is complete-strength (the dep must be DONE -- payload landed).
    deps: Dict[str, int] = dataclasses.field(default_factory=dict)
    fdeps: Dict[str, int] = dataclasses.field(default_factory=dict)
    # internal: launched-but-uncopied device gathers, holds, in-flight marks
    _gathered: Optional[list] = dataclasses.field(default=None, repr=False)
    _held: list = dataclasses.field(default_factory=list, repr=False)
    _flagged: list = dataclasses.field(default_factory=list, repr=False)

    def __post_init__(self):
        # conflict sets, computed ONCE here instead of per plan per
        # dispatch round (src/dst are frozen after construction): the
        # per-phase walk and the enqueue-time dependency scan both read
        # these instead of rebuilding Python sets in the hot loop
        self._src_ids = (frozenset(int(b) for b in self.src)
                         if self.src is not None else frozenset())
        self._dst_ids = (frozenset(int(b) for b in self.dst)
                         if self.dst is not None else frozenset())
        self._skey = frozenset((self.pool_class, b) for b in self._src_ids)
        self._dkey = frozenset((self.pool_class, b) for b in self._dst_ids)


def _zeroed() -> Dict[str, int]:
    return {d: 0 for d in DIRECTIONS}


@dataclasses.dataclass
class TransferStats:
    """Observability of the transfer plane (rendered by ``repro.report``
    and embedded in ``BENCH_serve.json`` / ``BENCH_transfers.json``)."""

    enqueued: Dict[str, int] = dataclasses.field(default_factory=_zeroed)
    completed: Dict[str, int] = dataclasses.field(default_factory=_zeroed)
    bytes_moved: Dict[str, int] = dataclasses.field(default_factory=_zeroed)
    launches: int = 0          # device kernel launches / host transfers
    coalesced: int = 0         # plans merged into a shared launch
    reordered: int = 0         # d2h plans coalesced ACROSS a blocked plan
    dispatches: int = 0
    drains: int = 0
    fences: int = 0            # fence phases (complete_dispatched / wait)
    #: PER-ENGINE overlap attribution (the PR 5 bugfix: the global
    #: counter conflated h2d prefetch overlap with d2h double
    #: buffering).  ``overlapped[d2h]`` counts host copies that landed
    #: only AFTER a compute step ran between their gather launch and
    #: completion; ``overlapped[h2d]`` counts speculative scatters whose
    #: commit came after a compute step ran past their launch.
    overlapped: Dict[str, int] = dataclasses.field(default_factory=_zeroed)
    #: per-engine queue-depth high-water marks
    max_pending: Dict[str, int] = dataclasses.field(default_factory=_zeroed)
    #: speculative (background-lane) plan accounting
    prefetch_enqueued: int = 0
    prefetch_completed: int = 0    # speculative plans that executed
    prefetch_committed: int = 0    # commits (mapping promoted to device)
    prefetch_cancelled: int = 0
    #: Python-side overhead accounting (the PR 7 de-Pythonization
    #: target): ``python_launches`` counts per-plan visits in the
    #: dispatch walk -- the inner-loop bookkeeping the step loop pays in
    #: the interpreter; ``dispatches_per_step`` is ``dispatches`` per
    #: compute mark, refreshed on every ``note_compute()``.
    python_launches: int = 0
    dispatches_per_step: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _conflicts(earlier: TransferPlan, src: Set[int], dst: Set[int]) -> bool:
    """Must ``earlier`` execute before a plan reading ``src`` / writing
    ``dst`` of the same pool class?  Write-read, read-write and
    write-write order; read-read does not.  Uses the conflict sets
    precomputed at plan construction, not a fresh set walk."""
    return (bool(earlier._dst_ids & (src | dst))
            or bool(earlier._src_ids & dst))


class TransferEngine:
    """One DMA engine: a per-direction FIFO with its own epoch clock
    and priority lanes (see module docstring)."""

    __slots__ = ("direction", "_pending", "_dispatched", "_seq")

    def __init__(self, direction: str):
        self.direction = direction
        self._pending: List[TransferPlan] = []     # seqno order
        self._dispatched: List[TransferPlan] = []  # d2h two-phase
        self._seq = 0

    @property
    def epoch(self) -> int:
        """Highest seqno issued so far (-1 when virgin)."""
        return self._seq - 1

    def stamp(self, plan: TransferPlan) -> TransferPlan:
        plan.seqno = self._seq
        self._seq += 1
        return plan

    @property
    def depth(self) -> int:
        return len(self._pending) + len(self._dispatched)

    def unsettled(self) -> List[TransferPlan]:
        return self._pending + self._dispatched

    def prefix_done(self, epoch: int) -> bool:
        return not any(p.seqno <= epoch
                       for p in self._pending + self._dispatched)

    def launched_through(self, epoch: int) -> bool:
        """Every plan with seqno <= epoch has at least launched (a
        dispatched d2h gather has captured its snapshot)."""
        return not any(p.seqno <= epoch for p in self._pending)


class QueueSet:
    """Front-end over the per-direction ``TransferEngine``s: preserves
    the PR 4 producer API while executing on multiple queues with
    cross-queue fences (see module docstring)."""

    def __init__(self, arena: "Arena", eager: bool = False):
        self.arena = arena
        #: eager=True is the synchronous fallback: every enqueue drains
        #: immediately, pinning token-identical behavior for tests/CI.
        self.eager = eager
        self.stats = TransferStats()
        self.engines: Dict[str, TransferEngine] = {
            d: TransferEngine(d) for d in DIRECTIONS}
        self._compute_marks = 0
        # pool class -> (get_streams, set_streams, layered)
        self._executors: Dict[str, Tuple[Callable, Callable, bool]] = {}
        self._observers: Dict[object, Callable[[TransferPlan], None]] = {}

    # ---------------- wiring ----------------
    def register_executor(self, pool_class: str, get_streams: Callable,
                          set_streams: Callable,
                          layered: bool = True) -> None:
        """Bind the device streams of one pool class.

        ``get_streams()`` returns the current list of device arrays
        (layered: ``(L, NB, *block)``; flat: ``(NB, *block)``);
        ``set_streams(list)`` writes the updated arrays back.  The last
        registration wins (an arena handed to a new engine re-binds).
        """
        self._executors[pool_class] = (get_streams, set_streams, layered)

    def has_executor(self, pool_class: str) -> bool:
        """Whether a device payload exists for this pool class (classes
        without an executor complete plans as residency-only moves --
        snapshot/migration can carry no bytes for them)."""
        return pool_class in self._executors

    def is_layered(self, pool_class: str) -> bool:
        """Stream layout of the class's executor: layered streams are
        ``(L, NB, *block)``, flat streams ``(NB, *block)``."""
        return self._executors[pool_class][2]

    def add_observer(self, fn: Callable[[TransferPlan], None],
                     key: Optional[str] = None) -> None:
        """Called once per completed plan (byte ledgers, e.g.
        ``serve/swap.HostBlockStore``).

        A ``key``ed registration REPLACES any earlier observer with the
        same key -- the same last-wins rule as ``register_executor``, so
        re-handing an arena to a new engine does not accumulate (and
        retain) dead ledgers.
        """
        self._observers[key if key is not None else object()] = fn

    def unregister_executor(self, pool_class: str) -> None:
        """Symmetric teardown: drop the executor binding (refuses while
        plans that would need it are outstanding)."""
        if any(p.pool_class == pool_class
               for eng in self.engines.values() for p in eng.unsettled()):
            raise ValueError(
                f"pool class {pool_class!r} has outstanding plans; "
                f"drain() before unregistering its executor")
        self._executors.pop(pool_class, None)

    def remove_observer(self, key: str) -> None:
        self._observers.pop(key, None)

    def note_compute(self) -> None:
        """Mark that a compute step (decode) ran: a transfer launched
        before this mark and completed/committed after it genuinely
        overlapped compute (the per-engine ``overlapped`` stats)."""
        self._compute_marks += 1
        self.stats.dispatches_per_step = round(
            self.stats.dispatches / self._compute_marks, 4)

    # ---------------- queries ----------------
    @property
    def pending(self) -> int:
        """Plans not yet fully executed (pending + dispatched), summed
        over engines."""
        return sum(eng.depth for eng in self.engines.values())

    @property
    def has_undispatched(self) -> bool:
        """Plans whose device work has not launched (these may hold
        freed blocks; ``dispatch()`` releases the holds non-blocking)."""
        return any(eng._pending for eng in self.engines.values())

    def pending_by_direction(self) -> Dict[str, int]:
        return {d: eng.depth for d, eng in self.engines.items()}

    def queue_depths(self) -> Dict[str, Dict[str, int]]:
        """Per-engine live depth split by lane (the bench/report
        surface for the multi-queue refactor)."""
        out = {}
        for d, eng in self.engines.items():
            lanes = {lane: 0 for lane in LANES}
            for p in eng.unsettled():
                lanes[p.lane] += 1
            out[d] = lanes
        return out

    def in_transit(self, pool_class: str) -> List[object]:
        """Owners whose swap-out payload has not reached the host tier
        yet (enqueued or dispatched d2h)."""
        return [p.owner for p in self.engines[D2H].unsettled()
                if p.pool_class == pool_class]

    def in_flight_blocks(self, pool_class: str) -> set:
        """Device ids named as destination by any unexecuted plan."""
        out = set()
        for eng in self.engines.values():
            for p in eng._pending:
                if p.pool_class == pool_class and p.dst is not None:
                    out.update(int(b) for b in p.dst)
        return out

    def last_reference(self, pool_class: str, ids) -> Optional[Dict[str, int]]:
        """Per-engine epoch vector of the last PENDING plans that read
        or write one of ``ids``, or None when nothing does.

        Dispatched d2h plans have already captured their sources, so
        only undispatched plans pin device state.  ``Mapping.free``
        consults this: releasing blocks a pending plan still names
        would let reuse race the plan's execution -- a
        ``drain(upto=<this vector>)`` settles exactly the prefixes that
        matter and leaves later plans overlapped.
        """
        ids = set(int(b) for b in ids)
        epochs: Dict[str, int] = {}
        for d, eng in self.engines.items():
            for p in eng._pending:
                if p.pool_class != pool_class:
                    continue
                for vec in (p.src, p.dst):
                    if vec is not None and any(int(b) in ids for b in vec):
                        epochs[d] = p.seqno
        return epochs or None

    def last_transit(self, pool_class: str, owner) -> Optional[int]:
        """Highest d2h seqno of an unfenced swap-out of ``owner``
        (payload still in transit), or None -- the fence target for
        teardown and the complete-strength dep of a swap-in."""
        last = None
        for p in self.engines[D2H].unsettled():
            if p.pool_class == pool_class and p.owner == owner:
                last = max(p.seqno, last if last is not None else p.seqno)
        return last

    def fence(self) -> Fence:
        """Epoch-vector token covering everything enqueued so far on
        every engine."""
        return Fence(self, {d: eng.epoch
                            for d, eng in self.engines.items()})

    def _done_fence(self) -> Fence:
        """An already-complete fence (empty/no-op plans): waiting on it
        must not serialize unrelated pending transfers."""
        return Fence(self, {d: -1 for d in DIRECTIONS})

    # ---------------- producer API ----------------
    def enqueue_copy(self, pool_class: str, src, dst,
                     kind: str = "cow") -> Fence:
        """d2d: copy block src[i] -> dst[i] on every stream."""
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        if src.size == 0:
            return self._done_fence()
        nbytes = int(src.size) * self.arena.block_nbytes(pool_class)
        return self._enqueue(TransferPlan(D2D, pool_class, kind,
                                          src=src, dst=dst, nbytes=nbytes))

    def enqueue_swap_out(self, pool_class: str, owner, src,
                         kind: str = "swap-out",
                         lane: str = URGENT) -> Fence:
        """d2h: gather ``src`` on device, deposit the compact payload in
        the arena host tier under ``owner`` at the fence.

        ``lane=BACKGROUND`` is the live-migration pre-copy path: gathers
        of LIVE blocks (refcount > 0) take no holds -- they are pure
        reads that ride behind the urgent traffic while decode runs.
        """
        src = np.asarray(src, np.int32).reshape(-1)
        if src.size == 0:
            return self._done_fence()
        return self._enqueue(TransferPlan(D2H, pool_class, kind,
                                          src=src, owner=owner, lane=lane))

    def enqueue_swap_in(self, pool_class: str, owner, dst,
                        kind: str = "swap-in",
                        speculative: bool = False) -> Fence:
        """h2d: scatter ``owner``'s host payload into fresh ids ``dst``.

        ``speculative=True`` rides the background lane, PEEKS the host
        payload instead of consuming it, and stays cancellable while
        pending -- the prefetch half of the multi-queue plane.
        """
        dst = np.asarray(dst, np.int32).reshape(-1)
        if dst.size == 0:
            return self._done_fence()
        plan = TransferPlan(H2D, pool_class, kind, dst=dst, owner=owner,
                            lane=BACKGROUND if speculative else URGENT,
                            speculative=speculative)
        return self._enqueue(plan)

    def enqueue_prefetch(self, pool_class: str, owner, dst) -> TransferPlan:
        """Speculative swap-in on the background h2d lane; returns the
        PLAN (not a fence) so the producer can later ``cancel_plan`` it
        or promote it at commit (``Mapping.prefetch`` holds it)."""
        dst = np.asarray(dst, np.int32).reshape(-1)
        plan = TransferPlan(H2D, pool_class, "swap-in", dst=dst,
                            owner=owner, lane=BACKGROUND, speculative=True)
        self._enqueue(plan)
        return plan

    # ---------------- enqueue internals ----------------
    def _enqueue(self, plan: TransferPlan) -> Fence:
        eng = self.engines[plan.direction]
        eng.stamp(plan)
        self.stats.enqueued[plan.direction] += 1
        if plan.speculative:
            self.stats.prefetch_enqueued += 1
        if plan.pool_class not in self._executors:
            # metadata-only arena: no device payload exists, so the plan
            # completes immediately as a residency-only move (stamped
            # with the current compute mark: an inline completion never
            # overlapped anything)
            plan.state = DONE
            plan.dispatch_mark = self._compute_marks
            self.stats.completed[plan.direction] += 1
            if plan.speculative:
                self.stats.prefetch_completed += 1
            self._notify(plan)
            return Fence(self, {d: (plan.seqno if d == plan.direction
                                    else e.epoch)
                                for d, e in self.engines.items()})
        self._compute_deps(plan)
        self._mark(plan)
        eng._pending.append(plan)
        for d, e in self.engines.items():
            self.stats.max_pending[d] = max(self.stats.max_pending[d],
                                            e.depth)
        fence = Fence(self, {d: (plan.seqno if d == plan.direction
                                 else e.epoch)
                             for d, e in self.engines.items()})
        if self.eager:
            self.drain()
        return fence

    def _compute_deps(self, plan: TransferPlan) -> None:
        """Cross-queue fences, computed once at enqueue: the other
        engines' pending plans this plan conflicts with (launch
        strength), and -- for a swap-in -- the in-transit swap-out of
        the same owner (complete strength: its payload must have
        LANDED, not just launched).  In-engine ordering needs no deps:
        the FIFO plus the blocked-set scan in ``_engine_pass`` keep
        conflicting same-engine plans ordered.
        """
        src, dst = plan._src_ids, plan._dst_ids
        for d, eng in self.engines.items():
            if d == plan.direction:
                continue
            dep = None
            for p in eng._pending:
                if p.pool_class == plan.pool_class \
                        and _conflicts(p, src, dst):
                    dep = p.seqno
            if dep is not None:
                plan.deps[d] = dep
        if plan.direction == H2D:
            last = self.last_transit(plan.pool_class, plan.owner)
            if last is not None:
                plan.fdeps[D2H] = last

    def _mark(self, plan: TransferPlan) -> None:
        """Discipline marks: HOLD freed source blocks (a DMA reads them
        after the allocator let go -- they must not be reallocated
        before the gather launches) and flag destination leases
        ``in_flight`` (their payload is not there yet).  Holds are
        tagged with the reading engine's direction (the per-engine
        hold/release discipline)."""
        st = self.arena._cls(plan.pool_class)
        if plan.src is not None:
            for b in plan.src:
                b = int(b)
                if st.allocator.refcount(b) == 0:
                    if st.allocator.is_held(b):
                        # an earlier pending plan already holds it; move
                        # the hold to this (later) reader so it survives
                        # until the LAST gather over the block launches
                        for eng in self.engines.values():
                            for p in eng._pending:
                                if (p.pool_class == plan.pool_class
                                        and b in p._held):
                                    p._held.remove(b)
                                    break
                        st.allocator.retag_hold(b, plan.direction)
                    else:
                        st.allocator.hold(b, engine=plan.direction)
                    plan._held.append(b)
        if plan.dst is not None:
            for b in plan.dst:
                for lease in st.leases.get(int(b), []):
                    if not lease.in_flight:
                        lease.in_flight = True
                        plan._flagged.append(lease)

    def _release_holds(self, plan: TransferPlan) -> None:
        st = self.arena._cls(plan.pool_class)
        for b in plan._held:
            st.allocator.release_hold(b)
        plan._held = []

    def _clear_flags(self, plan: TransferPlan) -> None:
        for lease in plan._flagged:
            lease.in_flight = False
        plan._flagged = []

    def _notify(self, plan: TransferPlan) -> None:
        for fn in self._observers.values():
            fn(plan)

    # ---------------- cancellation (speculative plans) ----------------
    def cancel_plan(self, plan: TransferPlan) -> bool:
        """Withdraw a PENDING speculative plan: release its holds,
        clear its in-flight lease flags and drop it from its engine's
        FIFO.  The host payload (peeked, never taken, by speculative
        plans) stays intact for a later real swap-in.  Returns False
        when the plan already launched (cancel then means the caller
        releases the now-materialized destination normally)."""
        if plan.state != PENDING:
            return False
        if not plan.speculative:
            raise ValueError(
                f"only speculative plans may be cancelled, got {plan!r}")
        self.engines[plan.direction]._pending.remove(plan)
        self._release_holds(plan)
        self._clear_flags(plan)
        plan.state = CANCELLED
        self.stats.prefetch_cancelled += 1
        return True

    def note_prefetch_commit(self, plan: TransferPlan) -> None:
        """A speculative swap-in was promoted to the real resume; if a
        compute step ran between its scatter launch and this commit,
        the prefetch genuinely overlapped decode (``overlapped[h2d]``
        -- NOT the d2h double-buffer counter; that conflation was the
        PR 5 stats bug).  Observers are re-notified with
        ``plan.committed`` set so byte ledgers fold the parked
        speculative bytes into their demand accounting no matter which
        client performed the resume (``Mapping.migrate`` auto-commit
        included, not just the serving engine)."""
        self.stats.prefetch_committed += 1
        plan.committed = True
        if plan.state == DONE:
            if self._compute_marks > plan.dispatch_mark:
                self.stats.overlapped[H2D] += 1
            self._notify(plan)

    def note_prefetch_abandon(self, plan: TransferPlan) -> None:
        """An EXECUTED speculative swap-in was cancelled: its scatter
        ran for nothing.  Count the waste and re-notify observers with
        ``plan.abandoned`` set so ledgers write the parked bytes off."""
        self.stats.prefetch_cancelled += 1
        plan.abandoned = True
        self._notify(plan)

    # ---------------- execution ----------------
    def dispatch(self, upto: Optional[Dict[str, int]] = None,
                 lanes: Optional[Iterable[str]] = None) -> None:
        """Execute d2d/h2d plans; LAUNCH d2h gathers, deferring their
        host copies to the next ``complete_dispatched``/``drain`` (the
        double-buffer half of the step loop).  ``lanes`` restricts to a
        lane subset (the step loop dispatches the background prefetch
        lane separately, after the urgent critical path).

        Empty-lane fast path: when no pending plan matches the lane
        filter there is nothing to launch, release or unblock -- skip
        the fixpoint entirely (and the ``dispatches`` counter, so the
        stat counts scheduling WORK, not step-loop calls: the serving
        loop dispatches 2+ lanes every step, overwhelmingly no-ops).
        """
        lane_set = None if lanes is None else set(lanes)
        if not any(lane_set is None or p.lane in lane_set
                   for eng in self.engines.values() for p in eng._pending):
            return
        self.stats.dispatches += 1
        self._run_dispatch(self._closure(upto), lanes)

    def complete_dispatched(self, upto: Optional[Dict[str, int]] = None
                            ) -> None:
        """Fence phase: land every launched-but-uncopied d2h payload.
        Skipped (no counter) when nothing was dispatched."""
        if not self.engines[D2H]._dispatched:
            return
        self.stats.fences += 1
        self._run_complete(upto)

    def drain(self, upto: Optional[Dict[str, int]] = None) -> None:
        """Synchronous fallback: execute everything (or the fenced
        epoch-vector prefix, expanded to its cross-queue dependency
        closure) now.  Skipped (no counter) when the plane is empty."""
        if self.pending == 0:
            return
        self.stats.drains += 1
        limits = self._closure(upto)
        self._run_dispatch(limits, None)
        self._run_complete(limits)

    def _closure(self, upto: Optional[Dict[str, int]]
                 ) -> Optional[Dict[str, int]]:
        """Expand an epoch vector until it covers the cross-queue
        dependencies of every plan it names -- draining a d2h prefix
        must also drain the d2d copies those gathers wait on."""
        if upto is None:
            return None
        limits = {d: upto.get(d, -1) for d in DIRECTIONS}
        changed = True
        while changed:
            changed = False
            for d, eng in self.engines.items():
                for p in eng._pending:
                    if p.seqno > limits[d]:
                        continue
                    for dep in (p.deps, p.fdeps):
                        for dd, e in dep.items():
                            if e > limits[dd]:
                                limits[dd] = e
                                changed = True
        return limits

    def _run_dispatch(self, limits: Optional[Dict[str, int]],
                      lanes: Optional[Iterable[str]]) -> None:
        """Iterative fixpoint over engines: every pass executes the
        plans whose cross-queue dependencies are settled and skips the
        rest; skipped plans unblock as the engines they wait on
        progress.  Dependencies point backwards in enqueue time, so the
        loop terminates.  The d2h engine goes first each round so
        independent gathers launch ahead of the copies/scatters they do
        not depend on (the reorder window).

        Each pass also reports how many in-scope plans it left behind:
        when every engine comes back empty the fixpoint is reached and
        the loop exits WITHOUT the classic extra no-progress
        verification round -- the common single-phase step pays exactly
        one walk per engine (the ``python_launches`` stat counts the
        per-plan visits those walks cost)."""
        lanes = None if lanes is None else set(lanes)
        while True:
            progressed, remaining = False, 0
            for d in (D2H, D2D, H2D):
                prog, left = self._engine_pass(d, limits, lanes)
                progressed |= prog
                remaining += left
            if not remaining or not progressed:
                break

    def _engine_pass(self, direction: str,
                     limits: Optional[Dict[str, int]],
                     lanes: Optional[Set[str]]) -> Tuple[bool, int]:
        """One scheduling pass over one engine's FIFO: batch and run
        every eligible plan; skipped plans (lane-filtered, beyond the
        fence limit, or waiting on another engine) block exactly the
        later plans that conflict with them -- independent plans
        execute PAST them, which is what lets d2h gathers coalesce
        across an intervening dependency.

        Eligibility reads the conflict keys precomputed at plan
        construction (``_skey``/``_dkey``) -- the walk does no per-plan
        set building.  Returns ``(progressed, remaining)`` where
        ``remaining`` counts in-scope (lane-matched, within-limit)
        plans still pending, so the fixpoint driver can stop the moment
        the FIFOs are clear instead of running one more empty round."""
        eng = self.engines[direction]
        limit = None if limits is None else limits[direction]
        blocked_src: Set[Tuple[str, int]] = set()   # (pool_class, block)
        blocked_dst: Set[Tuple[str, int]] = set()
        skipped_min: Optional[int] = None
        batch: List[TransferPlan] = []
        batch_dsts: Set[Tuple[str, int]] = set()
        progressed = False
        remaining = 0

        def flush():
            nonlocal progressed, batch, batch_dsts
            if not batch:
                return
            for p in batch:
                eng._pending.remove(p)
            if skipped_min is not None:
                self.stats.reordered += sum(1 for p in batch
                                            if p.seqno > skipped_min)
            if direction == D2D:
                self._exec_copies(batch)
            elif direction == D2H:
                self._dispatch_gathers(batch)
            else:
                for p in batch:
                    self._exec_swap_in(p)
            progressed = True
            batch, batch_dsts = [], set()

        for plan in list(eng._pending):
            if limit is not None and plan.seqno > limit:
                break                      # FIFO is seqno-ordered
            self.stats.python_launches += 1
            skey, dkey = plan._skey, plan._dkey
            in_lane = lanes is None or plan.lane in lanes
            eligible = in_lane \
                and not (skey & blocked_dst) \
                and not (dkey & (blocked_dst | blocked_src)) \
                and self._deps_settled(plan)
            if not eligible:
                blocked_src |= skey
                blocked_dst |= dkey
                if skipped_min is None:
                    skipped_min = plan.seqno
                if in_lane:
                    remaining += 1
                continue
            if batch and (plan.pool_class != batch[0].pool_class
                          or (skey & batch_dsts) or (dkey & batch_dsts)):
                # depends on a copy already in the batch (or targets the
                # same block): it must not share the batch's snapshot
                flush()
            batch.append(plan)
            batch_dsts |= dkey
        flush()
        return progressed, remaining

    def _deps_settled(self, plan: TransferPlan) -> bool:
        """Launch-strength deps must have left PENDING; complete-
        strength deps must be DONE -- when their gathers have launched
        but the host copies are still deferred, land those copies now
        (the price of resuming an owner whose swap-out never fenced)."""
        for d, e in plan.deps.items():
            if not self.engines[d].launched_through(e):
                return False
        for d, e in plan.fdeps.items():
            eng = self.engines[d]
            if not eng.launched_through(e):
                return False
            if not eng.prefix_done(e):
                self._run_complete({dd: (e if dd == d else -1)
                                    for dd in DIRECTIONS})
        return True

    def _streams(self, pool_class: str):
        get, set_, layered = self._executors[pool_class]
        return get(), set_, layered

    def _exec_copies(self, batch: List[TransferPlan]) -> None:
        from repro.kernels import ops
        import jax.numpy as jnp
        src = jnp.asarray(np.concatenate([p.src for p in batch]), jnp.int32)
        dst = jnp.asarray(np.concatenate([p.dst for p in batch]), jnp.int32)
        streams, set_, layered = self._streams(batch[0].pool_class)
        copy = ops.copy_pool_blocks if layered else ops.block_copy
        set_([copy(s, src, dst) for s in streams])
        self.stats.launches += 1
        self.stats.coalesced += len(batch) - 1
        self.arena.allocator(batch[0].pool_class).note_write(
            [int(b) for b in np.asarray(dst)])
        for plan in batch:
            self._release_holds(plan)
            self._clear_flags(plan)
            plan.state = DONE
            plan.dispatch_mark = self._compute_marks
            self.stats.completed[D2D] += 1
            self.stats.bytes_moved[D2D] += plan.nbytes
            self._notify(plan)

    def _dispatch_gathers(self, batch: List[TransferPlan]) -> None:
        """Launch ONE device gather over the batch's concatenated ids
        (multi-plan) and slice per plan; the blocking host copies wait
        for the fence.  Holds release here: the gather has captured the
        functional snapshot, so the ids are safely reusable."""
        from repro.kernels import ops
        import jax.numpy as jnp
        ids = jnp.asarray(np.concatenate([p.src for p in batch]), jnp.int32)
        streams, _, layered = self._streams(batch[0].pool_class)
        gathered = [ops.gather_blocks(s, ids) if layered else s[ids]
                    for s in streams]
        self.stats.launches += 1
        self.stats.coalesced += len(batch) - 1
        off = 0
        for plan in batch:
            n = plan.src.size
            plan._gathered = [(g[:, off:off + n] if layered
                               else g[off:off + n]) for g in gathered]
            off += n
            self._release_holds(plan)
            plan.state = DISPATCHED
            plan.dispatch_mark = self._compute_marks
            self.engines[D2H]._dispatched.append(plan)

    def _run_complete(self, limits: Optional[Dict[str, int]] = None) -> None:
        eng = self.engines[D2H]
        limit = None if limits is None else limits.get(D2H, eng.epoch)
        for plan in [p for p in eng._dispatched
                     if limit is None or p.seqno <= limit]:
            eng._dispatched.remove(plan)
            self._complete(plan)

    def _complete(self, plan: TransferPlan) -> None:
        host = tuple(np.asarray(g) for g in plan._gathered)
        plan._gathered = None
        plan.nbytes = int(sum(h.nbytes for h in host))
        self.arena.host_deposit(plan.pool_class, plan.owner, host,
                                plan.nbytes)
        plan.state = DONE
        self.stats.launches += 1                 # the host copy itself
        self.stats.completed[D2H] += 1
        self.stats.bytes_moved[D2H] += plan.nbytes
        if self._compute_marks > plan.dispatch_mark:
            self.stats.overlapped[D2H] += 1      # a decode ran in between
        self._notify(plan)

    def _exec_swap_in(self, plan: TransferPlan) -> None:
        from repro.kernels import ops
        import jax.numpy as jnp
        cls, owner = plan.pool_class, plan.owner
        if not self.arena.host_contains(cls, owner):
            # belt-and-suspenders behind the fdep mechanism: the payload
            # is still in a dispatched d2h of the same owner (preempt +
            # immediate resume): land it first, in FIFO order
            d2h = self.engines[D2H]
            for p in [p for p in d2h._dispatched
                      if p.pool_class == cls and p.owner == owner]:
                d2h._dispatched.remove(p)
                self._complete(p)
        payload = (self.arena.host_peek(cls, owner) if plan.speculative
                   else self.arena.host_take(cls, owner))
        idx = jnp.asarray(plan.dst, jnp.int32)
        streams, set_, layered = self._streams(cls)
        if len(payload) != len(streams):
            raise ValueError(
                f"swap-in of {owner!r}: payload has {len(payload)} "
                f"streams, executor exposes {len(streams)}")
        n = int(plan.dst.size)
        for h in payload:
            saved = (h.shape[1] if layered else h.shape[0]) \
                if h is not None else n
            if saved != n:
                raise ValueError(
                    f"swap-in of {owner!r}: {saved} saved blocks into "
                    f"{n} fresh ids")
        out = [s if h is None
               else ops.scatter_blocks(s, idx, jnp.asarray(h)) if layered
               else s.at[idx].set(jnp.asarray(h))
               for s, h in zip(streams, payload)]
        set_(out)
        self.arena.allocator(cls).note_write(
            [int(b) for b in np.asarray(plan.dst)])
        plan.nbytes = int(sum(h.nbytes for h in payload if h is not None))
        self._clear_flags(plan)
        plan.state = DONE
        plan.dispatch_mark = self._compute_marks
        self.stats.launches += 1
        self.stats.completed[H2D] += 1
        self.stats.bytes_moved[H2D] += plan.nbytes
        if plan.speculative:
            self.stats.prefetch_completed += 1
        self._notify(plan)


#: PR 4 name, kept so every existing producer/import keeps working: the
#: front-end IS the queue set now.
TransferQueue = QueueSet
