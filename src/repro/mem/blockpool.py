"""Fixed-size block pool: the paper's physical-memory allocator.

The paper's OS hands out fixed-size blocks (32 KB) as the minimum
allocation unit and *never* promises large contiguous regions.  On TPU,
HBM is physically addressed already; we model the paper's allocator as

  * a device-resident ``pool`` array of shape ``(num_blocks, *block_shape)``
    (one contiguous physical arena, carved into fixed blocks), and
  * a host-side ``BlockAllocator`` (free list + refcounts) that plays the
    role of the paper's simple OS memory manager.

Device code never sees pointers -- only ``int32`` block ids, which is
exactly the paper's "software page table" discipline.  Copy-on-write is
supported via refcounts so that block tables can alias blocks (used by
the serving engine for shared prefixes, mirroring vLLM-style sharing --
an instance of the paper's claim that software can re-create VM features
it actually needs).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NULL_BLOCK = -1  # sentinel "unmapped" entry in block tables


class OutOfBlocksError(RuntimeError):
    """Raised when the pool has no free blocks (the paper's OOM analogue)."""


class BlockAllocator:
    """Host-side free-list allocator with refcounts (COW support).

    This is deliberately simple -- the paper argues a fixed-block OS
    allocator *can* be this simple because external fragmentation is
    impossible: every request is exactly one block.
    """

    def __init__(self, num_blocks: int):
        if num_blocks <= 0:
            raise ValueError(f"num_blocks must be positive, got {num_blocks}")
        self.num_blocks = int(num_blocks)
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._refcount = np.zeros(num_blocks, dtype=np.int32)
        # block -> the DMA engine (transfer direction) holding it; the
        # per-engine discipline lets the transfer plane release one
        # engine's holds without fencing the others
        self._held: Dict[int, str] = {}
        # monotone per-block write-generation counter: every writer of a
        # block's payload (COW fulfilment copies, swap-in scatters,
        # append_token decode writes via the strategy barrier) bumps it.
        # Live migration diffs generations between pre-copy rounds to
        # find the dirty set -- the software analogue of dirty-page bits.
        self._write_gen = np.zeros(num_blocks, dtype=np.int64)

    # -- queries ---------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - len(self._free) - len(self._held)

    @property
    def num_held(self) -> int:
        return len(self._held)

    def refcount(self, block: int) -> int:
        return int(self._refcount[block])

    def used_ids(self) -> np.ndarray:
        """Ascending ids of all currently allocated blocks."""
        return np.nonzero(self._refcount > 0)[0]

    def is_allocated(self, block: int) -> bool:
        return self._refcount[block] > 0

    # -- write generations (dirty tracking for live migration) ----------
    def note_write(self, blocks: Sequence[int]) -> None:
        """Record that the payload of ``blocks`` was (or is about to be)
        written.  Conservative pre-write bumps are fine: an extra copy in
        the next migration round is cheap; a missed one is corruption."""
        for b in blocks:
            if b != NULL_BLOCK:
                self._write_gen[b] += 1

    def write_gen(self, block: int) -> int:
        return int(self._write_gen[block])

    def write_gens(self, blocks: Sequence[int]) -> np.ndarray:
        return self._write_gen[np.asarray(blocks, dtype=np.int64)]

    # -- allocation ------------------------------------------------------
    def alloc(self) -> int:
        if not self._free:
            raise OutOfBlocksError("block pool exhausted")
        b = self._free.pop()
        self._refcount[b] = 1
        # a fresh allocation is about to be written (prefill scatter,
        # growth, copy target): bump conservatively so a migration that
        # copied this id under a previous tenant re-copies it
        self._write_gen[b] += 1
        return b

    def alloc_many(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocksError(
                f"requested {n} blocks, only {len(self._free)} free"
            )
        return [self.alloc() for _ in range(n)]

    def share(self, block: int) -> int:
        """Increment refcount (a block-table aliases this block)."""
        if self._refcount[block] <= 0:
            raise ValueError(f"share of unallocated block {block}")
        self._refcount[block] += 1
        return block

    def free(self, block: int) -> None:
        if block == NULL_BLOCK:
            return
        if self._refcount[block] <= 0:
            raise ValueError(f"double free of block {block}")
        self._refcount[block] -= 1
        if self._refcount[block] == 0:
            self._free.append(block)

    def free_many(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            self.free(int(b))

    # -- transfer-plane holds (per-engine) ------------------------------
    def hold(self, block: int, engine: str = "dma") -> None:
        """Remove a FREED block from the free list without allocating it.

        The transfer plane holds the vacated sources of an unfenced DMA
        (swap-out gather, compaction copy): the allocator let go of the
        ids, but the device still has to read them -- handing them out
        before the gather launches would let a prefill/scatter clobber
        the payload mid-flight.  Each hold is tagged with the DMA
        engine (direction) that will read the block -- since holds are
        released plan-by-plan as each per-direction queue dispatches,
        the tags attribute every outstanding hold to the engine
        responsible for it (``held_by_engine`` feeds ``ArenaStats``, so
        a stalled queue's pinned blocks are visible per engine).
        ``release_hold`` returns them.
        """
        if self._refcount[block] != 0 or block in self._held:
            raise ValueError(f"hold of non-free block {block}")
        self._free.remove(block)
        self._held[block] = engine

    def retag_hold(self, block: int, engine: str) -> None:
        """Move an existing hold to another engine (a later plan in a
        different queue became the block's last reader)."""
        if block not in self._held:
            raise ValueError(f"retag_hold of unheld block {block}")
        self._held[block] = engine

    def is_held(self, block: int) -> bool:
        return block in self._held

    def held_ids(self) -> set:
        return set(self._held)

    def held_by(self, engine: str) -> set:
        """Blocks held on behalf of one DMA engine (direction)."""
        return {b for b, e in self._held.items() if e == engine}

    def held_by_engine(self) -> Dict[str, int]:
        """Outstanding holds per DMA engine (the ``ArenaStats``
        attribution surface: which queue is pinning vacated blocks)."""
        out: Dict[str, int] = {}
        for e in self._held.values():
            out[e] = out.get(e, 0) + 1
        return out

    def release_hold(self, block: int) -> None:
        if block not in self._held:
            raise ValueError(f"release_hold of unheld block {block}")
        del self._held[block]
        self._free.append(block)

    def fork_for_write(self, block: int) -> Tuple[int, bool]:
        """COW: return a private block id for writing.

        If refcount == 1 the caller already owns it exclusively; otherwise
        allocate a fresh block (caller must copy payload) and drop one ref
        on the shared one.  Returns (block_id, needs_copy).
        """
        if self._refcount[block] <= 0:
            raise ValueError(f"fork of unallocated block {block}")
        if self._refcount[block] == 1:
            return block, False
        fresh = self.alloc()
        self.free(block)
        return fresh, True

    # -- relocation (defrag / compaction) -------------------------------
    def relocate(self, plan: Sequence[Tuple[int, int]]) -> None:
        """Apply a (src, dst) move plan to the id space.

        Refcounts travel with blocks; the free list is rebuilt so the
        vacated sources become allocatable again.  The caller is
        responsible for (a) copying payloads src -> dst on device and
        (b) rewriting every table/lease that names a moved id -- the
        Arena's ``compact()`` does all three in one motion.
        """
        for s, d in plan:
            if self._refcount[s] <= 0:
                raise ValueError(f"relocate of unallocated block {s}")
            if self._refcount[d] != 0:
                raise ValueError(f"relocate into live block {d}")
            self._refcount[d] = self._refcount[s]
            self._refcount[s] = 0
            # generations travel with the payload; the d2d copy that
            # fulfils the plan bumps the destination when it executes.
            self._write_gen[d] = self._write_gen[s]
        self._free = [b for b in range(self.num_blocks - 1, -1, -1)
                      if self._refcount[b] == 0 and b not in self._held]

    def refcount_histogram(self) -> "np.ndarray":
        """histogram[r] = number of blocks currently at refcount r."""
        return np.bincount(self._refcount,
                           minlength=2).astype(np.int64)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockPool:
    """Device-side arena of fixed-size blocks.

    ``data`` has shape ``(num_blocks, *block_shape)``.  All updates are
    functional (return a new BlockPool sharing the updated buffer).
    """

    data: jax.Array  # (num_blocks, *block_shape)

    # pytree plumbing ------------------------------------------------------
    def tree_flatten(self):
        return (self.data,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0])

    # constructors ---------------------------------------------------------
    @classmethod
    def create(cls, num_blocks: int, block_shape: Tuple[int, ...],
               dtype=jnp.float32) -> "BlockPool":
        return cls(jnp.zeros((num_blocks, *block_shape), dtype=dtype))

    # properties -----------------------------------------------------------
    @property
    def num_blocks(self) -> int:
        return self.data.shape[0]

    @property
    def block_shape(self) -> Tuple[int, ...]:
        return self.data.shape[1:]

    @property
    def block_nbytes(self) -> int:
        return int(np.prod(self.block_shape)) * self.data.dtype.itemsize

    # block ops --------------------------------------------------------
    def read(self, block: jax.Array) -> jax.Array:
        """Gather one or many blocks.  ``block`` may be scalar or int array."""
        return jnp.take(self.data, block, axis=0, mode="clip")

    def write(self, block, payload) -> "BlockPool":
        """Scatter one or many whole blocks (scalar or int-array ids)."""
        return BlockPool(self.data.at[jnp.asarray(block)].set(payload))

    def copy_block(self, src, dst) -> "BlockPool":
        """Physical block copy (COW fulfilment / defrag / swap-in)."""
        return BlockPool(self.data.at[dst].set(self.data[src]))

    def copy_blocks(self, src: jax.Array, dst: jax.Array) -> "BlockPool":
        return BlockPool(self.data.at[dst].set(self.data[src]))
