"""Typed block handles: the unit clients hold instead of raw ``int`` ids.

A ``Lease`` names exactly one block of one Arena pool class.  It is a
*mutable* handle: compaction may relocate the underlying physical block,
in which case the Arena rewrites ``lease.block`` in place -- holders
never see stale ids, which is the whole point of routing every client
through one address space (paper Table 1 row 'Relocation / Migration').

Kinds (derived, not stored -- a lease's kind changes as refcounts move):

  * ``exclusive``  -- this lease is the block's only holder (refcount 1);
    writes are safe.
  * ``cow-shared`` -- other leases alias the same block (refcount > 1);
    a write requires ``Mapping.ensure_writable`` first.
  * ``pinned``     -- permanently claimed, never handed to a sequence
    (the serving engine's write-sink block); released only via
    ``Arena.unpin``.
  * ``in-flight``  -- an unfenced transfer plan targets this block (COW
    copy destination, compaction destination, swap-in scatter target):
    the payload is not there yet.  Reads must fence first --
    ``Mapping.assert_settled`` raises ``UnfencedReadError`` otherwise.
    Set/cleared by ``mem/transfer.py``, never by clients.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from repro.mem.arena import Arena

EXCLUSIVE = "exclusive"
COW_SHARED = "cow-shared"
PINNED = "pinned"
IN_FLIGHT = "in-flight"


class Lease:
    """One holder's claim on one block of one pool class."""

    __slots__ = ("arena", "pool_class", "block", "owner", "pinned", "live",
                 "in_flight")

    def __init__(self, arena: "Arena", pool_class: str, block: int,
                 owner, pinned: bool = False):
        self.arena = arena
        self.pool_class = pool_class
        self.block = int(block)
        self.owner = owner
        self.pinned = pinned
        self.live = True
        self.in_flight = False

    # -- queries ---------------------------------------------------------
    @property
    def refcount(self) -> int:
        return self.arena.refcount(self.pool_class, self.block)

    @property
    def shared(self) -> bool:
        return self.refcount > 1

    @property
    def kind(self) -> str:
        if self.pinned:
            return PINNED
        if self.in_flight:
            return IN_FLIGHT
        return COW_SHARED if self.shared else EXCLUSIVE

    # -- verbs (delegate to the arena so bookkeeping stays centralized) --
    def share(self, owner) -> "Lease":
        """Alias this block under a new lease (COW: refcount++)."""
        return self.arena.share(self, owner)

    def release(self) -> None:
        self.arena.release(self)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Lease({self.pool_class}[{self.block}] owner={self.owner} "
                f"kind={self.kind if self.live else 'dead'})")
