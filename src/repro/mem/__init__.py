"""repro.mem -- the unified software address space (see README.md).

One ``Arena`` behind every block-backed subsystem: typed ``Lease``
handles instead of raw ints, ``Mapping`` page tables with
``fork``/``ensure_writable``/``migrate`` as the only mutation verbs, a
host swap tier as a first-class placement level, pressure-time reclaim
(LIFO preemption) as arena policy, ``compact()`` as the defrag pass,
and the multi-queue transfer plane (a ``TransferEngine`` per direction
with urgent/background lanes behind a ``QueueSet`` front-end,
cross-queue ``Fence`` epoch vectors, speculative swap-in prefetch)
behind every block copy, swap and migration.
"""

from repro.mem.arena import Arena, LeaseRevokedError
from repro.mem.blockpool import (NULL_BLOCK, BlockAllocator, BlockPool,
                                 OutOfBlocksError)
from repro.mem.lease import COW_SHARED, EXCLUSIVE, IN_FLIGHT, PINNED, Lease
from repro.mem.mapping import DEVICE, FLAT, HOST, RADIX, Mapping
from repro.mem.migrate import (BlockBundle, MigrationSession,
                               adopt_payload, export_mapping)
from repro.mem.stats import ArenaStats, PoolClassStats
from repro.mem.transfer import (BACKGROUND, D2D, D2H, DIRECTIONS, H2D,
                                LANES, URGENT, Fence, QueueSet,
                                TransferEngine, TransferPlan,
                                TransferQueue, TransferStats,
                                UnfencedReadError)

__all__ = [
    "Arena", "LeaseRevokedError",
    "BlockAllocator", "BlockPool", "NULL_BLOCK", "OutOfBlocksError",
    "Lease", "EXCLUSIVE", "COW_SHARED", "PINNED", "IN_FLIGHT",
    "Mapping", "FLAT", "RADIX", "DEVICE", "HOST",
    "MigrationSession", "BlockBundle", "export_mapping", "adopt_payload",
    "ArenaStats", "PoolClassStats",
    "QueueSet", "TransferEngine", "TransferQueue", "TransferPlan",
    "TransferStats", "Fence", "UnfencedReadError",
    "D2D", "D2H", "H2D", "DIRECTIONS", "URGENT", "BACKGROUND", "LANES",
]
