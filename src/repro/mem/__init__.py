"""repro.mem -- the unified software address space (see README.md).

One ``Arena`` behind every block-backed subsystem: typed ``Lease``
handles instead of raw ints, ``Mapping`` page tables with
``fork``/``ensure_writable``/``migrate`` as the only mutation verbs, a
host swap tier as a first-class placement level, pressure-time reclaim
(LIFO preemption) as arena policy, and ``compact()`` as the defrag pass.
"""

from repro.mem.arena import Arena, LeaseRevokedError
from repro.mem.blockpool import (NULL_BLOCK, BlockAllocator, BlockPool,
                                 OutOfBlocksError)
from repro.mem.lease import COW_SHARED, EXCLUSIVE, PINNED, Lease
from repro.mem.mapping import DEVICE, FLAT, HOST, RADIX, Mapping
from repro.mem.stats import ArenaStats, PoolClassStats

__all__ = [
    "Arena", "LeaseRevokedError",
    "BlockAllocator", "BlockPool", "NULL_BLOCK", "OutOfBlocksError",
    "Lease", "EXCLUSIVE", "COW_SHARED", "PINNED",
    "Mapping", "FLAT", "RADIX", "DEVICE", "HOST",
    "ArenaStats", "PoolClassStats",
]
