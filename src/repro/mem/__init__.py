"""repro.mem -- the unified software address space (see README.md).

One ``Arena`` behind every block-backed subsystem: typed ``Lease``
handles instead of raw ints, ``Mapping`` page tables with
``fork``/``ensure_writable``/``migrate`` as the only mutation verbs, a
host swap tier as a first-class placement level, pressure-time reclaim
(LIFO preemption) as arena policy, ``compact()`` as the defrag pass,
and the asynchronous transfer plane (``TransferQueue``/``Fence``) behind
every block copy, swap and migration.
"""

from repro.mem.arena import Arena, LeaseRevokedError
from repro.mem.blockpool import (NULL_BLOCK, BlockAllocator, BlockPool,
                                 OutOfBlocksError)
from repro.mem.lease import COW_SHARED, EXCLUSIVE, IN_FLIGHT, PINNED, Lease
from repro.mem.mapping import DEVICE, FLAT, HOST, RADIX, Mapping
from repro.mem.stats import ArenaStats, PoolClassStats
from repro.mem.transfer import (D2D, D2H, DIRECTIONS, H2D, Fence,
                                TransferPlan, TransferQueue, TransferStats,
                                UnfencedReadError)

__all__ = [
    "Arena", "LeaseRevokedError",
    "BlockAllocator", "BlockPool", "NULL_BLOCK", "OutOfBlocksError",
    "Lease", "EXCLUSIVE", "COW_SHARED", "PINNED", "IN_FLIGHT",
    "Mapping", "FLAT", "RADIX", "DEVICE", "HOST",
    "ArenaStats", "PoolClassStats",
    "TransferQueue", "TransferPlan", "TransferStats", "Fence",
    "UnfencedReadError", "D2D", "D2H", "H2D", "DIRECTIONS",
]
