"""Live migration of an Arena between engines: incremental pre-copy +
stop-and-copy, and the block-handoff bundles behind prefill/decode
disaggregation.

The paper's closing argument is that explicitly-managed physical memory
makes data movement a first-class, schedulable resource.  This module is
that argument applied to a WHOLE address space: because every payload
move is already a transfer-plane plan and every table an id-indirected
``Mapping``, moving a serving engine's memory to another process needs
no new device mechanism -- only a dirty-tracking loop over the verbs
that already exist:

  * **pre-copy rounds** (``MigrationSession.begin_round`` /
    ``collect_round``): gather the blocks whose write generation changed
    since their last copy, on the BACKGROUND d2h lane, while decode
    keeps running.  Gathers of live blocks (refcount > 0) take no
    allocator holds -- they are pure reads, the software analogue of
    DMA-ing pages a process still maps;
  * **dirty tracking**: ``BlockAllocator`` keeps a per-block
    write-generation counter bumped by every writer (COW fulfilment
    copies, swap-in scatters, fresh allocations, the strategies'
    per-step append-token barrier).  A block is dirty when its current
    generation differs from the generation recorded at its last copy --
    the software dirty bit the paper's no-VM hardware lacks;
  * **convergence**: with decode running the dirty set never reaches
    zero (every running sequence keeps appending into its tail block);
    it CONVERGES when it stops shrinking -- the residue is the working
    set, one tail block per running sequence, which bounds the
    stop-and-copy pause by the running-set size, not the pool size;
  * **stop-and-copy** (``finalize``): with the engine paused between
    steps, re-gather the dirty tail, assemble the full device payload
    from the pre-copied store, and write one ``Arena.snapshot`` with
    ``device_payloads`` -- refcounts, COW aliasing and per-tenant tags
    all ride the mapping tables.

``export_mapping``/``adopt_payload`` reuse the same gather/scatter pair
for ONE mapping: the prefill/decode-disaggregation handoff
(``serve/disagg.py``) -- a prefill worker deposits a finished sequence's
blocks as a ``BlockBundle``, a decode worker adopts them onto fresh ids.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.mem.arena import Arena
from repro.mem.mapping import DEVICE, Mapping
from repro.mem.transfer import BACKGROUND


def _live_device_ids(arena: Arena, cls: str) -> List[int]:
    """Ordered union of block ids named by device-resident mappings."""
    out: List[int] = []
    seen = set()
    for m in arena._cls(cls).mappings:
        if m.placement != DEVICE:
            continue
        for b in m.block_ids():
            if b not in seen:
                seen.add(b)
                out.append(b)
    return out


class MigrationSession:
    """Incremental live migration of every executor-backed pool class.

    Usage (the engine keeps stepping between rounds)::

        sess = MigrationSession(engine.arena)
        while not sess.converged():
            sess.begin_round()       # background gathers enqueued
            engine.step()            # decode overlaps the pre-copy
            sess.collect_round()     # payload landed; record gens
        sess.finalize(path)          # short stop-and-copy + snapshot

    ``migration_report()`` exposes rounds, blocks/bytes per round and
    the stop-and-copy tail size -- the acceptance surface the
    ``migrate_probe`` gates in CI.
    """

    def __init__(self, arena: Arena,
                 pool_classes: Optional[List[str]] = None,
                 max_rounds: int = 8):
        self.arena = arena
        self.classes = [c for c in (pool_classes or arena.pool_classes)
                        if arena.transfers.has_executor(c)]
        self.max_rounds = int(max_rounds)
        #: block id -> write generation recorded at its last copy
        self._copied_gen: Dict[str, Dict[int, int]] = {
            c: {} for c in self.classes}
        #: block id -> per-stream host slices from its last copy
        self._store: Dict[str, Dict[int, Tuple]] = {
            c: {} for c in self.classes}
        self._rounds: List[dict] = []
        self._pending: Optional[Dict[str, Tuple]] = None
        self._stop = {"blocks": 0, "bytes": 0}
        self.pause_steps = 0
        self.finalized = False

    # -- dirty tracking --------------------------------------------------
    def _dirty_ids(self, cls: str) -> Tuple[List[int], List[int]]:
        alloc = self.arena._cls(cls).allocator
        ids, gens = [], []
        for b in _live_device_ids(self.arena, cls):
            g = alloc.write_gen(b)
            if self._copied_gen[cls].get(b) != g:
                ids.append(b)
                gens.append(g)
        return ids, gens

    def dirty_count(self) -> int:
        return sum(len(self._dirty_ids(c)[0]) for c in self.classes)

    def converged(self) -> bool:
        """The dirty set stopped shrinking (the residue is the working
        set -- under live decode it never reaches zero), or the round
        budget ran out."""
        if len(self._rounds) >= self.max_rounds:
            return True
        if self._rounds and self._rounds[-1]["blocks"] == 0:
            return True
        if len(self._rounds) < 2:
            return False
        return self._rounds[-1]["blocks"] >= self._rounds[-2]["blocks"]

    # -- pre-copy rounds -------------------------------------------------
    def begin_round(self) -> int:
        """Enqueue background gathers of every dirty block; returns how
        many blocks this round will copy.  The caller keeps stepping the
        engine -- its dispatch/fence phases execute the gathers."""
        if self._pending is not None:
            raise RuntimeError("collect_round() the previous round first")
        if self.finalized:
            raise RuntimeError("session already finalized")
        self._pending = {}
        total = 0
        for cls in self.classes:
            ids, gens = self._dirty_ids(cls)
            if not ids:
                continue
            owner = f"__migrate__/{cls}/{len(self._rounds)}"
            self.arena.transfers.enqueue_swap_out(
                cls, owner, ids, kind="migrate-out", lane=BACKGROUND)
            self._pending[cls] = (owner, ids, gens)
            total += len(ids)
        return total

    def collect_round(self) -> dict:
        """Land this round's payloads into the per-block store and
        record the generations they were copied at."""
        if self._pending is None:
            raise RuntimeError("no round in flight; begin_round() first")
        report = {"round": len(self._rounds), "blocks": 0, "bytes": 0}
        for cls, (owner, ids, gens) in self._pending.items():
            if not self.arena.host_contains(cls, owner):
                self.arena.transfers.drain()
            streams = self.arena.host_take(cls, owner)
            layered = self.arena.transfers.is_layered(cls)
            for i, (b, g) in enumerate(zip(ids, gens)):
                sl = tuple(
                    None if s is None else np.ascontiguousarray(
                        s[:, i] if layered else s[i])
                    for s in streams)
                self._store[cls][b] = sl
                self._copied_gen[cls][b] = g
                report["bytes"] += int(sum(
                    x.nbytes for x in sl if x is not None))
            report["blocks"] += len(ids)
        self._pending = None
        self._rounds.append(report)
        return report

    # -- stop-and-copy ---------------------------------------------------
    def finalize(self, path: str) -> dict:
        """The short pause: drain, re-copy the dirty tail synchronously,
        assemble the full device payload from the store and write the
        snapshot.  Runs between engine steps; the tail is bounded by the
        working set (``converged()``), so the pause is too.  Returns the
        stop-and-copy report ``{"blocks": n, "bytes": n}``."""
        if self._pending is not None:
            raise RuntimeError("collect_round() the in-flight round first")
        self.arena.transfers.drain()
        for cls in self.classes:
            ids, gens = self._dirty_ids(cls)
            if not ids:
                continue
            owner = f"__migrate__/{cls}/final"
            self.arena.transfers.enqueue_swap_out(
                cls, owner, ids, kind="migrate-out")
            self.arena.transfers.drain()
            streams = self.arena.host_take(cls, owner)
            layered = self.arena.transfers.is_layered(cls)
            for i, (b, g) in enumerate(zip(ids, gens)):
                sl = tuple(
                    None if s is None else np.ascontiguousarray(
                        s[:, i] if layered else s[i])
                    for s in streams)
                self._store[cls][b] = sl
                self._copied_gen[cls][b] = g
                self._stop["bytes"] += int(sum(
                    x.nbytes for x in sl if x is not None))
            self._stop["blocks"] += len(ids)
        payloads: Dict[str, tuple] = {}
        for cls in self.classes:
            live = _live_device_ids(self.arena, cls)
            if not live:
                continue
            layered = self.arena.transfers.is_layered(cls)
            nstreams = len(self._store[cls][live[0]])
            streams = []
            for j in range(nstreams):
                parts = [self._store[cls][b][j] for b in live]
                if parts[0] is None:
                    streams.append(None)
                else:
                    streams.append(np.stack(parts,
                                            axis=1 if layered else 0))
            gens = [self._copied_gen[cls][b] for b in live]
            payloads[cls] = (live, tuple(streams), gens)
        self.pause_steps = max(self.pause_steps, 1)
        self.arena.snapshot(path, include_device=True,
                            device_payloads=payloads)
        self.finalized = True
        return dict(self._stop)

    # -- observability ---------------------------------------------------
    def migration_report(self) -> dict:
        return {
            "rounds": len(self._rounds),
            "blocks_per_round": [r["blocks"] for r in self._rounds],
            "bytes_per_round": [r["bytes"] for r in self._rounds],
            "precopy_blocks": sum(r["blocks"] for r in self._rounds),
            "precopy_bytes": sum(r["bytes"] for r in self._rounds),
            "stop_copy_blocks": self._stop["blocks"],
            "stop_copy_bytes": self._stop["bytes"],
            "pause_steps": self.pause_steps,
            "finalized": self.finalized,
        }


# ---------------------------------------------------------------------------
# block handoff: the prefill/decode-disaggregation transfer pattern
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BlockBundle:
    """One mapping's blocks as a transferable payload: what a prefill
    worker deposits and a decode worker adopts.  ``streams`` follow the
    pool class's executor layout (layered ``(L, n, *block)`` or flat
    ``(n, *block)``); ``None`` entries are passthrough streams."""

    pool_class: str
    nblocks: int
    streams: Tuple
    nbytes: int
    tenant: str = "default"


def export_mapping(arena: Arena, mapping: Mapping) -> BlockBundle:
    """Gather a device-resident mapping's blocks into a ``BlockBundle``
    and release the mapping -- the source side of the handoff.  The
    gather rides the transfer plane (kind ``handoff``, so swap ledgers
    ignore it) and the blocks return to the source pool."""
    if mapping.placement != DEVICE:
        raise ValueError("export of a host-resident mapping; migrate to "
                         "device first or hand over the host payload")
    cls = mapping.pool_class
    ids = mapping.block_ids()
    owner = f"__handoff__/{cls}/{Arena._tag_owner(mapping.owner)}"
    arena.transfers.enqueue_swap_out(cls, owner, ids, kind="handoff")
    arena.transfers.drain()
    streams = arena.host_take(cls, owner)
    nbytes = int(sum(s.nbytes for s in streams if s is not None))
    tenant = mapping.tenant
    mapping.free()
    return BlockBundle(cls, len(ids), tuple(np.asarray(s) if s is not None
                                            else None for s in streams),
                       nbytes, tenant=str(tenant))


def adopt_payload(arena: Arena, owner, bundle: BlockBundle,
                  pool_class: Optional[str] = None) -> Mapping:
    """Materialize a ``BlockBundle`` on (possibly different) fresh
    blocks of ``arena`` -- the destination side of the handoff.  The
    scatter rides the transfer plane; the returned mapping is
    device-resident and ready for ``PagedKVManager.adopt`` /
    ``ConstantStateManager.adopt``."""
    cls = pool_class if pool_class is not None else bundle.pool_class
    if not arena.transfers.has_executor(cls):
        raise RuntimeError(f"pool class {cls!r} has no executor on the "
                           f"adopting arena; build the engine first")
    m = arena.mapping(cls, owner, tenant=bundle.tenant)
    m.append_blocks(bundle.nblocks, pressure=True)
    key = f"__handoff__/{cls}/{Arena._tag_owner(owner)}"
    arena.host_deposit(cls, key, bundle.streams, bundle.nbytes)
    arena.transfers.enqueue_swap_in(cls, key, m.block_ids(),
                                    kind="handoff")
    arena.transfers.drain()
    return m
