"""Mapping: one logical object's software page table over Arena leases.

A ``Mapping`` subsumes the repo's ad-hoc block tables: the flat
per-sequence tables of the paged KV cache (``kind="flat"``) and the
radix leaf tables of ``TreeArray`` (``kind="radix"``).  It holds an
ordered list of ``Lease`` handles -- logical block ``i`` of the object
lives in physical block ``leases[i].block`` -- and exposes exactly three
mutation verbs beyond growth:

  * ``fork(owner, nblocks)``    -- COW-share a prefix into a new Mapping
    (paper Table 1 row 'Copy-on-Write': aliasing, not copying);
  * ``ensure_writable(idx)``    -- the COW write barrier: trade a shared
    lease for an exclusive one, returning the (src, dst) physical copy
    the caller must DMA (``kernels/block_copy``);
  * ``migrate(to)``             -- move the whole object between the
    device pool and the host swap tier (Table 1 rows 'Swapping' and
    'Relocation': the new device blocks after a round trip need not
    match the old ones -- the Mapping absorbs relocation).

Growth (``ensure_capacity``) and the write barrier allocate *under
pressure*: when the pool is exhausted the Arena consults its registered
reclaimer (the serving engine's LIFO preemption) instead of failing, and
raises ``LeaseRevokedError`` only when the requester itself had to be
reclaimed.  That policy used to live inline in ``serve/engine.py``; it
is Arena-level now so every client shares it.

Since the transfer-plane redesign the mutation verbs are *plan
producers*: ``migrate`` and ``ensure_writable`` no longer expect the
caller to move payloads -- they enqueue ``TransferPlan``s onto the
Arena's ``TransferQueue`` (``mem/transfer.py``) and the engine's step
loop dispatches/fences them.  ``assert_settled`` is the read barrier:
building a device table over a block whose transfer is unfenced raises
``UnfencedReadError``.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, List, Optional, Tuple

import numpy as np

from repro.mem.blockpool import NULL_BLOCK
from repro.mem.lease import Lease
from repro.mem.transfer import UnfencedReadError

if TYPE_CHECKING:  # pragma: no cover
    from repro.mem.arena import Arena

FLAT = "flat"
RADIX = "radix"

DEVICE = "device"
HOST = "host"


class Mapping:
    """Ordered leases for one logical object (see module docstring)."""

    __slots__ = ("arena", "pool_class", "owner", "kind", "leases",
                 "placement", "_host_blocks", "freed")

    def __init__(self, arena: "Arena", pool_class: str, owner,
                 kind: str = FLAT):
        if kind not in (FLAT, RADIX):
            raise ValueError(f"unknown mapping kind {kind!r}")
        self.arena = arena
        self.pool_class = pool_class
        self.owner = owner
        self.kind = kind
        self.leases: List[Lease] = []
        self.placement = DEVICE
        self._host_blocks = 0
        self.freed = False

    # -- views -----------------------------------------------------------
    def __len__(self) -> int:
        return (len(self.leases) if self.placement == DEVICE
                else self._host_blocks)

    def block_ids(self) -> List[int]:
        return [l.block for l in self.leases]

    def packed_table(self, capacity: int) -> np.ndarray:
        """NULL-padded flat device table (the per-sequence 'page table')."""
        t = np.full(capacity, NULL_BLOCK, np.int32)
        ids = self.block_ids()
        t[: len(ids)] = ids
        return t

    def assert_settled(self) -> None:
        """Read barrier: every lease's payload must be fenced.

        The engine calls this when it builds the decode tables (after
        ``TransferQueue.dispatch``); an ``in_flight`` lease here means a
        transfer targeting the block was never fenced and the decode
        would read garbage.
        """
        stale = [l.block for l in self.leases if l.in_flight]
        if stale:
            raise UnfencedReadError(
                f"mapping {self.owner!r} ({self.pool_class!r}): blocks "
                f"{stale} are targets of unfenced transfers; dispatch/"
                f"drain the arena's TransferQueue before reading")

    def locality(self) -> float:
        """Fraction of logically-adjacent block pairs that are physically
        adjacent -- the gather-locality half of the fragmentation story
        (``ArenaStats.table_locality`` aggregates this over mappings)."""
        ids = self.block_ids()
        if len(ids) < 2:
            return 1.0
        adj = sum(1 for a, b in zip(ids, ids[1:]) if b == a + 1)
        return adj / (len(ids) - 1)

    # -- growth ----------------------------------------------------------
    def append_blocks(self, n: int, *, pressure: bool = False) -> List[int]:
        """Append ``n`` fresh exclusive leases; returns their block ids."""
        if self.placement != DEVICE:
            raise ValueError(f"append to {self.placement}-resident mapping")
        fresh = self.arena.lease_blocks(self.pool_class, self.owner, n,
                                        pressure=pressure)
        self.leases.extend(fresh)
        return [l.block for l in fresh]

    def ensure_capacity(self, nblocks: int) -> List[int]:
        """Grow to at least ``nblocks`` blocks (under pressure); returns
        the newly added ids.  Atomic: on allocation failure the mapping
        is unchanged."""
        return self.append_blocks(max(0, nblocks - len(self.leases)),
                                  pressure=True)

    def pop_block(self) -> None:
        """Release the trailing lease (BlockStack unlink path)."""
        self.leases.pop().release()

    # -- the three mutation verbs ---------------------------------------
    def fork(self, owner, nblocks: int) -> "Mapping":
        """COW: a new mapping aliasing this one's first ``nblocks`` blocks.

        Pure refcount traffic -- no allocation, so it cannot hit pool
        pressure; the deferred cost surfaces later at the write barrier.
        """
        if self.placement != DEVICE:
            raise ValueError("fork of a host-resident mapping")
        if nblocks > len(self.leases):
            raise ValueError(
                f"fork of {nblocks} blocks, parent holds {len(self.leases)}")
        child = self.arena.mapping(self.pool_class, owner, kind=self.kind)
        for l in self.leases[:nblocks]:
            child.leases.append(l.share(owner))
        return child

    def ensure_writable(self, idx: int) -> Optional[Tuple[int, int]]:
        """COW write barrier for logical block ``idx``.

        When the block is shared this trades the shared lease for an
        exclusive one and ENQUEUES the fulfilment copy on the Arena's
        ``TransferQueue`` (the fresh lease stays ``in_flight`` until the
        plan executes); returns the ``(src, dst)`` pair for callers that
        track copy traffic, or None when the block is already exclusive.
        Allocates the copy target under pressure (this is the deferred
        claim admission cannot reserve -- see ``serve/engine.py``); on
        ``LeaseRevokedError`` the mapping has already been migrated out
        by the reclaimer.
        """
        lease = self.leases[idx]
        if not lease.shared:
            return None
        [fresh] = self.arena.lease_blocks(self.pool_class, self.owner, 1,
                                          pressure=True)
        if not lease.shared:
            # pressure reclaim evicted the last co-sharer mid-alloc:
            # the block is exclusive now, no copy needed
            fresh.release()
            return None
        self.leases[idx] = fresh
        lease.release()
        self.arena.transfers.enqueue_copy(self.pool_class, [lease.block],
                                          [fresh.block], kind="cow")
        return lease.block, fresh.block

    def migrate(self, to: str) -> List[int]:
        """Move the object device<->host -- as a transfer-plane producer.

        ``to="host"``: release every device lease, register host
        residency and ENQUEUE the swap-out plan (gather + host copy) on
        the Arena's ``TransferQueue``; returns the vacated ids.  The ids
        stay HELD in the allocator until the gather is dispatched, so
        reuse cannot clobber the payload mid-flight.

        ``to="device"``: reallocate (anywhere!), ENQUEUE the swap-in
        scatter into the fresh ids (leases stay ``in_flight`` until it
        executes) and return the new ids -- block tables absorb the
        relocation.
        """
        if to == HOST:
            if self.placement != DEVICE:
                raise ValueError("already host-resident")
            ids = self.block_ids()
            for l in self.leases:
                l.release()
            self.leases = []
            self._host_blocks = len(ids)
            self.placement = HOST
            self.arena._host_register(self.pool_class, self.owner, len(ids))
            self.arena.transfers.enqueue_swap_out(self.pool_class,
                                                  self.owner, ids)
            return ids
        if to == DEVICE:
            if self.placement != HOST:
                raise ValueError("already device-resident")
            n = self.arena._host_unregister(self.pool_class, self.owner)
            self.leases = self.arena.lease_blocks(self.pool_class,
                                                  self.owner, n)
            self._host_blocks = 0
            self.placement = DEVICE
            self.arena.transfers.enqueue_swap_in(self.pool_class,
                                                 self.owner,
                                                 self.block_ids())
            return self.block_ids()
        raise ValueError(f"unknown placement {to!r}")

    # -- teardown --------------------------------------------------------
    def free(self) -> None:
        """Release everything this mapping holds (either placement)."""
        if self.freed:
            raise ValueError(f"double free of mapping {self.owner!r}")
        if self.placement == HOST:
            upto = self.arena.transfers.last_transit(self.pool_class,
                                                     self.owner)
            if upto is not None:
                # cancel-while-swapping: land the in-flight payload so
                # residency and payload tear down together -- only the
                # FIFO prefix up to our plan; later transfers stay
                # overlapped
                self.arena.transfers.drain(upto=upto)
            self.arena._host_unregister(self.pool_class, self.owner)
            self.arena.host_discard(self.pool_class, self.owner)
        else:
            upto = self.arena.transfers.last_reference(self.pool_class,
                                                       self.block_ids())
            if upto is not None:
                # cancel-while-transferring: a pending plan (swap-in
                # scatter, COW copy) still names these blocks -- settle
                # the prefix through it before the ids return to the
                # free list, or a stale scatter would clobber their
                # next tenant
                self.arena.transfers.drain(upto=upto)
            for l in self.leases:
                l.release()
        self.leases = []
        self.freed = True
        self.arena._forget_mapping(self)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Mapping({self.pool_class}/{self.owner!r} {self.kind} "
                f"{self.placement} x{len(self)})")
