"""Mapping: one logical object's software page table over Arena leases.

A ``Mapping`` subsumes the repo's ad-hoc block tables: the flat
per-sequence tables of the paged KV cache (``kind="flat"``) and the
radix leaf tables of ``TreeArray`` (``kind="radix"``).  It holds an
ordered list of ``Lease`` handles -- logical block ``i`` of the object
lives in physical block ``leases[i].block`` -- and exposes exactly three
mutation verbs beyond growth:

  * ``fork(owner, nblocks)``    -- COW-share a prefix into a new Mapping
    (paper Table 1 row 'Copy-on-Write': aliasing, not copying);
  * ``ensure_writable(idx)``    -- the COW write barrier: trade a shared
    lease for an exclusive one, returning the (src, dst) physical copy
    the caller must DMA (``kernels/block_copy``);
  * ``migrate(to)``             -- move the whole object between the
    device pool and the host swap tier (Table 1 rows 'Swapping' and
    'Relocation': the new device blocks after a round trip need not
    match the old ones -- the Mapping absorbs relocation).

Growth (``ensure_capacity``) and the write barrier allocate *under
pressure*: when the pool is exhausted the Arena consults its registered
reclaimer (the serving engine's LIFO preemption) instead of failing, and
raises ``LeaseRevokedError`` only when the requester itself had to be
reclaimed.  That policy used to live inline in ``serve/engine.py``; it
is Arena-level now so every client shares it.

Since the transfer-plane redesign the mutation verbs are *plan
producers*: ``migrate`` and ``ensure_writable`` no longer expect the
caller to move payloads -- they enqueue ``TransferPlan``s onto the
Arena's ``TransferQueue`` (``mem/transfer.py``) and the engine's step
loop dispatches/fences them.  ``assert_settled`` is the read barrier:
building a device table over a block whose transfer is unfenced raises
``UnfencedReadError``.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, List, Optional, Tuple

import numpy as np

from repro.mem.blockpool import NULL_BLOCK
from repro.mem.lease import Lease
from repro.mem.transfer import D2H, DONE, PENDING, URGENT, UnfencedReadError

if TYPE_CHECKING:  # pragma: no cover
    from repro.mem.arena import Arena

FLAT = "flat"
RADIX = "radix"

DEVICE = "device"
HOST = "host"


class Mapping:
    """Ordered leases for one logical object (see module docstring)."""

    __slots__ = ("arena", "pool_class", "owner", "kind", "tenant",
                 "leases", "placement", "_host_blocks", "freed", "_spec",
                 "_spec_plan")

    def __init__(self, arena: "Arena", pool_class: str, owner,
                 kind: str = FLAT, tenant: str = "default"):
        if kind not in (FLAT, RADIX):
            raise ValueError(f"unknown mapping kind {kind!r}")
        self.arena = arena
        self.pool_class = pool_class
        self.owner = owner
        self.kind = kind
        #: quota-accounting tag: whose budget this object's blocks bill
        self.tenant = tenant
        self.leases: List[Lease] = []
        self.placement = DEVICE
        self._host_blocks = 0
        self.freed = False
        # speculative swap-in (prefetch): fresh device leases + their
        # background h2d plan, parked until commit_prefetch/cancel
        self._spec: List[Lease] = []
        self._spec_plan = None

    # -- views -----------------------------------------------------------
    def __len__(self) -> int:
        return (len(self.leases) if self.placement == DEVICE
                else self._host_blocks)

    def block_ids(self) -> List[int]:
        return [l.block for l in self.leases]

    def packed_table(self, capacity: int) -> np.ndarray:
        """NULL-padded flat device table (the per-sequence 'page table')."""
        t = np.full(capacity, NULL_BLOCK, np.int32)
        ids = self.block_ids()
        t[: len(ids)] = ids
        return t

    def assert_settled(self) -> None:
        """Read barrier: every lease's payload must be fenced.

        The engine calls this when it builds the decode tables (after
        ``TransferQueue.dispatch``); an ``in_flight`` lease here means a
        transfer targeting the block was never fenced and the decode
        would read garbage.
        """
        stale = [l.block for l in self.leases if l.in_flight]
        if stale:
            raise UnfencedReadError(
                f"mapping {self.owner!r} ({self.pool_class!r}): blocks "
                f"{stale} are targets of unfenced transfers (per-engine "
                f"queue depths "
                f"{self.arena.transfers.pending_by_direction()}); "
                f"dispatch/drain the arena's transfer queues before "
                f"reading")

    def locality(self) -> float:
        """Fraction of logically-adjacent block pairs that are physically
        adjacent -- the gather-locality half of the fragmentation story
        (``ArenaStats.table_locality`` aggregates this over mappings)."""
        ids = self.block_ids()
        if len(ids) < 2:
            return 1.0
        adj = sum(1 for a, b in zip(ids, ids[1:]) if b == a + 1)
        return adj / (len(ids) - 1)

    # -- growth ----------------------------------------------------------
    def append_blocks(self, n: int, *, pressure: bool = False) -> List[int]:
        """Append ``n`` fresh exclusive leases; returns their block ids."""
        if self.placement != DEVICE:
            raise ValueError(f"append to {self.placement}-resident mapping")
        fresh = self.arena.lease_blocks(self.pool_class, self.owner, n,
                                        pressure=pressure)
        self.leases.extend(fresh)
        return [l.block for l in fresh]

    def ensure_capacity(self, nblocks: int) -> List[int]:
        """Grow to at least ``nblocks`` blocks (under pressure); returns
        the newly added ids.  Atomic: on allocation failure the mapping
        is unchanged."""
        return self.append_blocks(max(0, nblocks - len(self.leases)),
                                  pressure=True)

    def pop_block(self) -> None:
        """Release the trailing lease (BlockStack unlink path)."""
        self.leases.pop().release()

    # -- the three mutation verbs ---------------------------------------
    def fork(self, owner, nblocks: int,
             tenant: Optional[str] = None) -> "Mapping":
        """COW: a new mapping aliasing this one's first ``nblocks`` blocks.

        Pure refcount traffic -- no allocation, so it cannot hit pool
        pressure; the deferred cost surfaces later at the write barrier.
        The child bills ``tenant`` (default: the parent's) -- shared
        blocks are double-billed by design, like refcounts.
        """
        if self.placement != DEVICE:
            raise ValueError("fork of a host-resident mapping")
        if nblocks > len(self.leases):
            raise ValueError(
                f"fork of {nblocks} blocks, parent holds {len(self.leases)}")
        child = self.arena.mapping(self.pool_class, owner, kind=self.kind,
                                   tenant=self.tenant if tenant is None
                                   else tenant)
        for l in self.leases[:nblocks]:
            child.leases.append(l.share(owner))
        return child

    def ensure_writable(self, idx: int) -> Optional[Tuple[int, int]]:
        """COW write barrier for logical block ``idx``.

        When the block is shared this trades the shared lease for an
        exclusive one and ENQUEUES the fulfilment copy on the Arena's
        ``TransferQueue`` (the fresh lease stays ``in_flight`` until the
        plan executes); returns the ``(src, dst)`` pair for callers that
        track copy traffic, or None when the block is already exclusive.
        Allocates the copy target under pressure (this is the deferred
        claim admission cannot reserve -- see ``serve/engine.py``); on
        ``LeaseRevokedError`` the mapping has already been migrated out
        by the reclaimer.
        """
        lease = self.leases[idx]
        if not lease.shared:
            return None
        [fresh] = self.arena.lease_blocks(self.pool_class, self.owner, 1,
                                          pressure=True)
        if not lease.shared:
            # pressure reclaim evicted the last co-sharer mid-alloc:
            # the block is exclusive now, no copy needed
            fresh.release()
            return None
        self.leases[idx] = fresh
        lease.release()
        self.arena.transfers.enqueue_copy(self.pool_class, [lease.block],
                                          [fresh.block], kind="cow")
        # dirty tracking: the divergent write that motivated this barrier
        # lands in the fresh block right after the copy
        self.arena.allocator(self.pool_class).note_write([fresh.block])
        return lease.block, fresh.block

    def migrate(self, to: str) -> List[int]:
        """Move the object device<->host -- as a transfer-plane producer.

        ``to="host"``: release every device lease, register host
        residency and ENQUEUE the swap-out plan (gather + host copy) on
        the Arena's ``TransferQueue``; returns the vacated ids.  The ids
        stay HELD in the allocator until the gather is dispatched, so
        reuse cannot clobber the payload mid-flight.

        ``to="device"``: reallocate (anywhere!), ENQUEUE the swap-in
        scatter into the fresh ids (leases stay ``in_flight`` until it
        executes) and return the new ids -- block tables absorb the
        relocation.
        """
        if to == HOST:
            if self.placement != DEVICE:
                raise ValueError("already host-resident")
            ids = self.block_ids()
            for l in self.leases:
                l.release()
            self.leases = []
            self._host_blocks = len(ids)
            self.placement = HOST
            self.arena._host_register(self.pool_class, self.owner, len(ids))
            self.arena.transfers.enqueue_swap_out(self.pool_class,
                                                  self.owner, ids)
            return ids
        if to == DEVICE:
            if self.placement != HOST:
                raise ValueError("already device-resident")
            if self._spec:
                # a speculative prefetch already reallocated and (maybe)
                # scattered the payload: the resume just commits it
                return self.commit_prefetch()[0]
            n = self.arena._host_unregister(self.pool_class, self.owner)
            self.leases = self.arena.lease_blocks(self.pool_class,
                                                  self.owner, n)
            self._host_blocks = 0
            self.placement = DEVICE
            self.arena.transfers.enqueue_swap_in(self.pool_class,
                                                 self.owner,
                                                 self.block_ids())
            return self.block_ids()
        raise ValueError(f"unknown placement {to!r}")

    # -- speculative swap-in (prefetch) ---------------------------------
    @property
    def prefetched(self) -> bool:
        """A speculative swap-in is parked on this mapping (its blocks
        are on device -- or in flight -- but the resume has not been
        committed; host residency and payload are still intact)."""
        return bool(self._spec)

    @property
    def spec_blocks(self) -> int:
        """Device blocks held by the uncommitted prefetch (0 if none)."""
        return len(self._spec)

    def prefetch(self) -> List[int]:
        """Speculative swap-in: allocate fresh device leases and enqueue
        the h2d scatter on the BACKGROUND lane, while host residency and
        the payload stay intact until ``commit_prefetch``.

        This is the multi-queue plane's hedge: the serving engine
        prefetches the scheduler's LIFO resume candidate while decode
        runs, so a later resume skips the synchronous swap-in entirely.
        Never allocates under pressure (speculation must not evict
        anyone -- the caller checks headroom and the Arena reclaimer
        cancels speculation first when memory tightens).
        """
        if self.placement != HOST:
            raise ValueError("prefetch of a device-resident mapping")
        if self._spec:
            raise ValueError(f"{self.owner!r} already prefetched")
        if self._host_blocks == 0:
            raise ValueError("prefetch of an empty mapping")
        self._spec = self.arena.lease_blocks(self.pool_class, self.owner,
                                             self._host_blocks)
        ids = [l.block for l in self._spec]
        self._spec_plan = self.arena.transfers.enqueue_prefetch(
            self.pool_class, self.owner, ids)
        return ids

    def commit_prefetch(self) -> Tuple[List[int], bool]:
        """Promote the speculative swap-in to the real resume: the spec
        leases become the mapping's table, host residency tears down,
        and -- when the scatter has not executed yet -- the plan leaves
        the background lane to run as a normal swap-in at the next
        dispatch.  Returns ``(new_ids, was_completed)``; ``True`` means
        the resume was served entirely from the completed prefetch (the
        acceptance metric ``prefetch_hit``)."""
        if not self._spec:
            raise ValueError(f"{self.owner!r} has no prefetch to commit")
        plan = self._spec_plan
        completed = plan.state == DONE
        self.leases = self._spec
        self._spec = []
        self._spec_plan = None
        self._host_blocks = 0
        self.placement = DEVICE
        self.arena._host_unregister(self.pool_class, self.owner)
        if completed:
            # the scatter only PEEKED the payload; consume it now
            self.arena.host_discard(self.pool_class, self.owner)
        elif plan.state == PENDING:
            plan.lane = URGENT
            plan.speculative = False     # executes as a real swap-in
        self.arena.transfers.note_prefetch_commit(plan)
        return self.block_ids(), completed

    def cancel_prefetch(self) -> None:
        """Withdraw the speculation: drop the plan (if still pending),
        release the fresh leases and leave the mapping exactly as
        preempted -- host residency and payload intact, so a later real
        swap-in still works.  Called when the candidate is freed, or by
        the pressure path (speculative blocks are the FIRST thing
        reclaimed -- cheaper than preempting a running sequence)."""
        if not self._spec:
            raise ValueError(f"{self.owner!r} has no prefetch to cancel")
        plan = self._spec_plan
        if not self.arena.transfers.cancel_plan(plan):
            # the scatter already ran (wasted speculation): the payload
            # was only peeked, so releasing the leases loses nothing --
            # ledgers are re-notified to write the parked bytes off
            self.arena.transfers.note_prefetch_abandon(plan)
        for l in self._spec:
            l.release()
        self._spec = []
        self._spec_plan = None

    # -- teardown --------------------------------------------------------
    def free(self) -> None:
        """Release everything this mapping holds (either placement)."""
        if self.freed:
            raise ValueError(f"double free of mapping {self.owner!r}")
        if self._spec:
            # cancel-while-prefetched: withdraw the speculation first so
            # the spec leases and their pending scatter never outlive
            # the mapping
            self.cancel_prefetch()
        if self.placement == HOST:
            upto = self.arena.transfers.last_transit(self.pool_class,
                                                     self.owner)
            if upto is not None:
                # cancel-while-swapping: land the in-flight payload so
                # residency and payload tear down together -- only the
                # d2h prefix up to our plan (plus its cross-queue
                # dependency closure); later transfers stay overlapped
                self.arena.transfers.drain(upto={D2H: upto})
            self.arena._host_unregister(self.pool_class, self.owner)
            self.arena.host_discard(self.pool_class, self.owner)
        else:
            upto = self.arena.transfers.last_reference(self.pool_class,
                                                       self.block_ids())
            if upto is not None:
                # cancel-while-transferring: a pending plan (swap-in
                # scatter, COW copy) still names these blocks -- settle
                # the prefix through it before the ids return to the
                # free list, or a stale scatter would clobber their
                # next tenant
                self.arena.transfers.drain(upto=upto)
            for l in self.leases:
                l.release()
        self.leases = []
        self.freed = True
        self.arena._forget_mapping(self)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Mapping({self.pool_class}/{self.owner!r} {self.kind} "
                f"{self.placement} x{len(self)})")
