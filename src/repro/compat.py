"""Version-portability layer for jax APIs that moved between releases.

The repo targets the ``shard_map`` API as it exists in jax >= 0.5
(``jax.shard_map`` with ``check_vma=`` and partial-manual ``axis_names=``).
On jax 0.4.x the implementation lives in ``jax.experimental.shard_map``
and spells those knobs ``check_rep=`` and ``auto=`` (the complement set:
axes NOT listed are manual).  Every in-repo caller imports ``shard_map``
from here so the translation happens in exactly one place:

    from repro.compat import shard_map

Resolution order:
  1. ``jax.shard_map``                       (jax >= 0.5: passthrough)
  2. ``jax.experimental.shard_map.shard_map`` (jax 0.4.x: kwargs mapped)

``check_vma``/``check_rep`` are the same switch (the replication-
invariance checker was renamed for "varying mesh axes"); ``axis_names``
lists the axes the body is *manual* over, while 0.4.x ``auto`` lists the
axes left to GSPMD -- we convert one into the other using the mesh.
"""

from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, Optional

import jax

__all__ = ["shard_map", "JAX_HAS_NATIVE_SHARD_MAP"]

_native = getattr(jax, "shard_map", None)
JAX_HAS_NATIVE_SHARD_MAP = _native is not None

if not JAX_HAS_NATIVE_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _experimental
    _EXP_PARAMS = frozenset(inspect.signature(_experimental).parameters)


def shard_map(f: Optional[Callable] = None, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None,
              axis_names: Optional[Any] = None,
              auto: Optional[Any] = None) -> Callable:
    """jax.shard_map with one spelling across jax versions.

    Accepts both the new-API kwargs (``check_vma``, ``axis_names``) and
    the 0.4.x kwargs (``check_rep``, ``auto``); whichever pair the
    installed jax does not understand is translated.  Usable directly or
    as ``functools.partial(shard_map, mesh=..., ...)`` exactly like the
    real API.
    """
    if f is None:
        return functools.partial(
            shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, check_rep=check_rep,
            axis_names=axis_names, auto=auto)

    check = check_vma if check_vma is not None else check_rep

    if JAX_HAS_NATIVE_SHARD_MAP:
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if check is not None:
            kw["check_vma"] = check
        if axis_names is None and auto is not None:
            axis_names = frozenset(mesh.axis_names) - frozenset(auto)
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        return _native(f, **kw)

    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if check is not None:
        kw["check_rep"] = check
    if auto is None and axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    if auto:
        if "auto" not in _EXP_PARAMS:
            # silently dropping 'auto' would run the body manual over
            # ALL axes -- different semantics; fail at the boundary
            raise NotImplementedError(
                "partial-manual shard_map (auto/axis_names) requested "
                "but this jax's experimental shard_map has no 'auto' "
                "parameter")
        kw["auto"] = frozenset(auto)
    return _experimental(f, **kw)
