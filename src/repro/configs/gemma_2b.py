"""Gemma-2B: 18L, d=2048, 8H MQA (kv=1), head_dim=256, d_ff=16384 GeGLU,
vocab 256000.  [arXiv:2403.08295]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp="geglu",
    rope_theta=10000.0,
    embed_scale=True,
    tie_embeddings=True,
)
