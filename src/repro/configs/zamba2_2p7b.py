"""Zamba2-2.7B: 54 Mamba2 layers (d=2560, ssm_state=64) + a SHARED
attention/MLP block (32H, d_ff=10240) applied every 6 layers with
per-invocation LoRA.  [arXiv:2411.15242]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2,
                  conv_width=4, chunk=64),
    shared_attn_every=6,
    shared_attn_lora=128,
    tie_embeddings=True,
)
