"""Assigned input shapes and the (arch x shape) cell matrix.

LM shapes are seq_len x global_batch.  ``decode_*``/``long_*`` lower
``serve_step`` (one new token against a KV cache of seq_len); the others
lower ``train_step``.  ``long_500k`` requires sub-quadratic sequence
mixing and therefore runs only for the SSM/hybrid archs (see DESIGN.md
§5 'Shape skips').
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.configs.base import ARCH_IDS, get_config


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "train"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# prefill_32k is "inference-prefill": forward-only over the full sequence.
# We lower it as the forward pass + prefill KV write (no backward).

_SUBQUADRATIC = {"rwkv6_7b", "zamba2_2p7b"}


def cell_is_runnable(arch_id: str, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and arch_id not in _SUBQUADRATIC:
        return False, ("N/A-by-spec: full-attention arch; long_500k needs "
                       "sub-quadratic sequence mixing (DESIGN.md §5)")
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, why = cell_is_runnable(arch, shape)
            out.append((arch, shape, ok, why))
    return out
