"""Qwen3-30B-A3B: 48L, d=2048, 32H GQA kv=4, MoE 128 experts top-8,
expert d_ff=768, vocab 151936.  [hf:Qwen/Qwen3-30B-A3B]"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    kv_heads=4,
    head_dim=128,
    d_ff=768,                 # per-expert hidden (dense d_ff unused)
    vocab_size=151936,
    qk_norm=True,             # qwen3 per-head RMSNorm on q,k
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)
