"""Gemma2-27B: 46L, d=4608, 32H GQA kv=16, head_dim=128, d_ff=36864,
alternating local(4096)/global attention, logit softcaps, GeGLU,
query scale (d_model/num_heads)^-0.5 = 144^-0.5.  [arXiv:2408.00118]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    mlp="geglu",
    local_window=4096,
    local_ratio=1,            # local, global, local, global, ...
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,
    rope_theta=10000.0,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
)
