"""DeepSeek-V2-Lite (15.7B): 27L, d=2048, 16H MLA (kv_lora=512, rope 64),
64 routed experts top-6 + 2 shared, expert d_ff=1408, first layer dense,
vocab 102400.  [arXiv:2405.04434]

The assignment line also mentions "160 routed"; the published V2-Lite
config is 64 routed (160 belongs to V2-236B) -- see DESIGN.md §5.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    kv_heads=16,              # MLA: logical heads (cache is latent)
    head_dim=128,
    d_ff=10944,               # dense d_ff (first layer)
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2, d_ff_shared=2 * 1408,
                  first_dense_layers=1),
    rope_theta=10000.0,
    tie_embeddings=False,
)
