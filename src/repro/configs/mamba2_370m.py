"""Mamba2-370M: 48 pure SSD layers (d=1024, ssm_state=128, head 64),
no attention anywhere -- decode state is O(1) per layer, served from
the constant-state pool discipline.  [arXiv:2405.21060]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=16,
    kv_heads=16,
    head_dim=64,
    d_ff=0,
    vocab_size=50288,
    attention="none",
    ssm=SSMConfig(kind="mamba2", state_dim=128, head_dim=64, expand=2,
                  conv_width=4, chunk=64),
    tie_embeddings=True,
)
