"""InternVL2-1B: Qwen2-0.5B LM backbone (24L, d=896, 14H GQA kv=2,
d_ff=4864, vocab 151655) + InternViT frontend (STUB: patch embeddings
arrive precomputed, 256 image tokens).  [arXiv:2404.16821]"""

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    num_image_tokens=256,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
