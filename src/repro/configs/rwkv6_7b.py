"""RWKV6-World-7B ("Finch"): 32L, d=4096, attention-free linear attention
with data-dependent decay, head size 64 (64 heads), ffn 14336(x3.5-ish;
assigned d_ff=14336), vocab 65536.  [arXiv:2404.05892]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,             # d_model / head_dim
    kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    attention="none",
    ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk=64,
                  decay_lora=64, mix_lora=32),
    tie_embeddings=False,
)
