"""Config system: one frozen dataclass tree per architecture.

Every assigned architecture is a ``ModelConfig`` in its own module
(``repro.configs.<id>``); ``get_config(name)`` resolves by id.  Reduced
configs for CPU smoke tests come from ``ModelConfig.reduced()`` so tests
always exercise the same code path as the full model.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""
    kv_lora_rank: int
    q_lora_rank: Optional[int]      # None => full-rank q projection
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int

    @property
    def latent_dim(self) -> int:    # what the paged pool stores per token
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0            # total shared-expert hidden dim
    first_dense_layers: int = 0     # leading layers with dense MLP
    router_aux_coef: float = 0.001  # load-balance loss weight
    capacity_factor: float = 0.0    # 0 => dropless (sort + ragged_dot)
    parallel_mode: str = "tp"       # "tp" (d_ff sharded) | "ep" (a2a)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Covers Mamba2 (kind='mamba2') and RWKV6 (kind='rwkv6')."""
    kind: str
    state_dim: int = 64             # N (mamba2) / ignored by rwkv6
    head_dim: int = 64
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4             # mamba2 conv1d
    chunk: int = 64                 # chunked-scan length
    subchunk: int = 0               # rwkv6: unrolled inner tiles (0 = off)
    intra_dtype: str = "float32"    # chunk-intra intermediates (bf16 opt)
    decay_lora: int = 64            # rwkv6 low-rank for w
    mix_lora: int = 32              # rwkv6 ddlerp low-rank


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Auxiliary encoder (whisper audio encoder / ViT stub)."""
    num_layers: int
    num_frames: int                 # encoder sequence length (stub frontend)
    frontend: str = "stub"          # embeddings arrive precomputed


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    attention: str = "gqa"          # gqa | mla | none
    mla: Optional[MLAConfig] = None
    # local/global attention pattern: every `local_ratio + 1` layers, the
    # last is global and the rest are local with `local_window`.
    local_window: Optional[int] = None
    local_ratio: int = 0            # gemma2: 1 (alternating); gemma3: 5
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None  # override head_dim**-0.5
    qk_norm: bool = False           # qwen3-style per-head RMSNorm on q,k
    mlp: str = "swiglu"             # swiglu | geglu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_every: int = 0      # zamba2: shared attn block period
    shared_attn_lora: int = 0       # zamba2: per-group LoRA rank on shared
    encoder: Optional[EncoderConfig] = None  # whisper / internvl frontend
    num_image_tokens: int = 0       # internvl: patch embeds prepended
    rope_theta: float = 10000.0
    rope_theta_local: Optional[float] = None  # gemma3: local layers' theta
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    post_norms: bool = False        # gemma2/3: post-attn/post-mlp norms
    embed_scale: bool = False       # gemma family: x *= sqrt(d_model)
    dtype: str = "bfloat16"
    # serving/paging knobs (the paper's block quantum)
    kv_block_tokens: int = 64
    # beyond-paper: shard the MLA latent KV pool over 'model' on the
    # kv_lora dim (rope stream kept separate+replicated).  See §Perf.
    mla_latent_tp: bool = False

    # -- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def layer_is_local(self, layer: int) -> bool:
        if self.local_ratio <= 0 or self.local_window is None:
            return False
        return (layer % (self.local_ratio + 1)) != self.local_ratio

    def param_count(self) -> int:
        """Approximate parameter count N (for 6ND roofline accounting)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attention == "gqa":
            per_layer += d * self.num_heads * self.hd * 2  # q, o
            per_layer += d * self.kv_heads * self.hd * 2   # k, v
        elif self.attention == "mla":
            m = self.mla
            qdim = self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            if m.q_lora_rank:
                per_layer += d * m.q_lora_rank + m.q_lora_rank * qdim
            else:
                per_layer += d * qdim
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.num_heads * m.v_head_dim * d
        if self.moe is not None:
            e = self.moe
            moe_layer = e.num_experts * 3 * d * e.d_ff_expert + d * e.num_experts
            moe_layer += 3 * d * e.d_ff_shared
            dense_layer = 3 * d * self.d_ff
            per_layer_mlp = moe_layer
            total_mlp = (moe_layer * (L - e.first_dense_layers)
                         + dense_layer * e.first_dense_layers)
        elif self.ssm is not None and self.ssm.kind == "rwkv6":
            di = d  # rwkv6 time-mix operates at d_model
            total_mlp = L * (4 * d * di + 3 * d * self.d_ff // 1)
        else:
            mult = 3 if self.mlp in ("swiglu", "geglu") else 2
            total_mlp = L * mult * d * self.d_ff
        if self.ssm is not None and self.ssm.kind == "mamba2":
            di = self.ssm.expand * d
            per_layer = 2 * d * di + di * d  # in/out projections (approx)
        total = emb + per_layer * L + total_mlp
        if self.encoder is not None:
            enc_layer = 4 * d * d + 3 * d * self.d_ff
            total += self.encoder.num_layers * enc_layer
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d, L = self.d_model, self.num_layers
        full = self.param_count()
        all_experts = e.num_experts * 3 * d * e.d_ff_expert * (L - e.first_dense_layers)
        active_experts = e.top_k * 3 * d * e.d_ff_expert * (L - e.first_dense_layers)
        return int(full - all_experts + active_experts)

    # -- reduced config for CPU smoke tests -----------------------------
    def reduced(self) -> "ModelConfig":
        rep = dict(
            num_layers=min(self.num_layers, 4 if self.shared_attn_every == 0
                           else 2 * max(1, self.shared_attn_every)),
            d_model=128, num_heads=4,
            kv_heads=min(self.kv_heads, 2) if self.kv_heads < self.num_heads else 4,
            head_dim=32, d_ff=256, vocab_size=512, dtype="float32",
            kv_block_tokens=8,
        )
        if self.mla is not None:
            rep["mla"] = MLAConfig(kv_lora_rank=32,
                                   q_lora_rank=48 if self.mla.q_lora_rank else None,
                                   qk_nope_head_dim=16, qk_rope_head_dim=8,
                                   v_head_dim=16)
        if self.moe is not None:
            rep["moe"] = dataclasses.replace(
                self.moe, num_experts=8, top_k=2, d_ff_expert=64,
                d_ff_shared=64 if self.moe.num_shared_experts else 0)
        if self.ssm is not None:
            rep["ssm"] = dataclasses.replace(self.ssm, state_dim=16,
                                             head_dim=16, chunk=8,
                                             decay_lora=8, mix_lora=8)
        if self.local_window is not None:
            rep["local_window"] = 16
        if self.encoder is not None:
            rep["encoder"] = dataclasses.replace(self.encoder, num_layers=2,
                                                 num_frames=16)
        if self.num_image_tokens:
            rep["num_image_tokens"] = 8
        if self.shared_attn_lora:
            rep["shared_attn_lora"] = 8
        return dataclasses.replace(self, **rep)


ARCH_IDS = [
    "qwen3_moe_30b_a3b", "deepseek_v2_lite_16b", "minicpm3_4b",
    "gemma2_27b", "gemma3_27b", "gemma_2b", "internvl2_1b",
    "rwkv6_7b", "zamba2_2p7b", "whisper_tiny",
]


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG
