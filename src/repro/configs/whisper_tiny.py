"""Whisper-tiny: enc-dec, 4L each, d=384, 6H, d_ff=1536, vocab 51865;
conv audio frontend is a STUB (precomputed 1500-frame embeddings).
[arXiv:2212.04356; pool tag: unverified]"""

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,             # decoder layers
    d_model=384,
    num_heads=6,
    kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    encoder=EncoderConfig(num_layers=4, num_frames=1500),
    rope_theta=0.0,           # whisper uses learned/sinusoidal positions
    tie_embeddings=True,
)
