"""Gemma3-27B: 62L, d=5376, 32H GQA kv=16, head_dim=128, d_ff=21504,
5 local(1024) : 1 global, qk-norm (replaces gemma2's softcap), 128k
context.  [pool tag: unverified; using published HF config]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    mlp="geglu",
    local_window=1024,
    local_ratio=5,            # 5 local then 1 global
    qk_norm=True,
    query_scale=(5376 / 32) ** -0.5,
    rope_theta=1_000_000.0,   # global layers (local use 10k; see models)
    post_norms=True,
    embed_scale=True,
    rope_theta_local=10000.0,
    tie_embeddings=True,
)
