"""Render dryrun_report.jsonl into the EXPERIMENTS.md roofline tables,
and ArenaStats snapshots (BENCH_serve.json) into the address-space table.

    PYTHONPATH=src python -m repro.report dryrun_report.jsonl
    PYTHONPATH=src python -m repro.report BENCH_serve.json   # ArenaStats
    PYTHONPATH=src python -m repro.report BENCH_migrate.json # migration
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(path: str) -> List[Dict]:
    rows = []
    for line in open(path):
        try:
            rows.append(json.loads(line))
        except json.JSONDecodeError:
            pass
    return rows


def fmt_table(rows: List[Dict], mesh: str) -> str:
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | dominant mem op | useful/HLO | roofline frac | "
           "peak GB/chip |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"N/A-by-spec | — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | "
                       f"{r.get('error','')[:60]} | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['bottleneck']} | {r.get('dominant_mem_op', '-')} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"**{r['roofline_fraction']:.3f}** | "
            f"{r['peak_mem_gb_per_chip']:.1f} |")
    return "\n".join(out)


def fmt_dryrun_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | status | HLO GFLOP/chip | HBM GB/chip | "
           "coll GB/chip | compile (s) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
                f"{r['hlo_gflops_per_chip']:.0f} | "
                f"{r['hbm_gb_per_chip']:.1f} | {r['coll_gb_per_chip']:.2f} | "
                f"{r.get('t_compile_s','')} |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']} | — | — | — | — |")
    return "\n".join(out)


def fmt_arena_table(arena: Dict) -> str:
    """Render an ``ArenaStats.to_dict()`` snapshot (the ``arena`` key of
    BENCH_serve.json) as the unified-address-space table: one row per
    pool class with placement split, sharing, locality metrics, and
    blocks used/free per dp pool group when the class is partitioned."""
    out = ["| pool class | blocks | used | free | pinned | host tier | "
           "COW-shared | frag | table locality | owners | dp groups | "
           "tenant used/quota |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for name in sorted(arena.get("classes", {})):
        c = arena["classes"][name]
        hist = c.get("refcount_histogram", [])
        shared = sum(hist[2:]) if len(hist) > 2 else 0
        groups = " ".join(f"g{g['group']} {g['used']}/{g['free']}"
                          for g in c.get("groups", [])) or "—"
        # pre-quota snapshots lack both tenant dicts: render "n/a",
        # never KeyError (same degradation contract as the tenant
        # latency table)
        quotas = c.get("quota_by_tenant")
        if quotas is None:
            quota_cell = "n/a"
        elif not quotas:
            quota_cell = "—"
        else:
            used = c.get("blocks_by_tenant", {})
            quota_cell = " ".join(f"{t}:{used.get(t, 0)}/{q}"
                                  for t, q in sorted(quotas.items()))
        out.append(
            f"| {name} | {c['num_blocks']} | {c['num_used']} | "
            f"{c['num_free']} | {c['pinned']} | {c['host_blocks']} | "
            f"{shared} | {c['fragmentation']:.3f} | "
            f"{c['table_locality']:.3f} | {len(c['blocks_by_owner'])} | "
            f"{groups} | {quota_cell} |")
    out.append("")
    out.append(f"compactions: {arena.get('compactions', 0)} "
               f"(blocks moved: {arena.get('blocks_compacted', 0)})")
    return "\n".join(out)


def fmt_transfer_table(tr: Dict) -> str:
    """Render a ``TransferStats.to_dict()`` snapshot: one row per DMA
    ENGINE (plans, bytes, per-engine queue depth and overlap) plus the
    scheduling counters of the multi-queue plane."""
    out = ["| engine | enqueued | completed | bytes moved | "
           "max depth | overlapped |",
           "|---|---|---|---|---|---|"]
    names = {"d2d": "d2d (COW / compaction)",
             "d2h": "d2h (swap-out)",
             "h2d": "h2d (swap-in / prefetch)"}

    def per_engine(field, d):
        v = tr.get(field, 0)
        # pre-multi-queue snapshots carried a single global counter
        return v.get(d, 0) if isinstance(v, dict) else v

    for d in ("d2d", "d2h", "h2d"):
        out.append(f"| {names[d]} | {tr['enqueued'].get(d, 0)} | "
                   f"{tr['completed'].get(d, 0)} | "
                   f"{tr['bytes_moved'].get(d, 0)} | "
                   f"{per_engine('max_pending', d)} | "
                   f"{per_engine('overlapped', d)} |")
    out.append("")
    out.append(
        f"launches: {tr.get('launches', 0)} "
        f"(coalesced plans: {tr.get('coalesced', 0)}, "
        f"reordered past a blocked plan: {tr.get('reordered', 0)}) · "
        f"dispatches: {tr.get('dispatches', 0)} · "
        f"drains: {tr.get('drains', 0)}")
    if "python_launches" in tr or "dispatches_per_step" in tr:
        out.append(
            f"step-loop overhead: {tr.get('python_launches', 0)} "
            f"python launches · "
            f"{tr.get('dispatches_per_step', 0.0)} dispatches/step")
    if tr.get("prefetch_enqueued"):
        rate = tr.get("prefetch_hit_rate")
        rate_s = "" if rate is None else f", hit rate {rate:.2f}"
        out.append(
            f"prefetch lane: {tr['prefetch_enqueued']} speculative "
            f"swap-ins ({tr.get('prefetch_completed', 0)} completed, "
            f"{tr.get('prefetch_committed', 0)} committed, "
            f"{tr.get('prefetch_cancelled', 0)} cancelled{rate_s})")
    else:
        # zero speculative plans ever launched: a hit rate is undefined
        # (the old snapshots' vacuous 1.0 here was misleading)
        out.append("prefetch lane: idle (hit rate n/a)")
    return "\n".join(out)


def fmt_tenant_latency_table(doc: Dict) -> str:
    """Render the request plane's per-tenant latency section
    (``tenant_latency`` + ``arrival_trace`` of BENCH_serve.json).

    Degrades gracefully on pre-request-plane snapshots that lack the
    section entirely, and on tenants whose percentile values are null
    (too few tokens to measure): both render as "n/a", never KeyError.
    """
    out = ["| tenant | requests | TTFT p50 (ms) | TTFT p99 (ms) | "
           "ITL p50 (ms) | ITL p99 (ms) |",
           "|---|---|---|---|---|---|"]

    def cell(v):
        return "n/a" if v is None else f"{v:.2f}"

    tl = doc.get("tenant_latency")
    if not tl:
        out.append("| n/a | n/a | n/a | n/a | n/a | n/a |")
        out.append("")
        out.append("no per-tenant section in this snapshot "
                   "(pre-request-plane BENCH_serve.json)")
        return "\n".join(out)
    for tenant in sorted(tl):
        r = tl[tenant]
        out.append(f"| {tenant} | {r.get('requests', 'n/a')} | "
                   f"{cell(r.get('ttft_p50_ms'))} | "
                   f"{cell(r.get('ttft_p99_ms'))} | "
                   f"{cell(r.get('itl_p50_ms'))} | "
                   f"{cell(r.get('itl_p99_ms'))} |")
    tr = doc.get("arrival_trace") or {}
    out.append("")
    out.append(f"arrival trace: {tr.get('kind', 'n/a')} "
               f"(seed {tr.get('seed', 'n/a')}, "
               f"{tr.get('requests', 'n/a')} requests over "
               f"{tr.get('tenants', 'n/a')} tenants, mean gap "
               f"{tr.get('mean_gap_steps', 'n/a')} steps)")
    hist = doc.get("latency_histogram") or {}
    if hist.get("counts"):
        edges, counts = hist.get("edges_ms", []), hist["counts"]
        buckets = " ".join(
            f"[{edges[i]:.0f},{edges[i + 1]:.0f}):{c}"
            for i, c in enumerate(counts) if i + 1 < len(edges))
        out.append(f"TTFT histogram (ms): {buckets}")
    return "\n".join(out)


def fmt_family_table(doc: Dict) -> str:
    """Render the ``mixed_arch`` section of BENCH_serve.json: one row
    per model family served from the shared arena, with its registry
    strategy, pool classes and throughput.

    Degrades gracefully on pre-architecture-registry snapshots that
    lack the section entirely: renders an "n/a" row and says why,
    never KeyError (same contract as the tenant latency table).
    """
    out = ["| family | strategy | pool classes | decode tokens | "
           "tokens/s | preemptions | swap out/in | tokens match |",
           "|---|---|---|---|---|---|---|---|"]
    ma = doc.get("mixed_arch")
    if not ma or not ma.get("families"):
        out.append("| n/a | n/a | n/a | n/a | n/a | n/a | n/a | n/a |")
        out.append("")
        out.append("no mixed-architecture section in this snapshot "
                   "(pre-architecture-registry BENCH_serve.json)")
        return "\n".join(out)

    def cell(v, fmt="{}"):
        return "n/a" if v is None else fmt.format(v)

    for fam in sorted(ma["families"]):
        r = ma["families"][fam]
        tps = r.get("tokens_per_s")
        out.append(
            f"| {fam} | {r.get('strategy', 'n/a')} | "
            f"{' '.join(r.get('pool_classes', [])) or 'n/a'} | "
            f"{cell(r.get('decode_tokens'))} | "
            f"{'n/a' if tps is None else f'{tps:.1f}'} | "
            f"{cell(r.get('preemptions'))} | "
            f"{cell(r.get('swap_outs'))}/{cell(r.get('swap_ins'))} | "
            f"{r.get('tokens_match', 'n/a')} |")
    return "\n".join(out)


def fmt_decode_path_table(doc: Dict) -> str:
    """Render the resident-decode section of BENCH_serve.json: the
    ``decode_path`` probe (device-persistent tables + delta sync + the
    fused donated step tail vs the eager full-rebuild fallback) and the
    workload run's per-step phase breakdown.

    Degrades gracefully on pre-resident snapshots that lack the
    section: renders an "n/a" row and says why, never KeyError (same
    contract as the other section tables).
    """
    out = ["| mode | tokens/s | uploads/step | rows scattered | "
           "sync bytes | completed |",
           "|---|---|---|---|---|---|"]
    dp = doc.get("decode_path")
    if not dp or "resident" not in dp:
        out.append("| n/a | n/a | n/a | n/a | n/a | n/a |")
        out.append("")
        out.append("no resident-decode section in this snapshot "
                   "(pre-resident-path BENCH_serve.json)")
        return "\n".join(out)

    def cell(v):
        return "n/a" if v is None else v

    for mode in ("resident", "eager"):
        r = dp.get(mode) or {}
        tps = r.get("tokens_per_s")
        out.append(
            f"| {mode} | {'n/a' if tps is None else f'{tps:.1f}'} | "
            f"{cell(r.get('host_uploads_per_step'))} | "
            f"{cell(r.get('table_rows_updated'))} | "
            f"{cell(r.get('table_sync_bytes'))} | "
            f"{cell(r.get('completed'))} |")
    out.append("")
    out.append(f"token identical: {dp.get('token_identical', 'n/a')}")
    ph = doc.get("phase_time_s")
    if ph:
        total = sum(ph.values()) or 1.0
        shares = ", ".join(f"{k} {v / total:.0%}"
                           for k, v in sorted(ph.items(),
                                              key=lambda kv: -kv[1]))
        out.append(f"workload step-phase wall share: {shares} "
                   f"(uploads/step "
                   f"{doc.get('host_uploads_per_step', 'n/a')}, "
                   f"table sync bytes "
                   f"{doc.get('table_sync_bytes', 'n/a')})")
    return "\n".join(out)


def fmt_migrate_table(doc: Dict) -> str:
    """Render the cross-process section (``migrate`` of
    BENCH_serve.json, or a standalone BENCH_migrate.json): the live
    migration's pre-copy/stop-and-copy breakdown and the
    prefill/decode-disaggregation handoff line.

    Degrades gracefully on pre-migration snapshots that lack the
    section: renders an "n/a" row and says why, never KeyError (same
    contract as the tenant latency and family tables).
    """
    out = ["| phase | rounds | blocks | bytes | pause steps | "
           "token identical |",
           "|---|---|---|---|---|---|"]
    mg = doc.get("migrate", doc if "migration" in doc else None)
    if not mg or not mg.get("migration"):
        out.append("| n/a | n/a | n/a | n/a | n/a | n/a |")
        out.append("")
        out.append("no cross-process section in this snapshot "
                   "(pre-migration BENCH_serve.json)")
        return "\n".join(out)
    m = mg["migration"]

    def cell(v):
        return "n/a" if v is None else v

    out.append(
        f"| pre-copy | {cell(m.get('rounds'))} | "
        f"{cell(m.get('precopy_blocks'))} | "
        f"{cell(m.get('precopy_bytes'))} | — | — |")
    out.append(
        f"| stop-and-copy | — | {cell(m.get('stop_copy_blocks'))} | "
        f"{cell(m.get('stop_copy_bytes'))} | "
        f"{cell(m.get('pause_steps'))} | "
        f"{m.get('token_identical', 'n/a')} |")
    per_round = m.get("blocks_per_round")
    if per_round:
        out.append("")
        out.append("blocks per pre-copy round: "
                   + " -> ".join(str(b) for b in per_round)
                   + f" (stop-copy tail {m.get('stop_copy_blocks', 'n/a')})")
    d = mg.get("disagg")
    if d:
        out.append(
            f"prefill/decode handoff: {d.get('handoffs', 'n/a')} bundles, "
            f"{d.get('handoff_bytes', 'n/a')} bytes, token identical: "
            f"{d.get('token_identical', 'n/a')}")
    return "\n".join(out)


def main(path: str) -> None:
    if path.endswith(".json"):
        with open(path) as f:
            doc = json.load(f)
        if "migration" in doc:        # standalone BENCH_migrate.json
            print("### Cross-process: live migration + disaggregation\n")
            print(fmt_migrate_table(doc))
            return
        arena = doc.get("arena", doc if "classes" in doc else None)
        if arena is None:
            raise SystemExit(f"{path}: no ArenaStats ('arena' key) found")
        print("### Unified address space (ArenaStats)\n")
        print(fmt_arena_table(arena))
        transfers = doc.get("transfers") or arena.get("transfers")
        if transfers:
            print("\n### Transfer plane (TransferStats)\n")
            print(fmt_transfer_table(transfers))
        print("\n### Request plane: per-tenant latency\n")
        print(fmt_tenant_latency_table(doc))
        print("\n### Architecture registry: per-family serving\n")
        print(fmt_family_table(doc))
        print("\n### Resident decode path: delta sync + fused tail\n")
        print(fmt_decode_path_table(doc))
        print("\n### Cross-process: live migration + disaggregation\n")
        print(fmt_migrate_table(doc))
        return
    rows = load(path)
    print("### Single-pod (16x16 = 256 chips)\n")
    print(fmt_table(rows, "16x16"))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(fmt_table(rows, "pod2x16x16"))


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_report.jsonl")
