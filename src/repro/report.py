"""Render dryrun_report.jsonl into the EXPERIMENTS.md roofline tables."""

from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(path: str) -> List[Dict]:
    rows = []
    for line in open(path):
        try:
            rows.append(json.loads(line))
        except json.JSONDecodeError:
            pass
    return rows


def fmt_table(rows: List[Dict], mesh: str) -> str:
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | dominant mem op | useful/HLO | roofline frac | "
           "peak GB/chip |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"N/A-by-spec | — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | "
                       f"{r.get('error','')[:60]} | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['bottleneck']} | {r.get('dominant_mem_op', '-')} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"**{r['roofline_fraction']:.3f}** | "
            f"{r['peak_mem_gb_per_chip']:.1f} |")
    return "\n".join(out)


def fmt_dryrun_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | status | HLO GFLOP/chip | HBM GB/chip | "
           "coll GB/chip | compile (s) |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
                f"{r['hlo_gflops_per_chip']:.0f} | "
                f"{r['hbm_gb_per_chip']:.1f} | {r['coll_gb_per_chip']:.2f} | "
                f"{r.get('t_compile_s','')} |")
        else:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']} | — | — | — | — |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1 else "dryrun_report.jsonl")
    print("### Single-pod (16x16 = 256 chips)\n")
    print(fmt_table(rows, "16x16"))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(fmt_table(rows, "pod2x16x16"))
