"""Instruction-level memory-traffic accounting over optimized HLO.

The measurement instrument behind every number this repo reports: walks
``Compiled.as_text()`` with loop trip counts multiplied through and
charges each executed instruction for the bytes it actually moves (see
``accounting`` for the rule table and ``README.md`` for the mapping to
the paper's cost model).

Public API:

  * ``analyze_text(hlo) -> Cost``      -- flops / bytes / coll / by_op
  * ``analyze_compiled(compiled)``     -- same, from a jax Compiled
  * ``attribute(hlo, top=20)``         -- per-(opcode, shape) byte tally
  * ``xla_cost_analysis(compiled)``    -- version-normalized raw XLA dict
  * ``Cost``, ``HloCostModel``, ``shape_bytes`` -- building blocks
"""

from __future__ import annotations

from repro.cost.accounting import (COLLECTIVE_OPS, Cost,  # noqa: F401
                                   HloCostModel)
from repro.cost.parser import (Instr, Module, parse_module,  # noqa: F401
                               shape_bytes, shape_dims)
from repro.cost.xla import (xla_bytes_accessed, xla_cost_analysis,  # noqa: F401
                            xla_flops)

__all__ = [
    "COLLECTIVE_OPS", "Cost", "HloCostModel", "Instr", "Module",
    "analyze_text", "analyze_compiled", "attribute", "parse_module",
    "shape_bytes", "shape_dims", "xla_bytes_accessed", "xla_cost_analysis",
    "xla_flops",
]


def analyze_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()


def analyze_compiled(compiled) -> Cost:
    return analyze_text(compiled.as_text())


def attribute(hlo_text: str, top: int = 20, min_bytes: float = 1e11):
    return HloCostModel(hlo_text).attribute(top=top, min_bytes=min_bytes)
