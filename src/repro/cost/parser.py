"""Text-level HLO parsing: shapes, instructions, computations, trip counts.

This is the lexical layer of the cost subsystem -- no accounting policy
lives here.  It turns ``Compiled.as_text()`` into:

  * ``Computation``: named instruction list with the ROOT marked,
  * per-computation s32 literal constants (the legacy trip-count source),
  * ``known_trip_count`` backend configs on ``while`` instructions (the
    preferred trip-count source -- XLA writes it after loop analysis).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_CONST_RE = re.compile(
    r"\s*(?:ROOT\s+)?%([\w\.\-]+) = s32\[\] constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')

ENTRY = "__entry__"


def shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    """All 'dtype[d0,d1]' tokens in a (possibly tuple) shape string."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def shape_bytes(shape_str: str) -> int:
    """Total byte size of a shape string (tuples summed)."""
    total = 0
    for dt, dims in shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str          # result shape string (may be a tuple)
    opcode: str
    operands: List[str]
    args: str           # raw text inside the operand parens
    attrs: str          # everything after the operand parens
    is_root: bool = False

    def param_index(self) -> Optional[int]:
        """For ``parameter(N)`` instructions, N."""
        if self.opcode != "parameter":
            return None
        m = re.match(r"\s*(\d+)", self.args)
        return int(m.group(1)) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_entry: bool = False

    @property
    def root(self) -> Optional[Instr]:
        for ins in self.instrs:
            if ins.is_root:
                return ins
        return self.instrs[-1] if self.instrs else None

    def symtab(self) -> Dict[str, str]:
        return {i.name: i.shape for i in self.instrs}

    def by_name(self) -> Dict[str, Instr]:
        return {i.name: i for i in self.instrs}


def parse_instruction(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    is_root = bool(m.group(1))
    name, rest = m.group(2), m.group(3).strip()
    # rest = "<shape> <opcode>(<args>), attrs..."; shape may be a tuple
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        shape = rest[: i + 1]
        rest2 = rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        rest2 = rest[sp + 1:].strip()
    pm = re.match(r"([\w\-]+)\((.*)$", rest2, re.DOTALL)
    if not pm:
        return None
    opcode = pm.group(1)
    tail = pm.group(2)
    depth = 1
    for i, ch in enumerate(tail):
        depth += ch == "("
        depth -= ch == ")"
        if depth == 0:
            break
    args = tail[:i]
    attrs = tail[i + 1:]
    operands = re.findall(r"%([\w\.\-]+)", args)
    return Instr(name, shape, opcode, operands, args, attrs, is_root)


@dataclasses.dataclass
class Module:
    """Parsed HLO module: computations + trip-count evidence."""
    comps: Dict[str, Computation]
    consts: Dict[Tuple[str, str], int]    # (computation, instr) -> value

    def entry(self) -> Optional[Computation]:
        if ENTRY in self.comps:
            return self.comps[ENTRY]
        if not self.comps:
            return None
        return max(self.comps.values(), key=lambda c: len(c.instrs))

    def max_s32_const(self, comp_name: str) -> Optional[int]:
        vals = [v for (c, _), v in self.consts.items() if c == comp_name]
        return max(vals) if vals else None

    def trip_count(self, while_ins: Instr) -> int:
        """Trip count of a ``while``: prefer XLA's ``known_trip_count``
        backend config; fall back to the largest s32 literal in the
        condition computation (a scan compares the induction variable
        against ``constant(N)``); default 1."""
        m = _TRIP_RE.search(while_ins.attrs)
        if m:
            return int(m.group(1))
        cm = re.search(r"condition=%?([\w\.\-]+)", while_ins.attrs)
        if cm:
            v = self.max_s32_const(cm.group(1))
            if v is not None:
                return v
        return 1


def parse_module(hlo_text: str) -> Module:
    comps: Dict[str, Computation] = {}
    consts: Dict[Tuple[str, str], int] = {}
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(2), [], is_entry=bool(m.group(1)))
            continue
        if line.startswith("}") or line.strip() == "}":
            comps[cur.name] = cur
            if cur.is_entry:
                comps[ENTRY] = cur
            cur = None
            continue
        ins = parse_instruction(line)
        if ins:
            cur.instrs.append(ins)
            cm = _CONST_RE.match(line)
            if cm:
                consts[(cur.name, cm.group(1))] = int(cm.group(2))
    return Module(comps, consts)
