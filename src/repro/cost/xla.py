"""Normalization of jax's ``Compiled.cost_analysis()`` across versions.

jax has returned, depending on version: a dict, a list with one dict per
partition (possibly empty), or raised for backends without the analysis.
Every in-repo consumer goes through :func:`xla_cost_analysis` and gets a
plain ``dict`` (empty when unavailable) -- never a list, never an
exception.
"""

from __future__ import annotations

from typing import Any, Dict


def xla_cost_analysis(compiled: Any) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as a flat dict, ``{}`` on any failure.

    Handles the 0.4.x list-of-dicts shape (the
    ``TypeError: list indices must be integers`` trap) and the >=0.5
    plain-dict shape uniformly.
    """
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    try:
        return dict(ca)
    except Exception:
        return {}


def xla_flops(compiled: Any) -> float:
    return float(xla_cost_analysis(compiled).get("flops", 0.0))


def xla_bytes_accessed(compiled: Any) -> float:
    return float(xla_cost_analysis(compiled).get("bytes accessed", 0.0))
