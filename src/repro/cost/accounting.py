"""Instruction-level FLOP / HBM-byte / collective accounting over HLO.

Policy layer: given a parsed module (``cost.parser``), attribute to every
*executed* instruction (loop trip counts multiplied through) the memory
traffic it actually generates.  The paper's argument hinges on charging
software memory management for the bytes it MOVES, not the buffers it
TOUCHES:

  * ``dynamic-update-slice`` writes the update slice in place -- bill
    2 x update bytes (read update + write slice), never the full buffer;
  * ``dynamic-slice`` / ``gather`` move the slice/gathered rows -- bill
    2 x result bytes (+ index reads for gather/scatter);
  * fusions are billed at their HBM boundary (internals live in
    registers/cache): parameter reads + root write, with two aliasing
    refinements -- a fusion rooted in ``dynamic-update-slice`` updates
    its target in place (bill the update, skip the aliased operand),
    and a parameter consumed only through ``gather``/``dynamic-slice``
    is charged for the rows actually read, not the whole operand;
  * ``while`` is a control construct: its body/condition are billed
    once per trip, the instruction itself moves nothing (the carry is
    aliased in place by XLA);
  * ``call`` is inlining -- recurse fully; ``conditional`` takes the
    most expensive branch.

Every byte lands in a category (``Cost.by_op``) so the roofline/report
layers can show *what kind* of traffic dominates: the paper-relevant
split is matmul vs. gather (block-table indirection) vs.
dynamic-update-slice (block copies) vs. collective vs. everything else.
"""

from __future__ import annotations

import collections
import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.cost.parser import (ENTRY, Computation, Instr, Module,
                               parse_module, shape_bytes, shape_dims)

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# opcodes that move no data themselves (metadata / aliasing / control)
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "while", "conditional", "call", "custom-call-start"}

# ops whose result shape understates nothing: billed 2x result
_SLICE_READ_OPS = ("dynamic-slice", "gather")

#: categories reported in ``Cost.by_op`` (stable keys for reports)
CATEGORY_MATMUL = "matmul"
CATEGORY_DUS = "dynamic-update-slice"
CATEGORY_DSLICE = "dynamic-slice"
CATEGORY_GATHER = "gather"
CATEGORY_SCATTER = "scatter"
CATEGORY_COLLECTIVE = "collective"
CATEGORY_COPY = "copy"
CATEGORY_FUSION = "fusion"
CATEGORY_OTHER = "other"


def _collective_kind(opcode: str) -> Optional[str]:
    base = opcode
    for suf in ("-start", "-done"):
        if base.endswith(suf):
            base = base[: -len(suf)]
    return base if base in COLLECTIVE_OPS else None


def dominant_category(by_op: Optional[Dict[str, float]]) -> str:
    """Largest-bytes category of a ``Cost.by_op`` dict ('-' when empty)."""
    if not by_op:
        return "-"
    return max(by_op, key=by_op.get)


def _category(opcode: str) -> str:
    if opcode in ("dot", "convolution"):
        return CATEGORY_MATMUL
    if opcode == "dynamic-update-slice":
        return CATEGORY_DUS
    if opcode == "dynamic-slice":
        return CATEGORY_DSLICE
    if opcode == "gather":
        return CATEGORY_GATHER
    if opcode == "scatter":
        return CATEGORY_SCATTER
    if _collective_kind(opcode):
        return CATEGORY_COLLECTIVE
    if opcode in ("copy", "copy-start"):
        return CATEGORY_COPY
    if opcode == "fusion":
        return CATEGORY_FUSION
    return CATEGORY_OTHER


@dataclasses.dataclass
class Cost:
    """Roofline quantities with a per-op-category byte breakdown."""
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None
    by_op: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in COLLECTIVE_OPS}
        if self.by_op is None:
            self.by_op = {}

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k in COLLECTIVE_OPS:
            self.coll[k] += other.coll[k] * times
        for k, v in other.by_op.items():
            self.by_op[k] = self.by_op.get(k, 0.0) + v * times

    def add_bytes(self, category: str, n: float):
        self.bytes += n
        self.by_op[category] = self.by_op.get(category, 0.0) + n

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())

    def dominant_op(self) -> str:
        return dominant_category(self.by_op)


class HloCostModel:
    """Walks a parsed module, multiplying loop bodies by trip counts."""

    def __init__(self, hlo_text: str):
        self.module: Module = parse_module(hlo_text)
        self.comps = self.module.comps
        self._memo: Dict[str, Cost] = {}

    # ---- flops ---------------------------------------------------------

    def _dot_flops(self, ins: Instr, sym: Dict[str, str]) -> float:
        res = 1
        for _, dims in shape_dims(ins.shape):
            for d in dims:
                res *= d
        lhs = sym.get(ins.operands[0]) if ins.operands else None
        contract = 1
        if lhs:
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
            ldims = shape_dims(lhs)
            if m and ldims:
                dims = ldims[0][1]
                for i in (int(x) for x in m.group(1).split(",") if x):
                    if i < len(dims):
                        contract *= dims[i]
        return 2.0 * res * contract

    def _conv_flops(self, ins: Instr, sym: Dict[str, str]) -> float:
        """Exact convolution FLOPs: every output element is a dot of
        length (kernel spatial product x per-group input channels), so

            flops = 2 * result_elements * prod(kernel_spatial) * C_in_grp

        The kernel operand's 'i' dimension in HLO is ALREADY divided by
        ``feature_group_count``, so grouped/depthwise convs need no
        extra correction.  Falls back to the old 2x-result-elements
        approximation only when the kernel shape or dim_labels cannot be
        resolved.
        """
        res = 1
        for d in shape_dims(ins.shape)[0][1] if shape_dims(ins.shape) else []:
            res *= d
        rhs = (sym.get(ins.operands[1])
               if len(ins.operands) > 1 else None)
        m = re.search(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)", ins.attrs)
        if rhs and m:
            kdims = shape_dims(rhs)
            if kdims:
                klabels, kshape = m.group(2), kdims[0][1]
                if len(klabels) == len(kshape):
                    spatial = 1
                    in_ch = 1
                    for lbl, dim in zip(klabels, kshape):
                        if lbl.isdigit():
                            spatial *= dim
                        elif lbl == "i":
                            in_ch *= dim
                    return 2.0 * res * spatial * in_ch
        return 2.0 * res

    def _called(self, ins: Instr) -> List[str]:
        out = []
        for m in re.finditer(
                r"(?:calls|to_apply|branch_computations)="
                r"\{?%?([\w\.\-,% ]+)\}?", ins.attrs):
            out.extend(re.findall(r"[\w\.\-]+", m.group(1)))
        return out

    def _fusion_traffic(self, ins: Instr) -> List[Tuple[str, float]]:
        """HBM boundary of a fusion: root write(s) + parameter reads,
        with in-place DUS and sliced-read (gather/dynamic-slice)
        refinements.  Multi-output fusions (root ``tuple``) are billed
        per element, so a fused K+V cache write is two slice-sized DUS
        bills, not two pool-sized ones."""
        called = self._called(ins)
        comp = self.comps.get(called[0]) if called else None
        if comp is None:
            return [(CATEGORY_FUSION, float(shape_bytes(ins.shape)))]
        sym = comp.symtab()
        byname = comp.by_name()
        root = comp.root
        out: List[Tuple[str, float]] = []

        # see through layout-only ops so a pool->bitcast->gather chain
        # (or a bitcast-wrapped DUS target) still resolves to the pool
        # parameter
        alias: Dict[str, str] = {}
        for bi in comp.instrs:
            if bi.opcode in ("bitcast", "reshape", "copy") and bi.operands:
                src = bi.operands[0]
                if src in byname and byname[src].opcode == "parameter":
                    alias[bi.name] = src
                elif src in alias:
                    alias[bi.name] = alias[src]

        def resolve_param(name: Optional[str]) -> Optional[str]:
            if name is None:
                return None
            if name in byname and byname[name].opcode == "parameter":
                return name
            return alias.get(name)

        roots: List[Instr] = []
        if root is not None and root.opcode == "tuple":
            roots = [byname[o] for o in root.operands if o in byname]
        elif root is not None:
            roots = [root]
        aliased: set = set()
        for r in roots:
            if r.opcode == "dynamic-update-slice":
                upd = r.operands[1] if len(r.operands) > 1 else None
                upd_b = shape_bytes(sym.get(upd, "")) if upd else 0
                out.append((CATEGORY_DUS, float(upd_b)))  # in-place write
                p = resolve_param(r.operands[0] if r.operands else None)
                if p:
                    aliased.add(p)                         # not re-read
            else:
                out.append((CATEGORY_FUSION, float(shape_bytes(r.shape))))

        uses: Dict[str, List[Instr]] = collections.defaultdict(list)
        for bi in comp.instrs:
            for o in bi.operands:
                p = (o if o in byname and byname[o].opcode == "parameter"
                     else alias.get(o))
                if p and alias.get(bi.name) != p:
                    uses[p].append(bi)
        for pi in comp.instrs:
            if pi.opcode != "parameter" or pi.name in aliased:
                continue
            pu = uses.get(pi.name, [])
            if not pu:
                continue
            sliced = all(
                u.opcode in _SLICE_READ_OPS and u.operands
                and (u.operands[0] == pi.name
                     or alias.get(u.operands[0]) == pi.name)
                for u in pu)
            if sliced:
                for u in pu:
                    cat = (CATEGORY_GATHER if u.opcode == "gather"
                           else CATEGORY_DSLICE)
                    out.append((cat, float(shape_bytes(u.shape))))
            else:
                out.append((CATEGORY_FUSION,
                            float(shape_bytes(pi.shape))))
        return out

    def instr_traffic(self, ins: Instr,
                      sym: Dict[str, str]) -> List[Tuple[str, float]]:
        """(category, bytes) contributions of one executed instruction.

        Control-flow ops return [] -- their bodies are billed by the
        walker.  This is the single byte-attribution rule table; both
        ``cost_of`` and ``attribute`` consume it.
        """
        op = ins.opcode
        kind = _collective_kind(op)
        if kind:
            # async pairs: the '-start' result is a tuple that carries
            # the input too -- bill the output once, at the '-done'
            if op.endswith("-start"):
                return []
            return [(CATEGORY_COLLECTIVE, float(shape_bytes(ins.shape)))]
        if op in _FREE_OPS or op.endswith("-done"):
            return []
        if op == "fusion":
            return self._fusion_traffic(ins)
        if op == "dynamic-update-slice":
            # in-place: read update + write slice, NOT the whole buffer
            # (XLA aliases operand 0)
            upd = (shape_bytes(sym[ins.operands[1]])
                   if len(ins.operands) > 1 and ins.operands[1] in sym
                   else shape_bytes(ins.shape))
            return [(CATEGORY_DUS, 2.0 * upd)]
        if op == "dynamic-slice":
            return [(CATEGORY_DSLICE, 2.0 * shape_bytes(ins.shape))]
        if op == "gather":
            idx = (shape_bytes(sym[ins.operands[1]])
                   if len(ins.operands) > 1 and ins.operands[1] in sym
                   else 0)
            return [(CATEGORY_GATHER, 2.0 * shape_bytes(ins.shape) + idx)]
        if op == "scatter":
            upd = (shape_bytes(sym[ins.operands[2]])
                   if len(ins.operands) > 2 and ins.operands[2] in sym
                   else shape_bytes(ins.shape))
            idx = (shape_bytes(sym[ins.operands[1]])
                   if len(ins.operands) > 1 and ins.operands[1] in sym
                   else 0)
            return [(CATEGORY_SCATTER, 2.0 * upd + idx)]
        # generic: result write + operand reads
        b = float(shape_bytes(ins.shape))
        for o in ins.operands:
            if o in sym:
                b += shape_bytes(sym[o])
        return [(_category(op), b)]

    # ---- walker --------------------------------------------------------

    def cost_of(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        self._memo[name] = total        # cycle guard
        if comp is None:
            return total
        sym = comp.symtab()
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                total.flops += self._dot_flops(ins, sym)
            elif op == "convolution":
                total.flops += self._conv_flops(ins, sym)
            elif op == "while":
                trips = self.module.trip_count(ins)
                body = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                cond = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                if body:
                    total.add(self.cost_of(body.group(1)), trips)
                if cond:
                    total.add(self.cost_of(cond.group(1)), trips)
            elif op == "call":
                for c in self._called(ins):
                    total.add(self.cost_of(c))
            elif op == "conditional":
                branches = [self.cost_of(c) for c in self._called(ins)]
                if branches:
                    total.add(max(branches, key=lambda c: c.bytes))
            elif op in ("fusion", "map", "reduce", "reduce-window",
                        "sort", "scatter", "select-and-scatter"):
                # internals: flops + collectives yes, bytes no (billed at
                # the boundary by instr_traffic)
                for c in self._called(ins):
                    sub = self.cost_of(c)
                    total.flops += sub.flops
                    for k in COLLECTIVE_OPS:
                        total.coll[k] += sub.coll[k]
            kind = _collective_kind(op)
            if kind and not op.endswith("-start"):
                # '-start' skipped: its tuple shape carries the input;
                # the output is billed once at the '-done' (or bare op)
                total.coll[kind] += shape_bytes(ins.shape)
            for cat, b in self.instr_traffic(ins, sym):
                total.add_bytes(cat, b)
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        comp = self.module.entry()
        if comp is None:
            return Cost()
        return self.cost_of(comp.name if not comp.is_entry else ENTRY)

    # ---- profiling -----------------------------------------------------

    def attribute(self, top: int = 20, min_bytes: float = 1e11):
        """Per-(opcode, shape) byte tally with trip multipliers -- the
        §Perf profiling view (what dominates the memory term?)."""
        tally: collections.Counter = collections.Counter()

        def walk(name: str, mult: float):
            comp = self.comps.get(name)
            if comp is None:
                return
            sym = comp.symtab()
            for ins in comp.instrs:
                if ins.opcode == "while":
                    t = self.module.trip_count(ins)
                    b = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                    c = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                    if b:
                        walk(b.group(1), mult * t)
                    if c:
                        walk(c.group(1), mult * t)
                    continue
                if ins.opcode == "call":
                    for c in self._called(ins):
                        walk(c, mult)
                    continue
                if ins.opcode == "conditional":
                    # mirror cost_of: bill the most expensive branch
                    branches = self._called(ins)
                    if branches:
                        walk(max(branches,
                                 key=lambda b: self.cost_of(b).bytes),
                             mult)
                    continue
                b = sum(v for _, v in self.instr_traffic(ins, sym))
                if not b:
                    continue
                bm = b * mult
                key = (ins.opcode,
                       ins.shape[:48] if bm > min_bytes else "(small)")
                tally[key] += bm

        comp = self.module.entry()
        if comp is not None:
            walk(comp.name, 1)
        return tally.most_common(top)
