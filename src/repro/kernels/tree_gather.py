"""Pallas TPU kernel: stream a TreeArray in logical order.

This is the paper's **iterator optimization as a DMA schedule**: the
(flattened) leaf table is a *scalar-prefetch* operand living in SMEM, and
the ``BlockSpec.index_map`` reads it to decide which physical leaf block
to DMA from HBM into VMEM next.  The Mosaic pipeline overlaps the table
lookup + DMA of block ``i+1`` with compute on block ``i`` -- i.e. the
software equivalent of a page-table-walk cache *plus* the prefetcher the
paper credits for hiding TLB miss latency (§4.4), with zero translation
hardware.

Kernels
-------
``tree_gather``     : materialize the logical array (linear scan / copy).
``tree_block_sum``  : per-leaf partial sums (linear-scan reduce) -- the
                      Table 2 'Linear Scan: Iter' discipline.
``tree_gather_rows``: gather logical *rows* of a 2-D blocked array via the
                      table (paged embedding lookup; GUPS-style random
                      access uses ``ref.tree_gather_elems`` -- truly random
                      single-element access has no block locality to
                      exploit, which is the paper's own observation about
                      GUPS).

Block shapes: leaves are ``(leaf_size,)`` with leaf_size a multiple of
128*8 so a (8,128)-tiled f32 block is MXU/VPU aligned; 8192 f32 elements
= the paper's 32 KB block.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU grid spec (works under interpret mode on CPU too)
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _gather_kernel(table_ref, leaves_ref, out_ref):
    # whole-block copy; the interesting work happened in the index_map
    out_ref[...] = leaves_ref[...]


def _block_sum_kernel(table_ref, leaves_ref, out_ref):
    out_ref[0] = jnp.sum(leaves_ref[...], dtype=jnp.float32)


def tree_gather(leaves: jax.Array, leaf_table: jax.Array,
                *, interpret: bool = False) -> jax.Array:
    """(num_blocks, leaf) pool + (n_logical,) table -> (n_logical, leaf)."""
    n_logical = leaf_table.shape[0]
    leaf = leaves.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_logical,),
        in_specs=[pl.BlockSpec((1, leaf), lambda i, tbl: (tbl[i], 0))],
        out_specs=pl.BlockSpec((1, leaf), lambda i, tbl: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_logical, leaf), leaves.dtype),
        interpret=interpret,
    )(leaf_table, leaves)


def tree_block_sum(leaves: jax.Array, leaf_table: jax.Array,
                   *, interpret: bool = False) -> jax.Array:
    """Per-logical-leaf partial sums: (n_logical,) f32."""
    n_logical = leaf_table.shape[0]
    leaf = leaves.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_logical,),
        in_specs=[pl.BlockSpec((1, leaf), lambda i, tbl: (tbl[i], 0))],
        out_specs=pl.BlockSpec((1,), lambda i, tbl: (i,)),
    )
    return pl.pallas_call(
        _block_sum_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_logical,), jnp.float32),
        interpret=interpret,
    )(leaf_table, leaves)


def _gather_rows_kernel(row_block_ref, row_off_ref, pool_ref, out_ref):
    # one logical row per grid step; the block is selected by the
    # index_map, the row-within-block by an SMEM offset here.
    i = pl.program_id(0)
    off = row_off_ref[i]
    out_ref[0, :] = pool_ref[0, off, :]


def tree_gather_rows(pool: jax.Array, row_ids: jax.Array, leaf_table: jax.Array,
                     rows_per_block: int, *, interpret: bool = False) -> jax.Array:
    """Gather rows of a blocked 2-D array (paged embedding table).

    pool: (num_blocks, rows_per_block, width); row_ids: (n,) logical row
    numbers; leaf_table: (num_logical_blocks,) physical block of each
    logical block.  Returns (n, width).

    The index_map composes table lookup with the row's block number --
    a full software 'page walk' per row, but hoisted into the prefetch
    pipeline (iterator discipline for the block, SMEM offset for the row).
    """
    n = row_ids.shape[0]
    width = pool.shape[2]
    row_block = row_ids // rows_per_block           # logical block per row
    row_off = (row_ids % rows_per_block).astype(jnp.int32)
    phys = leaf_table[row_block].astype(jnp.int32)  # resolve once (bulk walk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # phys block per row, offset per row
        grid=(n,),
        in_specs=[pl.BlockSpec((1, rows_per_block, width),
                               lambda i, blk, off: (blk[i], 0, 0))],
        out_specs=pl.BlockSpec((1, width), lambda i, blk, off: (i, 0)),
    )
    return pl.pallas_call(
        _gather_rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, width), pool.dtype),
        interpret=interpret,
    )(phys, row_off, pool)
