"""Jit'd public wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the Mosaic kernels run natively; on CPU
(this container) ``interpret=True`` executes the kernel bodies exactly,
and the *reference* path is what the dry-run lowers (see
``repro.models.attention.decode_attention``).  ``force`` overrides for
tests.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import block_copy as _bc
from repro.kernels import paged_attention as _pa
from repro.kernels import paged_prefill as _pp
from repro.kernels import tree_gather as _tg
from repro.kernels import ref as kref


def _use_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def tree_gather(leaves, leaf_table, interpret: Optional[bool] = None):
    return _tg.tree_gather(leaves, leaf_table,
                           interpret=_use_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def tree_block_sum(leaves, leaf_table, interpret: Optional[bool] = None):
    return _tg.tree_block_sum(leaves, leaf_table,
                              interpret=_use_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def tree_gather_rows(pool, row_ids, leaf_table, rows_per_block: int,
                     interpret: Optional[bool] = None):
    return _tg.tree_gather_rows(pool, row_ids, leaf_table, rows_per_block,
                                interpret=_use_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "window", "v_dim", "interpret"))
def paged_attention(q, k_pool, v_pool, block_tables, seq_lens,
                    scale: Optional[float] = None,
                    softcap: Optional[float] = None,
                    window: Optional[int] = None,
                    v_dim: Optional[int] = None,
                    interpret: Optional[bool] = None):
    return _pa.paged_attention(
        q, k_pool, v_pool, block_tables, seq_lens, scale=scale,
        softcap=softcap, window=window, v_dim=v_dim,
        interpret=_use_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "window", "interpret"), donate_argnums=(3, 4))
def paged_attention_append(q, k_new, v_new, k_pool, v_pool, block_tables,
                           seq_lens,
                           scale: Optional[float] = None,
                           softcap: Optional[float] = None,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None):
    """Fused append-then-attend decode step; pools donated (in-place)."""
    return _pa.paged_attention_append(
        q, k_new, v_new, k_pool, v_pool, block_tables, seq_lens,
        scale=scale, softcap=softcap, window=window,
        interpret=_use_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "window", "v_dim", "q_chunk", "interpret"))
def paged_prefill_attention(q, k_pool, v_pool, block_tables, kv_lens,
                            q_starts,
                            scale: Optional[float] = None,
                            softcap: Optional[float] = None,
                            window: Optional[int] = None,
                            v_dim: Optional[int] = None,
                            q_chunk: Optional[int] = None,
                            interpret: Optional[bool] = None):
    """Suffix prefill attention through the block table (COW sharing)."""
    return _pp.paged_prefill_attention(
        q, k_pool, v_pool, block_tables, kv_lens, q_starts, scale=scale,
        softcap=softcap, window=window, v_dim=v_dim, q_chunk=q_chunk,
        interpret=_use_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def block_copy(pool, src, dst, interpret: Optional[bool] = None):
    return _bc.block_copy(pool, src, dst,
                          interpret=_use_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_blocks(pool, idx, interpret: Optional[bool] = None):
    """Compact (L, n, *block) gather of blocks ``idx`` (swap-out path)."""
    return _bc.gather_blocks(pool, idx,
                             interpret=_use_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def copy_pool_blocks(pool, src, dst, interpret: Optional[bool] = None):
    """Layer-stacked block copy plan (COW fulfilment / relocation)."""
    return _bc.copy_pool_blocks(pool, src, dst,
                                interpret=_use_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def scatter_blocks(pool, idx, payload, interpret: Optional[bool] = None):
    """Scatter a compact (L, n, *block) payload into blocks ``idx``
    (swap-in path -- the inverse of ``gather_blocks``)."""
    return _bc.scatter_blocks(pool, idx, payload,
                              interpret=_use_interpret(interpret))


# re-export oracles for convenience
tree_gather_ref = kref.tree_gather_ref
tree_block_sum_ref = kref.tree_block_sum_ref
tree_gather_rows_ref = kref.tree_gather_rows_ref
paged_attention_ref = kref.paged_attention_ref
paged_attention_append_ref = kref.paged_attention_append_ref
paged_prefill_attention_ref = kref.paged_prefill_attention_ref
