"""Pallas TPU kernel: flash-decoding attention over a paged KV cache.

One new query token per sequence attends over KV stored in fixed-size
token blocks addressed through a block table (the paper's arrays-as-trees
applied to the KV cache).  The block table and sequence lengths are
**scalar-prefetch** operands in SMEM: the BlockSpec index_map dereferences
``table[b, j]`` to pick which physical KV block the next grid step DMAs
into VMEM -- the iterator/PTW-cache discipline, so the "tree walk" is
entirely off the critical path (overlapped with the previous block's
flash update).

Grid: ``(batch, kv_heads, max_blocks_per_seq)``; the last axis is the
sequential flash-decoding sweep with running (m, l, acc) scratch in VMEM.
Blocks past ``ceil(seq_len / bt)`` contribute nothing (masked to -1e30),
matching the reference exactly; a production TPU build would additionally
early-out via ``pltpu.when``-guarded DMA, which does not change results.

Supports:
  * GQA/MQA: q has ``G = q_heads // kv_heads`` rows per kv head.
  * logit softcap (gemma2), sliding window (gemma2/gemma3 local layers).
  * MLA latent mode: ``kv_heads=1``, ``head_dim = kv_lora + rope`` and
    values are the first ``v_dim`` (= kv_lora) lanes of the SAME latent
    blocks -- the "absorbed" DeepSeek decode, where the paged pool stores
    only the compressed stream.

MXU alignment: head_dim (128/256) and block_tokens (64..256 multiple of
8) give (8,128)-tileable operands; the score matmul is (G, HD) x (HD, BT)
and the value matmul (G, BT) x (BT, VD).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

_NEG = -1e30


def _paged_attn_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                       m_scr, l_scr, acc_scr, *, block_tokens: int,
                       scale: float, softcap: Optional[float],
                       window: Optional[int], num_blocks_grid: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, HD)
    k = k_ref[0, :, 0, :].astype(jnp.float32)    # (BT, HD)
    v = v_ref[0, :, 0, :].astype(jnp.float32)    # (BT, VD)

    s = jax.lax.dot_general(q * scale, k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, BT)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    seq_len = lens_ref[b]
    pos = j * block_tokens + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < seq_len
    if window is not None:
        valid = jnp.logical_and(valid, pos >= seq_len - window)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_scr[...]                          # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)              # (G, 1)
    p = jnp.exp(s - m_new)                       # (G, BT)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(j == num_blocks_grid - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _paged_append_kernel(tables_ref, lens_ref, q_ref, kn_ref, vn_ref,
                         k_ref, v_ref, o_ref, ok_ref, ov_ref,
                         m_scr, l_scr, acc_scr, *, block_tokens: int,
                         scale: float, softcap: Optional[float],
                         window: Optional[int], num_blocks_grid: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = lens_ref[b]
    jt = jnp.minimum(seq_len // block_tokens, num_blocks_grid - 1)
    off = seq_len - jt * block_tokens        # >= BT only when table full

    q = q_ref[0, 0].astype(jnp.float32)          # (G, HD)
    k = k_ref[0, :, 0, :].astype(jnp.float32)    # (BT, HD)
    v = v_ref[0, :, 0, :].astype(jnp.float32)    # (BT, VD)

    # Splice the new token's row into the tail block before scoring: the
    # append happens in VMEM, on the block the scalar-prefetch table
    # already DMA'd for this grid step -- no second pass over the pool.
    row = jax.lax.broadcasted_iota(jnp.int32, (block_tokens, 1), 0)
    here = jnp.logical_and(j == jt, row == off)  # (BT, 1)
    k = jnp.where(here, kn_ref[0, 0].astype(jnp.float32)[None, :], k)
    v = jnp.where(here, vn_ref[0, 0].astype(jnp.float32)[None, :], v)

    @pl.when(j == jt)
    def _writeback():
        ok_ref[0, :, 0, :] = k.astype(ok_ref.dtype)
        ov_ref[0, :, 0, :] = v.astype(ov_ref.dtype)

    s = jax.lax.dot_general(q * scale, k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, BT)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    pos = j * block_tokens + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < seq_len + 1
    if window is not None:
        valid = jnp.logical_and(valid, pos >= seq_len + 1 - window)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_scr[...]                          # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)              # (G, 1)
    p = jnp.exp(s - m_new)                       # (G, BT)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(j == num_blocks_grid - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_append(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                           k_pool: jax.Array, v_pool: jax.Array,
                           block_tables: jax.Array, seq_lens: jax.Array, *,
                           scale: Optional[float] = None,
                           softcap: Optional[float] = None,
                           window: Optional[int] = None,
                           interpret: bool = False):
    """Fused append-then-attend flash decode (resident decode tail).

    Same sweep as ``paged_attention``, but the new token's K/V rows are
    written into the tail block *inside the kernel*: the scalar-prefetch
    table already names the tail block, so at grid step ``j == lens[b]
    // BT`` the kernel splices ``k_new/v_new`` into the in-VMEM block,
    flushes it back to the pool through ``input_output_aliases`` (the
    pools are donated, in-place), and attends over ``seq_lens + 1``
    positions.  One launch replaces scatter-write + attention.

    Tail blocks of live rows must be exclusively owned (the engine's COW
    barrier guarantees this); rows parked on a shared sink block flush
    in unspecified order, touching only sink garbage.  GQA/MQA only (no
    MLA latent mode: the latent pool's value lanes alias the key pool).

    q           : (B, KVH, G, HD)
    k_new       : (B, KVH, HD);  v_new: (B, KVH, VD)
    k_pool      : (NB, BT, KVH, HD);  v_pool: (NB, BT, KVH, VD)
    block_tables: (B, MB) int32;  seq_lens: (B,) int32 (pre-append)
    returns     : (o (B, KVH, G, VD), k_pool, v_pool)
    """
    B, KVH, G, HD = q.shape
    NB, BT, KVH_k, HD_k = k_pool.shape
    assert KVH_k == KVH and HD_k == HD, (q.shape, k_pool.shape)
    assert k_new.shape == (B, KVH, HD), k_new.shape
    MB = block_tables.shape[1]
    VD = v_pool.shape[-1]
    assert v_new.shape == (B, KVH, VD), v_new.shape
    if scale is None:
        scale = HD ** -0.5

    kernel = functools.partial(
        _paged_append_kernel, block_tokens=BT, scale=float(scale),
        softcap=softcap, window=window, num_blocks_grid=MB)

    def k_map(b, h, j, tbl, lens):
        return (jnp.maximum(tbl[b, j], 0), 0, h, 0)

    def tail_map(b, h, j, tbl, lens):
        jt = jnp.minimum(lens[b] // BT, MB - 1)
        return (jnp.maximum(tbl[b, jt], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, HD), lambda b, h, j, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, HD), lambda b, h, j, tbl, lens: (b, h, 0)),
            pl.BlockSpec((1, 1, VD), lambda b, h, j, tbl, lens: (b, h, 0)),
            pl.BlockSpec((1, BT, 1, HD), k_map),
            pl.BlockSpec((1, BT, 1, VD), k_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, VD),
                         lambda b, h, j, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, BT, 1, HD), tail_map),
            pl.BlockSpec((1, BT, 1, VD), tail_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, VD), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KVH, G, VD), q.dtype),
                   jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
                   jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype)],
        interpret=interpret,
        input_output_aliases={5: 1, 6: 2},
    )(block_tables, seq_lens, q, k_new, v_new, k_pool, v_pool)


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, seq_lens: jax.Array, *,
                    scale: Optional[float] = None,
                    softcap: Optional[float] = None,
                    window: Optional[int] = None,
                    v_dim: Optional[int] = None,
                    interpret: bool = False) -> jax.Array:
    """Flash-decoding over paged KV.

    q           : (B, KVH, G, HD) one token's queries, grouped per kv head
    k_pool      : (NB, BT, KVH, HD)
    v_pool      : (NB, BT, KVH, VD)  (pass k_pool + v_dim for MLA latent)
    block_tables: (B, MB) int32 (NULL entries allowed past seq end)
    seq_lens    : (B,)   int32
    returns     : (B, KVH, G, VD)
    """
    B, KVH, G, HD = q.shape
    NB, BT, KVH_k, HD_k = k_pool.shape
    assert KVH_k == KVH and HD_k == HD, (q.shape, k_pool.shape)
    MB = block_tables.shape[1]
    VD = v_dim if v_dim is not None else v_pool.shape[-1]
    if scale is None:
        scale = HD ** -0.5

    kernel = functools.partial(
        _paged_attn_kernel, block_tokens=BT, scale=float(scale),
        softcap=softcap, window=window, num_blocks_grid=MB)

    def k_map(b, h, j, tbl, lens):
        return (jnp.maximum(tbl[b, j], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, HD), lambda b, h, j, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, BT, 1, HD), k_map),
            pl.BlockSpec((1, BT, 1, VD), k_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, VD),
                               lambda b, h, j, tbl, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, VD), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, VD), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, q, k_pool, v_pool)
