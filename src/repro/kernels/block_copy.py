"""Pallas TPU kernel: batched physical block copy (swap-in / compaction).

Executes a (src, dst) copy plan against the arena: the device-side half
of the paper's 'Relocation / Migration' and 'Swapping' rows.  The plan
is a scalar-prefetch operand, so the DMA schedule is driven from SMEM —
the same discipline as the other kernels; compaction plans come from
``core.block_table.compaction_plan``.

Copies must be applied to a SNAPSHOT (the plan generator guarantees
src/dst disjointness for compaction: movers come from beyond the dense
prefix, holes lie inside it — asserted in core.block_table tests).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None


def _copy_kernel(src_ref, dst_ref, pool_ref, out_ref):
    out_ref[...] = pool_ref[...]


def _gather_kernel(idx_ref, pool_ref, out_ref):
    out_ref[...] = pool_ref[...]


def _scatter_kernel(idx_ref, payload_ref, pool_ref, out_ref):
    out_ref[...] = payload_ref[...]


def block_copy(pool: jax.Array, src: jax.Array, dst: jax.Array,
               *, interpret: bool = False) -> jax.Array:
    """pool: (NB, *block); src/dst: (n,) int32 -> pool with plan applied.

    Grid step i DMAs block ``src[i]`` into position ``dst[i]``; untouched
    blocks are pre-seeded by aliasing the input (donate) or, in this
    functional form, by a first pass-through write.
    """
    n = src.shape[0]
    blk = pool.shape[1:]
    ones = (1,) + blk
    zeros = tuple(0 for _ in blk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[pl.BlockSpec(ones, lambda i, s, d: (s[i],) + zeros)],
        out_specs=pl.BlockSpec(ones, lambda i, s, d: (d[i],) + zeros),
    )
    moved = pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        interpret=interpret,
        input_output_aliases={2: 0},
    )(src, dst, pool)
    return moved


def gather_blocks(pool: jax.Array, idx: jax.Array,
                  *, interpret: bool = False) -> jax.Array:
    """pool: (L, NB, *block); idx: (n,) int32 -> (L, n, *block).

    Grid step (l, i) DMAs layer l of block ``idx[i]`` into out[l, i]:
    the device half of swap-out.  The result is COMPACT -- one
    device->host copy of it moves ``n * swap-block`` bytes, so transfer
    cost scales with blocks held, never pool size (paper Table 1 row
    'Swapping' done in software).
    """
    L, n = pool.shape[0], idx.shape[0]
    blk = pool.shape[2:]
    ones = (1, 1) + blk
    zeros = tuple(0 for _ in blk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(L, n),
        in_specs=[pl.BlockSpec(ones, lambda l, i, s: (l, s[i]) + zeros)],
        out_specs=pl.BlockSpec(ones, lambda l, i, s: (l, i) + zeros),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, n) + blk, pool.dtype),
        interpret=interpret,
    )(idx, pool)


def scatter_blocks(pool: jax.Array, idx: jax.Array, payload: jax.Array,
                   *, interpret: bool = False) -> jax.Array:
    """pool: (L, NB, *block); idx: (n,); payload: (L, n, *block).

    Grid step (l, i) DMAs payload[l, i] into pool position ``idx[i]`` --
    the device half of swap-in, and the inverse of ``gather_blocks``.
    Together they are the transfer plane's d2h/h2d executors: one plan
    entry moves a whole block across the L axis, and a batched plan (the
    multi-plan coalesced form) is a single launch over the concatenated
    id vector.  ``idx`` entries must be distinct (fresh allocations are).
    """
    L, n = pool.shape[0], idx.shape[0]
    blk = pool.shape[2:]
    ones = (1, 1) + blk
    zeros = tuple(0 for _ in blk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(L, n),
        in_specs=[pl.BlockSpec(ones, lambda l, i, s: (l, i) + zeros),
                  pl.BlockSpec(ones, lambda l, i, s: (l, s[i]) + zeros)],
        out_specs=pl.BlockSpec(ones, lambda l, i, s: (l, s[i]) + zeros),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        interpret=interpret,
        input_output_aliases={2: 0},
    )(idx, payload, pool)


def copy_pool_blocks(pool: jax.Array, src: jax.Array, dst: jax.Array,
                     *, interpret: bool = False) -> jax.Array:
    """pool: (L, NB, *block); copy block src[i] -> dst[i] on ALL layers.

    The layer-stacked twin of ``block_copy``: one (src, dst) plan entry
    moves a whole KV block across the L axis.  Used to fulfil COW when a
    sequence first writes into a shared block (``fork_for_write``).
    src/dst must be disjoint as sets (the allocator guarantees it: dst
    ids come fresh off the free list).
    """
    L, n = pool.shape[0], src.shape[0]
    blk = pool.shape[2:]
    ones = (1, 1) + blk
    zeros = tuple(0 for _ in blk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(L, n),
        in_specs=[pl.BlockSpec(ones, lambda l, i, s, d: (l, s[i]) + zeros)],
        out_specs=pl.BlockSpec(ones, lambda l, i, s, d: (l, d[i]) + zeros),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        interpret=interpret,
        input_output_aliases={2: 0},
    )(src, dst, pool)
