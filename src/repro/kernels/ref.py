"""Pure-jnp oracles for every Pallas kernel (no pallas imports).

These are also the implementations the dry-run compiles (kernels are
TPU-targeted; the CPU container validates them in interpret mode against
these oracles -- see tests/test_kernels.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG = -1e30


def tree_gather_ref(leaves: jax.Array, leaf_table: jax.Array) -> jax.Array:
    return leaves[leaf_table]


def tree_block_sum_ref(leaves: jax.Array, leaf_table: jax.Array) -> jax.Array:
    return jnp.sum(leaves[leaf_table].astype(jnp.float32), axis=1)


def tree_gather_rows_ref(pool: jax.Array, row_ids: jax.Array,
                         leaf_table: jax.Array, rows_per_block: int) -> jax.Array:
    phys = leaf_table[row_ids // rows_per_block]
    return pool[phys, row_ids % rows_per_block]


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, seq_lens: jax.Array, *,
                        scale: Optional[float] = None,
                        softcap: Optional[float] = None,
                        window: Optional[int] = None,
                        v_dim: Optional[int] = None) -> jax.Array:
    """Dense-gather decode attention.  Shapes as in kernels.paged_attention."""
    B, KVH, G, HD = q.shape
    NB, BT, _, _ = k_pool.shape
    MB = block_tables.shape[1]
    VD = v_dim if v_dim is not None else v_pool.shape[-1]
    if scale is None:
        scale = HD ** -0.5

    tbl = jnp.maximum(block_tables, 0)
    k = k_pool[tbl].reshape(B, MB * BT, KVH, HD)      # (B, S, KVH, HD)
    v = v_pool[tbl].reshape(B, MB * BT, KVH, -1)[..., :VD]

    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(MB * BT)[None, :]
    valid = pos < seq_lens[:, None]
    if window is not None:
        valid = jnp.logical_and(valid, pos >= (seq_lens[:, None] - window))
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_attention_append_ref(q: jax.Array, k_new: jax.Array,
                               v_new: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               seq_lens: jax.Array, *,
                               scale: Optional[float] = None,
                               softcap: Optional[float] = None,
                               window: Optional[int] = None):
    """Append-then-attend decode step (fused-kernel oracle).

    Writes the new token's K/V rows into the tail block named by the
    table (``tables[b, seq_lens[b] // BT]`` at offset ``seq_lens[b] %
    BT``), then attends over ``seq_lens + 1`` positions -- the resident
    decode tail's single-pass discipline.  Returns ``(o, k_pool,
    v_pool)``.  Rows whose table is full (``seq_lens == MB * BT``) drop
    the write and attend over the full table; rows sharing a tail block
    (empty slots parked on the sink) scatter in unspecified order, which
    only ever touches sink garbage.

    q     : (B, KVH, G, HD);  k_new: (B, KVH, HD);  v_new: (B, KVH, VD)
    pools / tables / lens as in ``paged_attention_ref``.
    """
    B = q.shape[0]
    NB, BT = k_pool.shape[:2]
    MB = block_tables.shape[1]
    jt = jnp.minimum(seq_lens // BT, MB - 1)
    phys = jnp.maximum(block_tables[jnp.arange(B), jt], 0)
    off = seq_lens - jt * BT                 # >= BT only when table full
    k_pool = k_pool.at[phys, off].set(k_new.astype(k_pool.dtype),
                                      mode="drop")
    v_pool = v_pool.at[phys, off].set(v_new.astype(v_pool.dtype),
                                      mode="drop")
    o = paged_attention_ref(q, k_pool, v_pool, block_tables, seq_lens + 1,
                            scale=scale, softcap=softcap, window=window)
    return o, k_pool, v_pool


def paged_prefill_attention_ref(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, block_tables: jax.Array,
                                kv_lens: jax.Array, q_starts: jax.Array, *,
                                scale: Optional[float] = None,
                                softcap: Optional[float] = None,
                                window: Optional[int] = None,
                                v_dim: Optional[int] = None) -> jax.Array:
    """Dense-gather suffix prefill attention.

    Query i of row b sits at absolute position q_starts[b] + i and
    attends causally to kv positions <= that, bounded by kv_lens[b]
    (window, when set, uses the flash convention kv > q - window).
    Shapes as in kernels.paged_prefill.
    """
    B, SQ, KVH, G, HD = q.shape
    NB, BT, _, _ = k_pool.shape
    MB = block_tables.shape[1]
    VD = v_dim if v_dim is not None else v_pool.shape[-1]
    if scale is None:
        scale = HD ** -0.5

    tbl = jnp.maximum(block_tables, 0)
    k = k_pool[tbl].reshape(B, MB * BT, KVH, HD)      # (B, S, KVH, HD)
    v = v_pool[tbl].reshape(B, MB * BT, KVH, -1)[..., :VD]

    s = jnp.einsum("bqhgd,bshd->bhgqs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kv_pos = jnp.arange(MB * BT)[None, None, :]        # (1, 1, S)
    q_abs = (q_starts[:, None] + jnp.arange(SQ)[None, :])[:, :, None]
    valid = jnp.logical_and(kv_pos <= q_abs,
                            kv_pos < kv_lens[:, None, None])
    if window is not None:
        valid = jnp.logical_and(valid, kv_pos > q_abs - window)
    vmask = valid[:, None, None, :, :]
    s = jnp.where(vmask, s, _NEG)
    # masked normalization (not jax.nn.softmax): a fully-masked query row
    # -- possible for padding rows past the suffix under a tight window --
    # yields 0, matching the kernel's l == 0 convention.
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * vmask
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqs,bshd->bqhgd", p / jnp.maximum(l, 1e-30),
                   v.astype(jnp.float32))
    return o.astype(q.dtype)
