"""Pallas TPU kernel: suffix prefill attention over a paged KV cache.

The COW-sharing companion of ``paged_attention``: when a forked request
aliases a cached prefix, only the un-cached *suffix* tokens are
prefilled, and their queries attend to the whole sequence -- the shared
prefix blocks included -- THROUGH the block table.  Prefix sharing then
saves FLOPs, not just memory (the paper's sharing row extended from
bytes to compute).

Queries are chunked over the suffix (``q_chunk`` tokens per grid step);
KV is gathered block-by-block through the same scalar-prefetch tables as
the decode sweep, with causal masking offset by the cached length: the
query at suffix index ``i`` of row ``b`` sits at absolute position
``q_starts[b] + i`` and sees kv positions ``<= q_starts[b] + i``.  The
suffix's own KV must already be IN the pool (the caller scatters it
before attending -- aliased blocks already hold the parent's identical
values), so one sweep covers prefix and suffix uniformly.

Grid: ``(batch, kv_heads, num_q_chunks, max_blocks_per_seq)``; the last
axis is the sequential flash sweep with running (m, l, acc) scratch per
query chunk.  Blocks past ``ceil(kv_len / bt)`` and query rows past the
suffix are fully masked (l == 0 -> output 0), matching the reference.

Supports GQA/MQA, logit softcap and sliding window exactly like the
decode kernel (window per QUERY row: ``kv_pos > q_abs - window``, the
``flash_attention`` convention).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

_NEG = -1e30


def _paged_prefill_kernel(tables_ref, lens_ref, starts_ref, q_ref, k_ref,
                          v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                          block_tokens: int, q_chunk: int, groups: int,
                          scale: float, softcap: Optional[float],
                          window: Optional[int], num_blocks_grid: int):
    b = pl.program_id(0)
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    QG = q_chunk * groups
    q = q_ref[0, :, 0].astype(jnp.float32).reshape(QG, -1)  # (QC*G, HD)
    k = k_ref[0, :, 0, :].astype(jnp.float32)               # (BT, HD)
    v = v_ref[0, :, 0, :].astype(jnp.float32)               # (BT, VD)

    s = jax.lax.dot_general(q * scale, k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (QG, BT)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    kv_pos = (j * block_tokens
              + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    q_abs = (starts_ref[b] + i * q_chunk
             + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // groups)
    valid = jnp.logical_and(kv_pos <= q_abs, kv_pos < lens_ref[b])
    if window is not None:
        valid = jnp.logical_and(valid, kv_pos > q_abs - window)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_scr[...]                          # (QG, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new) * valid               # masked rows: l stays 0
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(j == num_blocks_grid - 1)
    def _fin():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0] = out.reshape(q_chunk, groups, -1).astype(o_ref.dtype)


def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_tables: jax.Array,
                            kv_lens: jax.Array, q_starts: jax.Array, *,
                            scale: Optional[float] = None,
                            softcap: Optional[float] = None,
                            window: Optional[int] = None,
                            v_dim: Optional[int] = None,
                            q_chunk: Optional[int] = None,
                            interpret: bool = False) -> jax.Array:
    """Suffix-chunk flash attention over paged KV.

    q           : (B, SQ, KVH, G, HD) suffix queries; row b's query i
                  sits at absolute position q_starts[b] + i
    k_pool      : (NB, BT, KVH, HD) -- suffix KV already written
    v_pool      : (NB, BT, KVH, VD)
    block_tables: (B, MB) int32 (NULL entries allowed past seq end)
    kv_lens     : (B,) int32 total tokens visible (cached + suffix)
    q_starts    : (B,) int32 cached length (first suffix position)
    returns     : (B, SQ, KVH, G, VD)
    """
    B, SQ, KVH, G, HD = q.shape
    NB, BT, KVH_k, HD_k = k_pool.shape
    assert KVH_k == KVH and HD_k == HD, (q.shape, k_pool.shape)
    MB = block_tables.shape[1]
    VD = v_dim if v_dim is not None else v_pool.shape[-1]
    if scale is None:
        scale = HD ** -0.5
    QC = SQ if q_chunk is None else min(q_chunk, SQ)
    assert SQ % QC == 0, (SQ, QC)

    kernel = functools.partial(
        _paged_prefill_kernel, block_tokens=BT, q_chunk=QC, groups=G,
        scale=float(scale), softcap=softcap, window=window,
        num_blocks_grid=MB)

    def kv_map(b, h, i, j, tbl, lens, starts):
        return (jnp.maximum(tbl[b, j], 0), 0, h, 0)

    def q_map(b, h, i, j, tbl, lens, starts):
        return (b, i, h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KVH, SQ // QC, MB),
        in_specs=[
            pl.BlockSpec((1, QC, 1, G, HD), q_map),
            pl.BlockSpec((1, BT, 1, HD), kv_map),
            pl.BlockSpec((1, BT, 1, VD), kv_map),
        ],
        out_specs=pl.BlockSpec((1, QC, 1, G, VD), q_map),
        scratch_shapes=[
            pltpu.VMEM((QC * G, 1), jnp.float32),
            pltpu.VMEM((QC * G, 1), jnp.float32),
            pltpu.VMEM((QC * G, VD), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, SQ, KVH, G, VD), q.dtype),
        interpret=interpret,
    )(block_tables, kv_lens, q_starts, q, k_pool, v_pool)
