"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()`` (whole-program totals:
with SPMD partitioning XLA reports the per-partition program, so we
multiply by the partition count to get global, then divide by chips --
i.e. the per-chip figure IS cost_analysis of the partitioned module).
collective_bytes is not in cost_analysis: we parse the optimized HLO and
sum operand bytes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (guidance constants from the grading protocol).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> byte count; tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes of collective ops in optimized HLO, by kind.

    Output-shape accounting approximates wire bytes within 2x for every
    collective kind (all-gather output = full gathered size; all-reduce
    in-place size; all-to-all permuted size) and is uniform across
    schedule variants, which is what the §Perf comparisons need.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match "<shape> <name>-start(...)" or "= <shape> all-reduce(...)"
        m = re.match(r".*= ([^=]*?)\s*([a-z\-]+)(?:-start)?\(", ls)
        if not m:
            continue
        op = m.group(2)
        if op in _COLLECTIVES:
            out[op] += _shape_bytes(m.group(1))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    peak_memory_per_chip: float
    model_flops: float           # 6*N*D (or 6*N_active*D)
    coll_by_kind: Optional[Dict[str, float]] = None
    bytes_by_op: Optional[Dict[str, float]] = None
    xla_raw: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time (the score)."""
        t_useful = (self.model_flops / self.chips) / PEAK_FLOPS
        return t_useful / max(self.t_bound, 1e-30)

    @property
    def flops_utilization(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs -- remat/redundancy waste detector."""
        return self.model_flops / max(self.flops_per_chip * self.chips, 1e-30)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_gflops_per_chip": self.flops_per_chip / 1e9,
            "hbm_gb_per_chip": self.bytes_per_chip / 1e9,
            "coll_gb_per_chip": self.coll_bytes_per_chip / 1e9,
            "peak_mem_gb_per_chip": self.peak_memory_per_chip / 1e9,
            "model_gflops_global": self.model_flops / 1e9,
            "useful_flops_ratio": self.flops_utilization,
            "roofline_fraction": self.roofline_fraction,
            "coll_by_kind_gb": {k: v / 1e9 for k, v in
                                (self.coll_by_kind or {}).items()},
            "mem_by_op_gb": {k: v / 1e9 for k, v in
                             sorted((self.bytes_by_op or {}).items(),
                                    key=lambda kv: -kv[1])},
            "dominant_mem_op": self.dominant_mem_op,
            "xla_raw": self.xla_raw or {},
        }

    @property
    def dominant_mem_op(self) -> str:
        from repro.cost.accounting import dominant_category
        return dominant_category(self.bytes_by_op)


def analyze(compiled, *, arch: str, shape: str, mesh_desc: str, chips: int,
            model_flops: float) -> Roofline:
    """Derive roofline terms from the compiled SPMD module.

    Primary source: the instruction-level accounting subsystem
    (``repro.cost``) -- ``compiled.cost_analysis()`` counts while-loop
    bodies once, which under-reports scanned models by ~num_layers
    (validated in tests/test_hlo_cost.py), and its byte counts bill
    in-place updates and gathers at full-operand size.  The raw XLA
    numbers are kept in the row for reference.
    """
    from repro import cost as COST
    hlo = compiled.as_text()
    cost = COST.analyze_text(hlo)
    try:
        mem = compiled.memory_analysis()
        peak = float(mem.temp_size_in_bytes + mem.argument_size_in_bytes +
                     mem.output_size_in_bytes - mem.alias_size_in_bytes)
    except Exception:
        peak = 0.0
    rl = Roofline(arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
                  flops_per_chip=cost.flops, bytes_per_chip=cost.bytes,
                  coll_bytes_per_chip=cost.coll_total,
                  peak_memory_per_chip=peak, model_flops=model_flops)
    rl.coll_by_kind = {k: v for k, v in cost.coll.items() if v}
    rl.bytes_by_op = {k: v for k, v in cost.by_op.items() if v}
    xla_cost = COST.xla_cost_analysis(compiled)
    rl.xla_raw = {"flops": float(xla_cost.get("flops", 0.0)),
                  "bytes": float(xla_cost.get("bytes accessed", 0.0))} \
        if xla_cost else {}
    return rl


def model_flops_for(cfg, shape) -> float:
    """6*N*D for training; 2*N*D forward-only; decode: 2*N_active per token
    (+ attention KV term folded into HLO accounting, not the model number)."""
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if shape.name.startswith("prefill"):
            return 2.0 * n_active * B * S
        return 6.0 * n_active * B * S
    # decode: one token per sequence
    return 2.0 * n_active * B
