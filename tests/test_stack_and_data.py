"""BlockStack (split-stack analogue) + block-table utilities."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.blockpool import BlockAllocator
from repro.core.stack import BlockStack, DeviceBlockStack
from repro.core import block_table as BT


@given(st.lists(st.sampled_from(["push", "pop"]), max_size=300),
       st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_blockstack_matches_list(ops, bs):
    s = BlockStack(block_size=bs)
    ref = []
    n = 0
    for op in ops:
        if op == "push":
            s.push(n)
            ref.append(n)
            n += 1
        elif ref:
            assert s.pop() == ref.pop()
        assert len(s) == len(ref)
        if ref:
            assert s.peek() == ref[-1]
    # block count tracks occupancy (never more than 1 spare block)
    assert s.num_blocks <= len(ref) // bs + 2


def test_blockstack_with_shared_arena():
    from repro.mem import Arena
    arena = Arena()
    arena.register_class("stack", num_blocks=8, block_nbytes=2 * 8)
    s1 = BlockStack(block_size=2, arena=arena, pool_class="stack", owner="s1")
    s2 = BlockStack(block_size=2, arena=arena, pool_class="stack", owner="s2")
    for i in range(6):
        s1.push(i)
        s2.push(i)
    assert arena.num_used("stack") == 6
    assert arena.stats()["stack"].blocks_by_owner == {"s1": 3, "s2": 3}
    for _ in range(6):
        s1.pop()
    # fully drained stacks unlink everything (shared-arena leak rule)
    assert arena.num_used("stack") == 3
    for _ in range(6):
        s2.pop()
    arena.assert_quiescent()


def test_device_block_stack():
    import jax.numpy as jnp
    s = DeviceBlockStack.full_of(jnp.arange(5))
    v, s = s.pop()
    assert int(v) == 4
    s = s.push(jnp.asarray(9))
    v, s = s.pop()
    assert int(v) == 9


def test_compaction_plan_minimal():
    live = [0, 5, 2, 9, 1]
    plan = BT.compaction_plan(live)
    # only blocks outside the dense prefix move
    assert sorted(src for src, _ in plan) == [5, 9]
    assert sorted(dst for _, dst in plan) == [3, 4]
    tables = {0: [0, 5], 1: [2, 9, 1]}
    BT.apply_compaction(tables, plan)
    used = sorted(b for t in tables.values() for b in t)
    assert used == [0, 1, 2, 3, 4]


def test_deep_table_resolution():
    alloc = BlockAllocator(32)
    data_blocks = alloc.alloc_many(20)
    root, tb_ids = BT.deep_table(data_blocks, ids_per_block=8,
                                 allocator=alloc)
    storage = np.full((32, 8), -1, np.int32)
    for i, tb in enumerate(tb_ids):
        chunk = data_blocks[i * 8:(i + 1) * 8]
        storage[tb, : len(chunk)] = chunk
    logical = np.arange(20)
    resolved = BT.resolve_deep(root, storage, logical, 8)
    np.testing.assert_array_equal(resolved, np.asarray(data_blocks))
