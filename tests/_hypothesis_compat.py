"""Offline-safe ``hypothesis`` stand-in for the property-based tests.

The container has no network access, so ``hypothesis`` may simply not be
installable.  When the real package is present we re-export it verbatim;
otherwise this module provides the tiny subset the test-suite uses
(``given``, ``settings``, ``strategies.integers/lists/sampled_from/...``)
backed by *seeded* numpy sampling:

  * deterministic: the RNG is seeded from the test-function name, so a
    failure reproduces exactly under plain ``pytest`` with no database;
  * boundary-biased: example 0 is always the minimal example (smallest
    integers, empty lists), which is where off-by-one bugs live;
  * ``settings(max_examples=N)`` is honored in either decorator order.

This is NOT a shrinker and does not try to be one -- a failing example
prints its arguments so the repro can be inlined into a regular test.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # type: ignore  # noqa: F401
    from hypothesis import strategies  # type: ignore  # noqa: F401
    HAVE_REAL_HYPOTHESIS = True
except ImportError:
    HAVE_REAL_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        """A value source: ``_draw(rng)`` samples, ``_minimal()`` is the
        smallest member (used as example 0)."""

        def __init__(self, draw, minimal):
            self._draw = draw
            self._minimal = minimal

        def example(self, rng, index):
            if index == 0:
                return self._minimal()
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, max_value + 1)),
                lambda: int(min_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.randint(len(seq)))],
                             lambda: seq[0])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.randint(2)), lambda: False)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                lambda: float(min_value))

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value, lambda: value)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.randint(min_size, max_size + 1))
                return [elements._draw(rng) for _ in range(n)]

            def minimal():
                return [elements._minimal() for _ in range(min_size)]

            return _Strategy(draw, minimal)

        @staticmethod
        def tuples(*strats):
            return _Strategy(
                lambda rng: tuple(s._draw(rng) for s in strats),
                lambda: tuple(s._minimal() for s in strats))

    strategies = _Strategies()

    def settings(max_examples=None, **_ignored):
        """Record ``max_examples``; works above or below ``@given``."""
        def deco(fn):
            fn._hc_max_examples = max_examples
            return fn
        return deco

    def given(*strats, **kwstrats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = (getattr(wrapper, "_hc_max_examples", None)
                     or getattr(fn, "_hc_max_examples", None)
                     or _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode()) & 0x7FFFFFFF
                rng = np.random.RandomState(seed)
                for i in range(n):
                    vals = [s.example(rng, i) for s in strats]
                    kwvals = {k: s.example(rng, i)
                              for k, s in kwstrats.items()}
                    try:
                        fn(*args, *vals, **kwargs, **kwvals)
                    except Exception:
                        print(f"[hypothesis-compat] falsifying example "
                              f"#{i} for {fn.__qualname__}: "
                              f"args={vals!r} kwargs={kwvals!r}")
                        raise
            # hide the strategy-fed params from pytest's fixture
            # resolution (it would otherwise look for fixtures "n" etc.)
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
