"""BlockAllocator / BlockPool invariants (property-based)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.blockpool import (BlockAllocator, BlockPool, NULL_BLOCK,
                                  OutOfBlocksError)


@given(st.integers(1, 64))
def test_alloc_free_roundtrip(n):
    a = BlockAllocator(n)
    blocks = a.alloc_many(n)
    assert sorted(blocks) == list(range(n))
    assert a.num_free == 0
    with pytest.raises(OutOfBlocksError):
        a.alloc()
    a.free_many(blocks)
    assert a.num_free == n


@given(st.lists(st.sampled_from(["alloc", "free", "share"]), max_size=200))
@settings(max_examples=50, deadline=None)
def test_allocator_state_machine(ops):
    """No double allocation, refcounts never negative, free-list sound."""
    a = BlockAllocator(16)
    live = []
    for op in ops:
        if op == "alloc" and a.num_free:
            b = a.alloc()
            assert b not in [x for x, _ in live]
            live.append((b, 1))
        elif op == "free" and live:
            b, rc = live.pop()
            a.free(b)
            if rc > 1:
                live.append((b, rc - 1))
        elif op == "share" and live:
            b, rc = live.pop()
            a.share(b)
            live.append((b, rc + 1))
        # invariant: used + free == total
        assert a.num_used + a.num_free == 16
        assert a.num_used == len(set(b for b, _ in live))


def test_double_free_raises():
    a = BlockAllocator(4)
    b = a.alloc()
    a.free(b)
    with pytest.raises(ValueError):
        a.free(b)


def test_cow_fork():
    a = BlockAllocator(4)
    b = a.alloc()
    a.share(b)
    nb, copy = a.fork_for_write(b)
    assert copy and nb != b
    assert a.refcount(b) == 1 and a.refcount(nb) == 1
    nb2, copy2 = a.fork_for_write(nb)
    assert not copy2 and nb2 == nb


def test_blockpool_rw(rng):
    pool = BlockPool.create(8, (4, 4), jnp.float32)
    payload = jnp.asarray(rng.randn(4, 4).astype(np.float32))
    pool = pool.write(3, payload)
    np.testing.assert_array_equal(np.asarray(pool.read(jnp.asarray(3))),
                                  np.asarray(payload))
    pool = pool.copy_block(3, 5)
    np.testing.assert_array_equal(np.asarray(pool.data[5]),
                                  np.asarray(payload))
