"""Cross-process Arena: live migration + prefill/decode disaggregation.

Acceptance centerpieces (ISSUE PR 9): (1) a serving engine migrated
MID-DECODE -- pre-copy rounds overlapping decode steps, dirty-set
convergence to the running working set, a bounded stop-and-copy pause --
decodes token-identical to an unmigrated control, across forced
preemption and COW-forked prefixes; (2) a prefill worker handing
finished sequences to a decode engine as ``BlockBundle``s is
token-identical to the monolithic engine.

Satellites pinned here: the allocator's write-generation dirty bit,
snapshot/restore carrying device payloads with COW aliasing + tenant
tags intact, the thread-fed async ``ThreadedRequestSource``, and the
rwkv6 registry row graduating to served on length-masked prefill.
"""

import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.mem import Arena
from repro.mem.migrate import MigrationSession
from repro.models.api import build_model
from repro.serve.disagg import (DisaggregatedEngine, PrefillWorker,
                                migrate_live)
from repro.serve.engine import Engine, Request
from repro.serve.traffic import ThreadedRequestSource
from conftest import assert_engine_quiescent


@pytest.fixture(scope="module")
def gemma():
    cfg = get_config("gemma_2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("num_blocks", 24)
    return Engine(model, params, eos_id=-1, prefill_budget=None, **kw)


# ---------------------------------------------------------------------------
# the software dirty bit
# ---------------------------------------------------------------------------
def test_write_generation_counter():
    """Fresh allocations count as writes; ``note_write`` is monotonic
    per block and leaves neighbours untouched."""
    a = Arena()
    a.register_class("kv", num_blocks=8, block_shape=(4,),
                     dtype=jnp.float32)
    alloc = a.allocator("kv")
    l1, l2 = a.lease_blocks("kv", "o", 2)
    g1, g2 = alloc.write_gen(l1.block), alloc.write_gen(l2.block)
    assert g1 > 0 and g2 > 0          # alloc itself dirties the block
    alloc.note_write([l1.block])
    assert alloc.write_gen(l1.block) == g1 + 1
    assert alloc.write_gen(l2.block) == g2      # neighbour untouched
    alloc.note_write([l1.block, l1.block])      # idempotent per call site
    assert alloc.write_gen(l1.block) > g1 + 1
    got = alloc.write_gens([l1.block, l2.block])
    assert list(got) == [alloc.write_gen(l1.block), g2]


def test_dirty_set_converges_to_working_set(gemma, tmp_path):
    """Pre-copy rounds shrink the dirty set down to the decode working
    set (one tail block per running sequence); the stop-and-copy tail is
    bounded by that residue, NOT the pool size."""
    _, model, params = gemma
    eng = _engine(model, params)
    rng = np.random.RandomState(3)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=rng.randint(2, 500, size=12),
                           max_new=16))
    for _ in range(3):
        eng.step()
    sess = MigrationSession(eng.arena, max_rounds=8)
    while not sess.converged():
        sess.begin_round()
        eng.step()
        sess.collect_round()
    stop = sess.finalize(str(tmp_path / "mig.npz"))
    rep = sess.migration_report()
    assert rep["finalized"] and rep["rounds"] >= 2
    # round 1 copies the whole mapped set; later rounds only re-copy
    # what decode dirtied since
    assert rep["blocks_per_round"][-1] < rep["blocks_per_round"][0]
    # the residue (and hence the pause) is bounded by the running set:
    # each running sequence dirties exactly its append-target tail block
    assert 0 < stop["blocks"] <= len(eng.running)
    assert stop["bytes"] == stop["blocks"] * eng.arena.block_nbytes(
        eng.strategy.mgr.pool_class)
    assert rep["pause_steps"] == 1
    eng.release_arena()


# ---------------------------------------------------------------------------
# snapshot/restore with device payloads: aliasing + tenants survive
# ---------------------------------------------------------------------------
def test_snapshot_restore_preserves_cow_aliases_and_tenants(gemma, tmp_path):
    _, model, params = gemma
    eng = _engine(model, params, slots=3)
    rng = np.random.RandomState(11)
    base = rng.randint(2, 500, size=16)           # two full blocks
    eng.submit(Request(rid=0, prompt=base.copy(), max_new=12, tenant="a"))
    eng.step()
    eng.submit(Request(                            # forks rid=0's prefix
        rid=1, prompt=np.concatenate([base, rng.randint(2, 500, size=5)]),
        max_new=12, tenant="b"))
    eng.submit(Request(rid=2, prompt=rng.randint(2, 500, size=9),
                       max_new=12, tenant="a"))
    for _ in range(3):
        eng.step()
    eng.preempt_latest()       # host-tier resident; snapshot before the
    eng.transfers.drain()      # next step would LIFO-resume it
    assert eng.prefix_hits >= 1
    cls = eng.strategy.mgr.pool_class
    src_blocks = {rid: eng.arena.find_mapping(cls, rid).block_ids()
                  for rid in (0, 1)}
    shared = set(src_blocks[0]) & set(src_blocks[1])
    assert shared                                  # COW aliases are live
    preempted = [rid for rid in (0, 1, 2)
                 if eng.arena.find_mapping(cls, rid).placement == "host"]
    assert preempted
    path = str(tmp_path / "snap.npz")
    eng.arena.snapshot(path, include_device=True)

    dst = _engine(model, params, slots=3)          # fresh engine-built arena
    restored = dst.arena.restore(path)
    dst.arena.check_consistency()
    for rid in (0, 1):
        if rid in preempted:
            continue
        m0, m1 = restored[(cls, 0)], restored[(cls, 1)]
        # aliasing pattern survives exactly: positions that shared a
        # physical block still do, with the refcount to match
        for i, (a, b) in enumerate(zip(src_blocks[0], src_blocks[1])):
            if a == b:
                assert m0.block_ids()[i] == m1.block_ids()[i]
                assert dst.arena.refcount(cls, m0.block_ids()[i]) == 2
    # tenant tags ride the mapping table through the roundtrip
    by_tenant = dst.arena.blocks_by_tenant(cls)
    assert by_tenant == eng.arena.blocks_by_tenant(cls)
    for rid in preempted:
        m = restored[(cls, rid)]
        assert m.placement == "host"
        assert dst.arena.host_contains(cls, rid)
    eng.release_arena()
    dst.release_arena()


# ---------------------------------------------------------------------------
# THE acceptance test: live migration mid-decode, token-identical
# ---------------------------------------------------------------------------
def _interleaved_requests(seed):
    """Seeded mix: plain prompts + a COW-forked pair riding a
    block-aligned shared base."""
    rng = np.random.RandomState(seed)
    base = rng.randint(2, 500, size=16)
    reqs = []
    for i in range(5):
        if i in (1, 3):
            extra = rng.randint(2, 500, size=int(rng.randint(1, 6)))
            prompt = np.concatenate([base, extra])
        else:
            prompt = rng.randint(2, 500, size=int(rng.randint(6, 20)))
        reqs.append(Request(rid=i, prompt=prompt.copy(),
                            max_new=int(rng.randint(4, 9)),
                            tenant=f"t{i % 2}"))
    return reqs


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_live_migration_token_identity(gemma, tmp_path, seed):
    """Grow/fork/preempt interleaved with migration: the destination
    engine resumes every in-flight request (running, queued AND
    preempted) and decodes byte-identically to an unmigrated control."""
    _, model, params = gemma
    rng = np.random.RandomState(100 + seed)
    pre_steps = int(rng.randint(2, 5))
    preempt_at = int(rng.randint(1, pre_steps + 1))

    def drive(eng):
        for req in _interleaved_requests(seed):
            eng.submit(req)
        for s in range(pre_steps):
            if s == preempt_at and eng.running:
                eng.preempt_latest()
            eng.step()

    control = _engine(model, params)
    drive(control)
    control.run(max_steps=400)
    want = {r.rid: list(r.generated) for r in control.done}
    assert len(want) == 5
    assert_engine_quiescent(control)

    src = _engine(model, params)
    drive(src)

    def build_dst():
        return _engine(model, params)

    dst, sess = migrate_live(src, build_dst, str(tmp_path / "live.npz"))
    rep = sess.migration_report()
    assert rep["finalized"]
    # bounded pause: the stop-and-copy tail re-copies only what the
    # final overlapped step dirtied -- strictly less than the full
    # mapped set the first pre-copy round moved (a stop-everything
    # copy would move all of round 0 again, inside the pause)
    assert 0 < rep["stop_copy_blocks"] < rep["blocks_per_round"][0]
    assert rep["pause_steps"] == 1
    dst.run(max_steps=400)
    got = {r.rid: list(r.generated) for r in dst.done}
    assert got == want
    dst.check_consistency()
    dst.arena.check_consistency()
    assert_engine_quiescent(dst)
    src.release_arena()
    dst.release_arena()


# ---------------------------------------------------------------------------
# prefill/decode disaggregation: handoff == monolithic
# ---------------------------------------------------------------------------
def test_disaggregated_prefill_token_identity(gemma):
    _, model, params = gemma
    rng = np.random.RandomState(5)
    prompts = [rng.randint(2, 500, size=int(rng.randint(5, 18)))
               for _ in range(4)]

    mono = _engine(model, params)
    for i, p in enumerate(prompts):
        mono.submit(Request(rid=i, prompt=p.copy(), max_new=6))
    mono.run(max_steps=300)
    want = {r.rid: list(r.generated) for r in mono.done}
    assert_engine_quiescent(mono)

    pre = PrefillWorker(model, params, max_seq=64, num_blocks=24,
                        eos_id=-1, prefill_budget=None)
    disagg = DisaggregatedEngine(pre, _engine(model, params))
    for i, p in enumerate(prompts):
        disagg.submit(Request(rid=i, prompt=p.copy(), max_new=6))
    disagg.run(max_steps=300)
    got = {r.rid: list(r.generated) for r in disagg.done}
    assert got == want
    assert disagg.handoffs == 4 and disagg.handoff_bytes > 0
    assert pre.prefills == 4
    # the prefill worker's pool drains fully on every export
    assert pre.engine.arena.num_used(pre.engine.strategy.mgr.pool_class) == 1
    disagg.engine.check_consistency()
    for r in disagg.done:
        assert r.t_first >= 0          # TTFT stamped at the prefill argmax
    assert_engine_quiescent(disagg.engine)
    pre.engine.release_arena()
    disagg.engine.release_arena()


# ---------------------------------------------------------------------------
# thread-fed arrivals
# ---------------------------------------------------------------------------
def test_threaded_request_source_feeds_serve(gemma):
    _, model, params = gemma
    eng = _engine(model, params)
    rng = np.random.RandomState(9)
    prompts = [rng.randint(2, 500, size=int(rng.randint(5, 14)))
               for _ in range(4)]
    source = ThreadedRequestSource()

    def producer():
        for i, p in enumerate(prompts):
            source.submit(Request(rid=i, prompt=p, max_new=4,
                                  arrival_time=float(2 * i)))
        source.close()

    t = threading.Thread(target=producer)
    t.start()
    done = eng.serve(source, max_steps=300)
    t.join()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    # future arrivals were held back to their virtual due times
    assert all(not source.poll(1e9) for _ in range(2))
    assert not source.has_more
    with pytest.raises(RuntimeError):
        source.submit(Request(rid=99, prompt=prompts[0], max_new=1))
    assert_engine_quiescent(eng)
    eng.release_arena()


# ---------------------------------------------------------------------------
# rwkv6 graduates to served
# ---------------------------------------------------------------------------
def test_rwkv6_served_with_length_masked_prefill():
    """The registry row is served now: the padded batched prefill masks
    lengths exactly, so ragged serving matches a per-sequence oracle."""
    cfg = get_config("rwkv6_7b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    prompts = [rng.randint(2, 400, size=n) for n in (5, 11, 8)]

    def pad8(p):
        t = np.zeros(-(-len(p) // 8) * 8, np.int64)
        t[:len(p)] = p
        return t

    def oracle(prompt):
        st = model.init_state(1)
        last, st = model.prefill(
            params, {"tokens": jnp.asarray(pad8(prompt))[None]}, st,
            jnp.asarray([len(prompt)], jnp.int32))
        out = [int(jnp.argmax(last[0]))]
        for _ in range(3):
            logits, st = model.decode_step(params, jnp.asarray([out[-1]]),
                                           st)
            out.append(int(jnp.argmax(logits[0])))
        return out

    want = {i: oracle(p) for i, p in enumerate(prompts)}
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=16,
                 eos_id=-1, prefill_budget=None)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=4))
    eng.run(max_steps=200)
    got = {r.rid: list(r.generated) for r in eng.done}
    assert got == want
    eng.check_consistency()
    eng.release_arena()
