"""The asynchronous transfer plane (repro.mem.transfer).

Four layers of pins:

  * the grep-enforced API rule: NOTHING outside ``mem/transfer.py``
    (and the kernel definitions themselves) calls the block-copy
    kernels or the host tier's payload verbs -- every movement rides
    the Arena's ``TransferQueue``;
  * unit semantics: fences/epochs, eager (synchronous-fallback) mode,
    multi-plan coalescing with dependency breaks, metadata-only arenas,
    allocator holds on unfenced DMA sources;
  * the ORDERING property: any interleaving of enqueued plans, fences
    and (barriered) device writes yields block contents identical to
    the fully synchronous ``drain()`` schedule;
  * the read barrier: an unfenced read of an ``in_flight`` lease raises
    ``UnfencedReadError``.

Plus the checkpoint-on-arena roundtrip (``snapshot``/``restore``).
"""

import dataclasses
import re
from pathlib import Path

import numpy as np
import pytest
import jax.numpy as jnp

from repro.mem import (Arena, BACKGROUND, D2D, D2H, H2D, IN_FLIGHT,
                       URGENT, OutOfBlocksError, UnfencedReadError)
from _hypothesis_compat import given, settings, strategies as st

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# the API rule, grep-enforced
# ---------------------------------------------------------------------------
def test_no_direct_transfer_calls_outside_transfer_plane():
    """Zero direct block-copy-kernel / host-transfer calls outside
    mem/transfer.py: all four movement producers (migrate, swap, COW
    copy, compact) route through the TransferQueue."""
    kernel_call = re.compile(
        r"\b(?:gather_blocks|scatter_blocks|copy_pool_blocks|block_copy)"
        r"\s*\(")
    host_verb = re.compile(r"\bhost_(?:deposit|take|peek|discard)\s*\(")
    kernels_dir = REPO / "src" / "repro" / "kernels"
    mem_dir = REPO / "src" / "repro" / "mem"
    offenders = []
    for root in ("src/repro", "benchmarks", "examples"):
        for path in sorted((REPO / root).rglob("*.py")):
            if kernels_dir in path.parents:
                continue                      # kernel definitions/wrappers
            in_mem = mem_dir in path.parents
            if in_mem and path.name == "transfer.py":
                continue                      # the one permitted executor
            for i, line in enumerate(path.read_text().splitlines(), 1):
                if kernel_call.search(line):
                    offenders.append(
                        f"{path.relative_to(REPO)}:{i}: {line.strip()}")
                # the host tier's own module may manage its payload dict;
                # everything outside repro.mem must go through plans
                if not in_mem and host_verb.search(line):
                    offenders.append(
                        f"{path.relative_to(REPO)}:{i}: {line.strip()}")
    assert not offenders, (
        "direct transfer calls outside the transfer plane (enqueue a "
        "TransferPlan on Arena.transfers instead):\n" + "\n".join(offenders))


# ---------------------------------------------------------------------------
# harness: an arena with a registered executor over real device streams
# ---------------------------------------------------------------------------
CLS = "kv"


def make_executor_arena(n=12, layers=1, blk=2, streams=1):
    a = Arena()
    a.register_class(CLS, num_blocks=n,
                     block_nbytes=layers * blk * 4 * streams)
    cell = {"streams": [jnp.zeros((layers, n, blk), jnp.float32)
                        for _ in range(streams)]}
    a.transfers.register_executor(
        CLS, lambda: list(cell["streams"]),
        lambda s: cell.update(streams=list(s)))
    return a, cell


def write_blocks(a, cell, mapping, value):
    """A device write through the engine's schedule: dispatch first
    (settles everything the write could race: pending d2d copies into
    or out of these blocks), then write."""
    a.transfers.dispatch()
    mapping.assert_settled()
    ids = jnp.asarray(mapping.block_ids(), jnp.int32)
    cell["streams"] = [s.at[:, ids].set(value) for s in cell["streams"]]


def contents(cell, ids):
    return [np.asarray(s)[:, np.asarray(ids, np.int32)]
            for s in cell["streams"]]


# ---------------------------------------------------------------------------
# fences / eager mode / holds
# ---------------------------------------------------------------------------
def test_empty_dispatch_phases_are_skipped():
    """Dispatch-count pin (first bite of the ROADMAP overlap gap: the
    step loop used to run ~49 fixpoint dispatches for 2 actual
    transfers at smoke scale).  A dispatch / fence / drain phase with
    nothing eligible must skip the fixpoint entirely and count
    NOTHING: the counters measure scheduling work, not step-loop
    calls."""
    a, cell = make_executor_arena()
    q = a.transfers
    # an idle step loop's worth of empty phases: all skipped
    for _ in range(25):
        q.dispatch()
        q.dispatch(lanes=(URGENT,))
        q.dispatch(lanes=(BACKGROUND,))
        q.complete_dispatched()
        q.drain()
    assert (q.stats.dispatches, q.stats.fences, q.stats.drains) == (0, 0, 0)

    # two real transfers cost exactly one phase each, no matter how
    # many no-op phases the loop schedules around them
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(2)
    write_blocks(a, cell, m, 5.0)      # its dispatch() is empty: skipped
    m.migrate("host")
    q.dispatch(lanes=(BACKGROUND,))    # wrong lane: still nothing to do
    assert q.stats.dispatches == 0
    q.dispatch()                       # launches the d2h gather
    q.dispatch()                       # nothing newly pending: skipped
    assert q.stats.dispatches == 1
    q.complete_dispatched()            # lands the host copy
    q.complete_dispatched()            # nothing dispatched: skipped
    assert q.stats.fences == 1
    assert a.host_contains(CLS, 0)
    m.migrate("device")                # enqueues the h2d scatter
    q.drain()
    q.drain()                          # plane empty again: skipped
    assert q.stats.drains == 1
    m.free()
    a.assert_quiescent()


def test_nonempty_dispatch_is_single_walk():
    """Extends the empty-phase pin above to NON-empty steps (the PR 7
    de-Pythonized step loop): N independent plans dispatched in one
    phase cost exactly N per-plan visits (``python_launches``) -- one
    walk per engine, not one walk per plan per fixpoint round, and no
    trailing no-progress verification round -- and
    ``dispatches_per_step`` reports dispatch phases per compute mark."""
    a, cell = make_executor_arena(n=16)
    q = a.transfers
    maps = []
    for i in range(4):
        m = a.mapping(CLS, owner=i)
        m.ensure_capacity(2)
        write_blocks(a, cell, m, float(i + 1))
        maps.append(m)
    base = q.stats.python_launches
    for m in maps:
        m.migrate("host")
    q.dispatch()                   # one walk batches 4 independent gathers
    assert q.stats.python_launches - base == 4
    assert q.stats.dispatches == 1
    q.complete_dispatched()
    assert all(a.host_contains(CLS, i) for i in range(4))
    # the derived per-step rate follows the compute-mark clock
    q.note_compute()
    q.note_compute()
    assert q.stats.dispatches_per_step == pytest.approx(
        q.stats.dispatches / 2)
    assert q.stats.to_dict()["python_launches"] == q.stats.python_launches
    for m in maps:
        m.migrate("device")
    q.drain()
    for m in maps:
        m.free()
    a.assert_quiescent()


def test_fence_epochs_and_drain():
    a, cell = make_executor_arena()
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(2)
    write_blocks(a, cell, m, 7.0)
    m.migrate("host")
    f = a.transfers.fence()
    assert not f.done and a.transfers.pending == 1
    assert not a.host_contains(CLS, 0)        # payload still in flight
    f.wait()
    assert f.done and a.transfers.pending == 0
    assert a.host_contains(CLS, 0)            # deposited at the fence
    m.free()
    a.assert_quiescent()


def test_eager_mode_is_synchronous():
    a, cell = make_executor_arena()
    a.transfers.eager = True
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(2)
    write_blocks(a, cell, m, 3.0)
    m.migrate("host")
    assert a.transfers.pending == 0           # drained inside enqueue
    assert a.host_contains(CLS, 0)
    m.migrate("device")
    assert a.transfers.pending == 0
    np.testing.assert_array_equal(contents(cell, m.block_ids())[0],
                                  np.full((1, 2, 2), 3.0, np.float32))
    m.free()
    a.assert_quiescent()


def test_swap_out_holds_sources_until_dispatch():
    """Vacated d2h sources are unallocatable until the gather launches;
    an allocation that needs them DISPATCHES the plane (non-blocking
    hold release -- the host copy stays overlapped, never a forced
    synchronous drain on the pressure path)."""
    a, cell = make_executor_arena(n=4)
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(3)
    write_blocks(a, cell, m, 5.0)
    old = m.migrate("host")
    alloc = a.allocator(CLS)
    assert alloc.num_held == 3 and a.num_free(CLS) == 1
    assert alloc.num_used + alloc.num_free + alloc.num_held == 4
    # per-engine hold attribution: the d2h queue owns all three, and
    # the ArenaStats surface reports the same split
    assert alloc.held_by_engine() == {"d2h": 3}
    assert a.stats()[CLS].held_by_engine == {"d2h": 3}
    # needs 3 blocks; only 1 unheld -> the arena dispatches the plane
    m2 = a.mapping(CLS, owner=1)
    m2.ensure_capacity(3)
    assert alloc.num_held == 0
    # the gather launched (ids reusable) but the host copy is still in
    # transit: allocation pressure does not force the synchronous path
    assert not a.host_contains(CLS, 0)
    assert 0 in a.transfers.in_transit(CLS)
    a.transfers.drain()
    assert a.host_contains(CLS, 0)
    np.testing.assert_array_equal(
        a._host_payload[(CLS, 0)][0][0],
        np.full((1, 3, 2), 5.0, np.float32)[:, :len(old)])
    m2.free()
    m.free()
    a.assert_quiescent()


def test_metadata_only_arena_completes_plans_inline():
    """No executor registered: plans complete immediately as
    residency-only moves (pure-policy arenas keep working)."""
    a = Arena()
    a.register_class("meta", num_blocks=4, block_nbytes=8)
    m = a.mapping("meta", owner=0)
    m.ensure_capacity(2)
    m.migrate("host")
    assert a.transfers.pending == 0
    assert a.transfers.stats.enqueued["d2h"] == 1
    assert a.transfers.stats.completed["d2h"] == 1
    m.migrate("device")
    assert a.transfers.pending == 0
    m.free()
    a.assert_quiescent()


# ---------------------------------------------------------------------------
# coalescing: the batched multi-plan launch, and its dependency break
# ---------------------------------------------------------------------------
def test_coalesced_copies_respect_dependencies():
    a, cell = make_executor_arena(n=6)
    cell["streams"] = [cell["streams"][0].at[:, 0].set(9.0)]
    # chain: 0 -> 1, then 1 -> 2 (reads the first copy's destination)
    a.transfers.enqueue_copy(CLS, [0], [1])
    a.transfers.enqueue_copy(CLS, [1], [2])
    # independent pair: may share the chain tail's launch
    a.transfers.enqueue_copy(CLS, [0], [3])
    a.transfers.drain()
    got = np.asarray(cell["streams"][0])
    for b in (1, 2, 3):
        np.testing.assert_array_equal(got[:, b],
                                      np.full((1, 2), 9.0, np.float32))
    st_ = a.transfers.stats
    assert st_.coalesced == 1                  # [1->2, 0->3] shared a launch
    assert st_.completed["d2d"] == 3


def test_multi_plan_gather_single_launch():
    """Two swap-outs enqueued back-to-back ride ONE device gather."""
    a, cell = make_executor_arena(n=8)
    m1 = a.mapping(CLS, owner=1)
    m1.ensure_capacity(2)
    write_blocks(a, cell, m1, 1.0)
    m2 = a.mapping(CLS, owner=2)
    m2.ensure_capacity(3)
    write_blocks(a, cell, m2, 2.0)
    launches_before = a.transfers.stats.launches
    m1.migrate("host")
    m2.migrate("host")
    a.transfers.dispatch()                     # one gather for both plans
    gather_launches = (a.transfers.stats.launches - launches_before)
    assert gather_launches == 1
    assert a.transfers.stats.coalesced >= 1
    a.transfers.complete_dispatched()
    k1 = a._host_payload[(CLS, 1)][0][0]
    k2 = a._host_payload[(CLS, 2)][0][0]
    np.testing.assert_array_equal(k1, np.full((1, 2, 2), 1.0, np.float32))
    np.testing.assert_array_equal(k2, np.full((1, 3, 2), 2.0, np.float32))
    m1.free()
    m2.free()
    a.assert_quiescent()


# ---------------------------------------------------------------------------
# multi-queue: cross-queue fences, the d2h reorder window, prefetch
# ---------------------------------------------------------------------------
def test_cross_queue_dependency_check_both_ways():
    """The enqueue-time dependency check: a d2h gather reading a block a
    pending d2d copy WRITES depends on the copy (launch strength); one
    that only shares READS does not.  This is the check that gates the
    reorder-window coalescing."""
    a, cell = make_executor_arena(n=8)
    m = a.mapping(CLS, owner=1)
    m.ensure_capacity(2)                       # blocks 0, 1
    write_blocks(a, cell, m, 1.0)
    a.transfers.enqueue_copy(CLS, [0], [2])    # d2d: writes block 2
    # FAILS the check: swap-out whose gather reads the copy's dst
    m2 = a.mapping(CLS, owner=2)
    m2.leases.append(a.lease_blocks(CLS, 2, 1)[0])
    # (hand-build a src overlap without device state: direct enqueue)
    f = a.transfers.enqueue_swap_out(CLS, "dep", [2])
    [dep_plan] = [p for p in a.transfers.engines[D2H]._pending
                  if p.owner == "dep"]
    assert dep_plan.deps == {D2D: 0}           # must wait for the copy
    # PASSES the check: swap-out reading only the copy's SOURCE
    a.transfers.enqueue_swap_out(CLS, "indep", [1])
    [ind_plan] = [p for p in a.transfers.engines[D2H]._pending
                  if p.owner == "indep"]
    assert ind_plan.deps == {}                 # read-read: no ordering
    a.transfers.drain()
    a.host_discard(CLS, "dep")
    a.host_discard(CLS, "indep")
    m2.leases.pop().release()
    m.free()
    a.assert_quiescent()


def test_d2h_reorder_window_coalesces_across_dependency():
    """Satellite pin: two INDEPENDENT swap-outs enqueued on either side
    of a d2d copy share one gather launch (the reorder window -- the
    old single-FIFO plane could only batch consecutive plans), while a
    swap-out that depends on the copy's destination is held back and
    reads the POST-copy payload."""
    a, cell = make_executor_arena(n=12)
    m1 = a.mapping(CLS, owner=1)
    m1.ensure_capacity(2)
    write_blocks(a, cell, m1, 1.0)
    parent = a.mapping(CLS, owner=3)
    parent.ensure_capacity(1)
    write_blocks(a, cell, parent, 9.0)
    m2 = a.mapping(CLS, owner=2)
    m2.ensure_capacity(2)
    write_blocks(a, cell, m2, 2.0)

    m1.migrate("host")                          # d2h A (independent)
    child = parent.fork(owner=4, nblocks=1)     # d2d X: COW copy into a
    assert child.ensure_writable(0) is not None  # fresh block
    cow_dst = child.leases[0].block
    child_swap = child.migrate("host")          # d2h B: reads X's dst
    assert cow_dst in child_swap
    m2.migrate("host")                          # d2h C (independent)

    launches_before = a.transfers.stats.launches
    a.transfers.dispatch()
    # A and C coalesced into ONE gather past the blocked B; X executed;
    # B launched separately once its dependency settled
    assert a.transfers.stats.reordered >= 1
    gather_launches = a.transfers.stats.launches - launches_before
    assert gather_launches == 3                # [A+C] + [X] + [B]
    a.transfers.complete_dispatched()
    # B's payload is the POST-copy content (the dependency held)
    np.testing.assert_array_equal(
        a._host_payload[(CLS, 4)][0][0],
        np.full((1, 1, 2), 9.0, np.float32))
    for m in (child, m1, m2):
        m.free()
    parent.free()
    a.assert_quiescent()


def test_swap_in_waits_for_same_owner_swap_out_fence():
    """Cross-queue COMPLETE-strength fence: an h2d swap-in enqueued
    while the owner's d2h swap-out is still unfenced lands the payload
    first (preempt + immediate resume), in any dispatch order."""
    a, cell = make_executor_arena(n=6)
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(2)
    write_blocks(a, cell, m, 5.0)
    m.migrate("host")                          # d2h pending
    m.migrate("device")                        # h2d with fdep on the d2h
    [plan] = a.transfers.engines[H2D]._pending
    assert plan.fdeps == {D2H: 0}
    a.transfers.dispatch()
    np.testing.assert_array_equal(contents(cell, m.block_ids())[0],
                                  np.full((1, 2, 2), 5.0, np.float32))
    m.free()
    a.assert_quiescent()


def test_prefetch_rides_background_lane_and_commits():
    """Speculative swap-in: payload is PEEKED (host copy stays
    authoritative), the plan rides the background lane, and committing
    after completion is pure bookkeeping -- with the overlap attributed
    to the h2d engine, NOT the d2h double buffer (the stats bugfix)."""
    a, cell = make_executor_arena(n=8)
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(2)
    write_blocks(a, cell, m, 4.0)
    m.migrate("host")
    a.transfers.drain()
    ids = m.prefetch()
    assert m.prefetched and m.placement == "host"
    assert a.host_contains(CLS, 0)             # payload NOT consumed
    assert all(l.in_flight for l in m._spec)
    assert a.transfers.queue_depths()[H2D][BACKGROUND] == 1
    a.transfers.dispatch()                     # scatter executes
    assert a.host_contains(CLS, 0)             # still only peeked
    a.transfers.note_compute()                 # a decode runs in between
    got_ids, completed = m.commit_prefetch()
    assert completed and got_ids == ids
    assert m.placement == "device" and not a.host_contains(CLS, 0)
    st_ = a.transfers.stats
    assert st_.prefetch_enqueued == 1 and st_.prefetch_committed == 1
    assert st_.overlapped["h2d"] == 1          # attributed to h2d...
    assert st_.overlapped["d2h"] == 0          # ...not the d2h buffer
    np.testing.assert_array_equal(contents(cell, m.block_ids())[0],
                                  np.full((1, 2, 2), 4.0, np.float32))
    m.free()
    a.assert_quiescent()


def test_cancelled_prefetch_releases_leases_and_holds():
    """Satellite regression: cancelling a prefetch releases its
    in-flight leases (and any holds) and never executes the scatter;
    the payload stays resumable, and the vacated ids' next tenant is
    not clobbered by a stale speculative scatter."""
    a, cell = make_executor_arena(n=6)
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(2)
    write_blocks(a, cell, m, 7.0)
    m.migrate("host")                          # d2h pending, 2 holds
    ids = m.prefetch()                         # spec plan, fdep on d2h
    spec_leases = list(m._spec)
    assert all(l.in_flight for l in spec_leases)
    free_before = a.num_free(CLS)
    m.cancel_prefetch()
    assert not m.prefetched
    assert a.num_free(CLS) == free_before + len(ids)
    assert not any(l.in_flight for l in spec_leases)    # flags cleared
    assert not any(l.live for l in spec_leases)         # leases released
    assert a.transfers.stats.prefetch_cancelled == 1
    assert a.transfers.stats.completed["h2d"] == 0      # never scattered
    # the d2h swap-out (and its holds) is untouched by the cancel
    assert 0 in a.transfers.in_transit(CLS)
    # a new tenant reuses the cancelled ids; draining must not replay
    # the withdrawn scatter over it
    m2 = a.mapping(CLS, owner=1)
    m2.ensure_capacity(2)
    write_blocks(a, cell, m2, 3.0)
    a.transfers.drain()
    np.testing.assert_array_equal(contents(cell, m2.block_ids())[0],
                                  np.full((1, 2, 2), 3.0, np.float32))
    # and the candidate still resumes from its intact host payload
    m.migrate("device")
    a.transfers.drain()
    np.testing.assert_array_equal(contents(cell, m.block_ids())[0],
                                  np.full((1, 2, 2), 7.0, np.float32))
    m2.free()
    m.free()
    a.assert_quiescent()


def test_metadata_only_prefetch_commit_does_not_count_overlap():
    """Regression: a metadata-only arena completes the speculative plan
    inline at enqueue -- committing it must not count a spurious
    ``overlapped[h2d]`` (nothing ever launched, no compute ran)."""
    a = Arena()
    a.register_class("meta", num_blocks=4, block_nbytes=8)
    m = a.mapping("meta", owner=0)
    m.ensure_capacity(2)
    m.migrate("host")
    m.prefetch()
    ids, completed = m.commit_prefetch()
    assert completed and len(ids) == 2
    assert a.transfers.stats.overlapped["h2d"] == 0
    m.free()
    a.assert_quiescent()


def test_free_while_prefetched_cancels_speculation():
    """Freeing a prefetched mapping withdraws the speculation and tears
    down host residency + payload together (no leaks)."""
    a, cell = make_executor_arena(n=6)
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(2)
    write_blocks(a, cell, m, 2.0)
    m.migrate("host")
    a.transfers.drain()
    m.prefetch()
    m.free()
    a.assert_quiescent()


# ---------------------------------------------------------------------------
# the read barrier: unfenced reads of in-flight leases raise
# ---------------------------------------------------------------------------
def test_unfenced_read_of_in_flight_lease_raises():
    a, cell = make_executor_arena()
    parent = a.mapping(CLS, owner=0)
    parent.ensure_capacity(2)
    write_blocks(a, cell, parent, 4.0)
    child = parent.fork(owner=1, nblocks=2)
    plan = child.ensure_writable(1)            # enqueues the COW copy
    assert plan is not None
    lease = child.leases[1]
    assert lease.in_flight and lease.kind == IN_FLIGHT
    with pytest.raises(UnfencedReadError):
        child.assert_settled()                 # the copy has not landed
    parent.assert_settled()                    # parent is untouched
    a.transfers.dispatch()                     # the engine's read barrier
    assert not lease.in_flight
    child.assert_settled()
    np.testing.assert_array_equal(contents(cell, [lease.block])[0],
                                  np.full((1, 1, 2), 4.0, np.float32))
    child.free()
    parent.free()
    a.assert_quiescent()


def test_free_while_swap_in_pending_does_not_clobber_next_tenant():
    """Regression: freeing a device mapping whose swap-in scatter is
    still pending must settle the plan first -- otherwise the ids
    return to the free list, a new tenant writes them, and the stale
    scatter clobbers the new data at the next dispatch."""
    a, cell = make_executor_arena(n=4)
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(2)
    write_blocks(a, cell, m, 7.0)
    m.migrate("host")
    m.migrate("device")                        # h2d scatter pending
    m.free()                                   # cancel mid-resume
    a.assert_quiescent()
    m2 = a.mapping(CLS, owner=1)
    m2.ensure_capacity(2)                      # reuses the vacated ids
    ids = jnp.asarray(m2.block_ids(), jnp.int32)
    cell["streams"] = [s.at[:, ids].set(3.0) for s in cell["streams"]]
    a.transfers.drain()                        # must NOT replay 7.0 here
    np.testing.assert_array_equal(contents(cell, m2.block_ids())[0],
                                  np.full((1, 2, 2), 3.0, np.float32))
    m2.free()
    a.assert_quiescent()


def test_quiescence_requires_fenced_plane():
    a, cell = make_executor_arena()
    m = a.mapping(CLS, owner=0)
    m.ensure_capacity(1)
    m.migrate("host")
    with pytest.raises(AssertionError):
        a.assert_quiescent()                   # unfenced d2h plan
    a.transfers.drain()
    m.free()
    a.assert_quiescent()


# ---------------------------------------------------------------------------
# ORDERING property: any multi-queue interleaving (including speculative
# prefetch and its cancellation) == the synchronous drain() schedule
# ---------------------------------------------------------------------------
GROW, PREEMPT, RESUME, COW, FENCE, PREFETCH, CANCELPF = range(7)


def _avail(a):
    return a.num_free(CLS) + a.allocator(CLS).num_held


def _run_schedule(ops, eager):
    a, cell = make_executor_arena(n=10)
    a.transfers.eager = eager
    maps = []
    next_owner = [0]
    fill = [1.0]

    def new_owner():
        next_owner[0] += 1
        return next_owner[0]

    for code, arg in ops:
        live = [m for m in maps if not m.freed]
        device = [m for m in live if m.placement == "device"]
        host = [m for m in live if m.placement == "host"]
        if code == GROW and _avail(a) >= 2:
            m = a.mapping(CLS, owner=new_owner())
            m.ensure_capacity(1 + arg % 2)
            maps.append(m)
            write_blocks(a, cell, m, fill[0])
            fill[0] += 1
        elif code == PREEMPT and device:
            device[arg % len(device)].migrate("host")
        elif code == RESUME and host:
            target = host[arg % len(host)]
            if _avail(a) >= len(target):
                target.migrate("device")
        elif code == COW and device and _avail(a) >= 1:
            parent = device[arg % len(device)]
            child = parent.fork(owner=new_owner(), nblocks=1)
            maps.append(child)
            child.ensure_writable(0)
            write_blocks(a, cell, child, fill[0])
            fill[0] += 1
        elif code == FENCE:
            a.transfers.drain()
        elif code == PREFETCH:
            idle = [m for m in host if not m.prefetched and len(m) > 0]
            if idle:
                target = idle[arg % len(idle)]
                if _avail(a) >= len(target):
                    target.prefetch()
        elif code == CANCELPF:
            spec = [m for m in host if m.prefetched]
            if spec:
                spec[arg % len(spec)].cancel_prefetch()
    a.transfers.drain()
    state = {}
    for m in maps:
        if m.freed:
            continue
        if m.placement == "device":
            state[m.owner] = ("device", contents(cell, m.block_ids()))
        else:
            payload, nbytes = a._host_payload[(CLS, m.owner)]
            state[m.owner] = ("host", [np.asarray(p) for p in payload],
                              nbytes)
    return state


@settings(max_examples=20)
@given(st.lists(st.tuples(st.integers(0, 6), st.integers(0, 7)),
                min_size=0, max_size=24))
def test_any_interleaving_matches_synchronous_drain(ops):
    """Block contents and host payloads after an arbitrary mix of
    grows, preemptions, resumes, COW barriers, speculative prefetches,
    prefetch cancellations, device writes and fences are identical
    between the overlapped multi-queue schedule and the eager
    (drain-per-enqueue) schedule."""
    deferred = _run_schedule(ops, eager=False)
    eager = _run_schedule(ops, eager=True)
    assert deferred.keys() == eager.keys()
    for owner in deferred:
        d, e = deferred[owner], eager[owner]
        assert d[0] == e[0], (owner, d[0], e[0])
        for da, ea in zip(d[1], e[1]):
            np.testing.assert_array_equal(da, ea)
        if d[0] == "host":
            assert d[2] == e[2]


# ---------------------------------------------------------------------------
# checkpoint-on-arena: snapshot/restore of host tier + mappings
# ---------------------------------------------------------------------------
def test_snapshot_restore_roundtrip(tmp_path):
    a, cell = make_executor_arena(n=8)
    m = a.mapping(CLS, owner=5)
    m.ensure_capacity(2)
    write_blocks(a, cell, m, 6.0)
    m.migrate("host")
    dev = a.mapping(CLS, owner="live")
    dev.ensure_capacity(1)
    path = str(tmp_path / "arena.npz")
    a.snapshot(path)                           # drains in-flight payloads

    b = Arena()
    restored = b.restore(path)
    assert (CLS, 5) in restored
    mm = restored[(CLS, 5)]
    assert mm.placement == "host" and len(mm) == 2
    assert b.host_counts(CLS) == {5: 2}
    # device-resident mappings do NOT survive a restart by design
    assert b.find_mapping(CLS, "live") is None
    # payload bytes roundtrip exactly (uint8 view through the npz)
    pa, na = a._host_payload[(CLS, 5)]
    pb, nb = b._host_payload[(CLS, 5)]
    assert na == nb
    for x, y in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # and the restored mapping re-materializes through a new executor
    cell2 = {"streams": [jnp.zeros((1, 8, 2), jnp.float32)]}
    b.transfers.register_executor(
        CLS, lambda: list(cell2["streams"]),
        lambda s: cell2.update(streams=list(s)))
    new_ids = mm.migrate("device")
    b.transfers.drain()
    np.testing.assert_array_equal(
        np.asarray(cell2["streams"][0])[:, np.asarray(new_ids)],
        np.full((1, 2, 2), 6.0, np.float32))
    mm.free()
    b.assert_quiescent()


def test_restore_rejects_spec_mismatch(tmp_path):
    a, _ = make_executor_arena(n=8)
    path = str(tmp_path / "arena.npz")
    a.snapshot(path)
    b = Arena()
    b.register_class(CLS, num_blocks=16, block_nbytes=8)   # different spec
    with pytest.raises(ValueError):
        b.restore(path)


# ---------------------------------------------------------------------------
# per-dp-group accounting (ArenaStats measurement surface)
# ---------------------------------------------------------------------------
def test_per_dp_group_block_accounting():
    a = Arena()
    a.register_class("kvg", num_blocks=8, block_nbytes=16, dp_groups=2)
    m = a.mapping("kvg", owner=0)
    m.ensure_capacity(3)                       # ids 0,1,2 -> group 0
    st_ = a.stats()["kvg"]
    assert st_.groups == [{"group": 0, "used": 3, "free": 1},
                          {"group": 1, "used": 0, "free": 4}]
    # re-registration with a different grouping is loud
    with pytest.raises(ValueError):
        a.register_class("kvg", num_blocks=8, block_nbytes=16, dp_groups=4)
    m.free()
    a.assert_quiescent()


def test_report_renders_groups_and_transfers():
    from repro.report import fmt_arena_table, fmt_transfer_table
    a = Arena()
    a.register_class("kvg", num_blocks=8, block_nbytes=16, dp_groups=2)
    m = a.mapping("kvg", owner=0)
    m.ensure_capacity(2)
    d = a.stats().to_dict()
    table = fmt_arena_table(d)
    assert "g0 2/2" in table and "g1 0/4" in table
    tr = fmt_transfer_table(d["transfers"])
    assert "d2h" in tr and "coalesced" in tr
    m.free()
