import os

# Tests run single-device (the dry-run alone forces 512 host devices).
# Distributed tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)


def assert_engine_quiescent(eng):
    """Suite-wide leak invariant for serving-engine tests.

    After a workload fully drains, the unified Arena must be back to
    zero non-pinned blocks used, an all-zeros refcount histogram (no
    stranded COW shares) and an empty host swap tier -- in every pool
    class (KV, scheduler metadata, ...).  Engine tests call this as
    their last line so allocator leaks fail loudly at the test that
    introduced them.
    """
    assert not eng.running, f"sequences still running: {eng.running}"
    assert not eng.sched.has_work, "scheduler still has queued work"
    eng.arena.assert_quiescent()
