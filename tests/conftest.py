import os

# Tests run single-device (the dry-run alone forces 512 host devices).
# Distributed tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
