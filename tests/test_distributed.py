"""Distributed semantics via subprocesses with forced host device counts:
sharded execution must match single-device execution exactly."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(ndev: int, body: str) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={ndev}"
        import sys
        sys.path.insert(0, {ROOT + '/src'!r})
        import numpy as np, jax, jax.numpy as jnp
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, cwd=ROOT, timeout=900)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    return r.stdout


_TRAIN_PARITY = """
import dataclasses
from repro.configs.base import get_config
from repro.models.api import build_model, make_concrete_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train_step
from repro.optim import adamw as OPT

cfg = get_config("%s").reduced()
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
batch = make_concrete_batch(cfg, 4, 16)
mesh = make_host_mesh(model=%d)
step = build_train_step(model, mesh, OPT.AdamWConfig(lr_peak=1e-3,
    warmup_steps=1, total_steps=5), remat=True, donate=False)
opt = OPT.init_state(params)
p2, o2, mets = step(params, opt, batch)
print("LOSS", float(mets["loss"]))
print("GNORM", float(mets["grad_norm"]))
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma_2b", "qwen3_moe_30b_a3b",
                                  "rwkv6_7b"])
def test_train_step_parity_1dev_vs_8dev(arch):
    """Same loss/grad-norm on a (4,2) mesh as on a single device --
    covering TP matmuls, the shard_map MoE, SP residuals."""
    out1 = _run(1, _TRAIN_PARITY % (arch, 1))
    out8 = _run(8, _TRAIN_PARITY % (arch, 2))

    def val(out, key):
        return float([l for l in out.splitlines()
                      if l.startswith(key)][0].split()[1])

    assert abs(val(out1, "LOSS") - val(out8, "LOSS")) < 2e-2, (out1, out8)
    assert abs(val(out1, "GNORM") - val(out8, "GNORM")) < \
        2e-2 * max(1.0, val(out1, "GNORM"))


_SERVE_PARITY = """
import dataclasses
import numpy as np
from repro.configs.base import get_config
from repro.core.paged_kv import PagedKVCache, PagedKVManager
from repro.models.api import build_model, make_concrete_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_serve_step, dp_groups_for

cfg = get_config("gemma2_27b").reduced()
m = build_model(cfg)
p, _ = m.init(jax.random.PRNGKey(0))
B, S, S0 = 4, 32, 24
batch = make_concrete_batch(cfg, B, S)
mesh = make_host_mesh(model=%d)
dp = dp_groups_for(mesh, B)
kvcfg = m.kv_config(max_seq=S, batch=B, dp_groups=dp)
cache = PagedKVCache.create(kvcfg, B)
# group-local tables: each dp group owns a contiguous pool range
per_group = kvcfg.num_blocks // dp
mbs = kvcfg.max_blocks_per_seq
tables = np.full((B, mbs), -1, np.int32)
seq_per_group = B // dp
for b in range(B):
    g, r = divmod(b, seq_per_group)
    tables[b] = np.arange(r * mbs, (r + 1) * mbs)
cache = dataclasses.replace(cache, block_tables=jnp.asarray(tables))
pre = dict(batch); pre["tokens"] = batch["tokens"][:, :S0]
last, cache = m.prefill(p, pre, cache, jnp.full((B,), S0, jnp.int32))
step = build_serve_step(m, mesh, cache, donate=False)
outs = []
for t in range(S0, S):
    lg, cache = step(p, batch["tokens"][:, t], cache)
    outs.append(np.asarray(lg, np.float32))
np.save("/tmp/serve_parity_%d.npy", np.stack(outs))
print("DONE")
"""


@pytest.mark.slow
def test_serve_step_parity_sharded():
    import numpy as np
    _run(1, _SERVE_PARITY % (1, 1))
    _run(8, _SERVE_PARITY % (2, 8))
    a = np.load("/tmp/serve_parity_1.npy")
    b = np.load("/tmp/serve_parity_8.npy")
    np.testing.assert_allclose(a, b, atol=3e-3, rtol=2e-2)


_COMPRESSION = """
from repro.optim import compression as C
from repro.compat import shard_map
import functools
from jax.sharding import PartitionSpec as P
mesh = jax.make_mesh((4,), ("data",))
rng = np.random.RandomState(0)
# per-device gradients: (4, L) -- each row one device's gradient
g = rng.randn(4, C.BLOCK * 2).astype(np.float32)
res = np.zeros_like(g)

@functools.partial(shard_map, mesh=mesh,
                   in_specs=(P("data"), P("data")),
                   out_specs=(P("data"), P("data")), check_vma=False)
def sync(gv, rv):
    mean, new_r = C.sync_mean(gv[0], rv[0], ("data",))
    return mean[None], new_r[None]

m1, r1 = sync(jnp.asarray(g), jnp.asarray(res))
m1 = np.asarray(m1)
true_mean = g.mean(0)
# all rows agree (it's a mean), error small vs int8 quantization
assert np.allclose(m1, m1[0:1], atol=1e-7)
err = np.abs(m1[0] - true_mean).max() / np.abs(true_mean).max()
print("ERR", err)
assert err < 0.02, err
# error feedback: residual equals what was not transmitted
m2, r2 = sync(jnp.asarray(g), r1)
print("DONE")
"""


@pytest.mark.slow
def test_int8_compressed_allreduce():
    out = _run(4, _COMPRESSION)
    assert "DONE" in out


_COMPRESSED_STEP = """
from repro.configs.base import get_config
from repro.models.api import build_model, make_concrete_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train_step
from repro.train.compressed import build_compressed_train_step, init_residual
from repro.optim import adamw as OPT

cfg = get_config("gemma_2b").reduced()
model = build_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))
batch = make_concrete_batch(cfg, 4, 16)
mesh = make_host_mesh(model=2)
opt_cfg = OPT.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=5)

ref_step = build_train_step(model, mesh, opt_cfg, donate=False)
p_ref, o_ref, m_ref = ref_step(params, OPT.init_state(params), batch)

cstep = build_compressed_train_step(model, mesh, opt_cfg)
res = init_residual(params, mesh)
p_c, o_c, res, m_c = cstep(params, OPT.init_state(params), res, batch)

print("LOSS", float(m_ref["loss"]), float(m_c["loss"]))
# int8-synced update must track the exact update closely
num = den = 0.0
for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_c)):
    num += float(jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32))))
    den += float(jnp.sum(jnp.square(a.astype(jnp.float32))))
rel = (num / max(den, 1e-30)) ** 0.5
print("RELDIFF", rel)
assert rel < 2e-3, rel
# residual is nonzero (it holds the quantization error)
assert float(jnp.abs(res).max()) > 0
print("DONE")
"""


@pytest.mark.slow
def test_compressed_train_step_tracks_exact():
    out = _run(8, _COMPRESSED_STEP)
    assert "DONE" in out
