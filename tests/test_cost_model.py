"""The repro.cost accounting subsystem: exact byte bills per op.

Two layers of tests:

  * synthetic HLO text with hand-computable byte counts -- pins the
    accounting RULES (DUS billed at slice size, gather at gathered rows,
    fusion aliasing, trip-count sources, collectives);
  * compiled-HLO integration -- pins the paper-level CLAIM that a paged
    KV layout's byte bill stays close to the contiguous baseline instead
    of inflating to pool size (the overcounting trap that would make
    software paging look ~4x more expensive than it is).
"""

import jax
import jax.numpy as jnp
import pytest

from repro import cost
from repro.cost.accounting import Cost


def _c(hlo: str) -> Cost:
    return cost.analyze_text(hlo)


# ---------------------------------------------------------------------------
# synthetic HLO: exact rule pins
# ---------------------------------------------------------------------------
def test_dus_billed_at_update_size():
    hlo = """
HloModule m

ENTRY %main (big: f32[1024], upd: f32[16], idx: s32[]) -> f32[1024] {
  %big = f32[1024]{0} parameter(0)
  %upd = f32[16]{0} parameter(1)
  %idx = s32[] parameter(2)
  ROOT %dus = f32[1024]{0} dynamic-update-slice(f32[1024]{0} %big, f32[16]{0} %upd, s32[] %idx)
}
"""
    c = _c(hlo)
    assert c.bytes == 2 * 16 * 4                      # read upd + write slice
    assert c.by_op == {"dynamic-update-slice": 128.0}


def test_gather_billed_at_gathered_rows():
    # 8 rows of 32 f32 from a 1024-row table + 8 s32 indices
    hlo = """
HloModule m

ENTRY %main (t: f32[1024,32], ids: s32[8,1]) -> f32[8,32] {
  %t = f32[1024,32]{1,0} parameter(0)
  %ids = s32[8,1]{1,0} parameter(1)
  ROOT %g = f32[8,32]{1,0} gather(f32[1024,32]{1,0} %t, s32[8,1]{1,0} %ids), offset_dims={1}
}
"""
    c = _c(hlo)
    assert c.bytes == 2 * 8 * 32 * 4 + 8 * 4          # 2x gathered + indices
    assert c.by_op == {"gather": 2080.0}


def test_convolution_flops_exact():
    """Conv FLOPs are kernel_spatial x in_channels per output element
    (not the old 2x-result-elements approximation): a 3x3 conv over 4
    input channels does a 36-long dot per output element."""
    hlo = """
HloModule m

ENTRY %main (x: f32[1,8,8,4], w: f32[3,3,4,16]) -> f32[1,8,8,16] {
  %x = f32[1,8,8,4]{3,2,1,0} parameter(0)
  %w = f32[3,3,4,16]{3,2,1,0} parameter(1)
  ROOT %conv = f32[1,8,8,16]{3,2,1,0} convolution(f32[1,8,8,4]{3,2,1,0} %x, f32[3,3,4,16]{3,2,1,0} %w), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f
}
"""
    c = _c(hlo)
    assert c.flops == 2 * (1 * 8 * 8 * 16) * (3 * 3 * 4)


def test_convolution_flops_depthwise_grouped():
    """Grouped conv: the kernel's 'i' dim is already per-group in HLO,
    so no feature_group_count correction applies -- depthwise (i=1)
    bills only kernel-spatial FLOPs per output element."""
    hlo = """
HloModule m

ENTRY %main (x: f32[1,8,8,4], w: f32[3,3,1,4]) -> f32[1,8,8,4] {
  %x = f32[1,8,8,4]{3,2,1,0} parameter(0)
  %w = f32[3,3,1,4]{3,2,1,0} parameter(1)
  ROOT %conv = f32[1,8,8,4]{3,2,1,0} convolution(f32[1,8,8,4]{3,2,1,0} %x, f32[3,3,1,4]{3,2,1,0} %w), window={size=3x3 pad=1_1x1_1}, dim_labels=b01f_01io->b01f, feature_group_count=4
}
"""
    c = _c(hlo)
    assert c.flops == 2 * (1 * 8 * 8 * 4) * (3 * 3 * 1)


def test_scan_matmul_trips_from_backend_config():
    # 128x128x128 dot inside a while with known_trip_count n=12
    hlo = """
HloModule m

%body (p: (s32[], f32[128,128], f32[128,128])) -> (s32[], f32[128,128], f32[128,128]) {
  %p = (s32[], f32[128,128], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[128,128], f32[128,128]) %p), index=0
  %a = f32[128,128]{1,0} get-tuple-element((s32[], f32[128,128], f32[128,128]) %p), index=1
  %b = f32[128,128]{1,0} get-tuple-element((s32[], f32[128,128], f32[128,128]) %p), index=2
  %d = f32[128,128]{1,0} dot(f32[128,128]{1,0} %a, f32[128,128]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(s32[] %i, s32[] %one)
  ROOT %r = (s32[], f32[128,128], f32[128,128]) tuple(s32[] %ip, f32[128,128]{1,0} %d, f32[128,128]{1,0} %b)
}

%cond (q: (s32[], f32[128,128], f32[128,128])) -> pred[] {
  %q = (s32[], f32[128,128], f32[128,128]) parameter(0)
  %j = s32[] get-tuple-element((s32[], f32[128,128], f32[128,128]) %q), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(s32[] %j, s32[] %n), direction=LT
}

ENTRY %main (x: (s32[], f32[128,128], f32[128,128])) -> (s32[], f32[128,128], f32[128,128]) {
  %x = (s32[], f32[128,128], f32[128,128]) parameter(0)
  ROOT %w = (s32[], f32[128,128], f32[128,128]) while((s32[], f32[128,128], f32[128,128]) %x), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
}
"""
    c = _c(hlo)
    assert c.flops == 12 * 2 * 128 * 128 * 128
    # dot traffic also multiplied: 12 * (result + 2 operands)
    assert c.by_op["matmul"] == 12 * 3 * 128 * 128 * 4


def test_trip_count_falls_back_to_cond_constant():
    hlo = """
HloModule m

%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[64]) %p), index=0
  %v = f32[64]{0} get-tuple-element((s32[], f32[64]) %p), index=1
  %d = f32[64]{0} add(f32[64]{0} %v, f32[64]{0} %v)
  %one = s32[] constant(1)
  %ip = s32[] add(s32[] %i, s32[] %one)
  ROOT %r = (s32[], f32[64]) tuple(s32[] %ip, f32[64]{0} %d)
}

%cond (q: (s32[], f32[64])) -> pred[] {
  %q = (s32[], f32[64]) parameter(0)
  %j = s32[] get-tuple-element((s32[], f32[64]) %q), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(s32[] %j, s32[] %n), direction=LT
}

ENTRY %main (x: (s32[], f32[64])) -> (s32[], f32[64]) {
  %x = (s32[], f32[64]) parameter(0)
  ROOT %w = (s32[], f32[64]) while((s32[], f32[64]) %x), condition=%cond, body=%body
}
"""
    c = _c(hlo)
    # 7 trips x (f32 add 768 + s32 add 12 + cond compare 9)
    assert c.by_op["other"] == 7 * (768 + 12 + 9)


def test_fusion_dus_root_aliases_target():
    # fusion computing big[idx:idx+16] = upd: bill the slice, NOT the
    # 1024-element operand (the paper-critical in-place block write)
    hlo = """
HloModule m

%fused (p0: f32[1024], p1: f32[16], p2: s32[]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %p1 = f32[16]{0} parameter(1)
  %p2 = s32[] parameter(2)
  ROOT %dus = f32[1024]{0} dynamic-update-slice(f32[1024]{0} %p0, f32[16]{0} %p1, s32[] %p2)
}

ENTRY %main (big: f32[1024], upd: f32[16], idx: s32[]) -> f32[1024] {
  %big = f32[1024]{0} parameter(0)
  %upd = f32[16]{0} parameter(1)
  %idx = s32[] parameter(2)
  ROOT %f = f32[1024]{0} fusion(f32[1024]{0} %big, f32[16]{0} %upd, s32[] %idx), kind=kLoop, calls=%fused
}
"""
    c = _c(hlo)
    # write slice (64) + read upd param (64) + read idx (4); big NOT billed
    assert c.bytes == 64 + 64 + 4
    assert c.by_op["dynamic-update-slice"] == 64.0


def test_fusion_dus_root_sees_through_bitcast_target():
    # the DUS target arrives via bitcast(param): the alias must still be
    # recognized so the 4 KB pool is not billed
    hlo = """
HloModule m

%fused (p0: f32[1024], p1: f32[16], p2: s32[]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %p1 = f32[16]{0} parameter(1)
  %p2 = s32[] parameter(2)
  %bc = f32[1024]{0} bitcast(f32[1024]{0} %p0)
  ROOT %dus = f32[1024]{0} dynamic-update-slice(f32[1024]{0} %bc, f32[16]{0} %p1, s32[] %p2)
}

ENTRY %main (big: f32[1024], upd: f32[16], idx: s32[]) -> f32[1024] {
  %big = f32[1024]{0} parameter(0)
  %upd = f32[16]{0} parameter(1)
  %idx = s32[] parameter(2)
  ROOT %f = f32[1024]{0} fusion(f32[1024]{0} %big, f32[16]{0} %upd, s32[] %idx), kind=kLoop, calls=%fused
}
"""
    c = _c(hlo)
    assert c.bytes == 64 + 64 + 4, c.by_op
    assert c.by_op["dynamic-update-slice"] == 64.0


def test_multi_output_fusion_dus_billed_per_element():
    # fused K+V cache token write: root tuple(dus_k, dus_v) must bill
    # two slice-sized updates, not two full pools
    hlo = """
HloModule m

%fused (p0: f32[1024], p1: f32[1024], p2: f32[16], p3: s32[]) -> (f32[1024], f32[1024]) {
  %p0 = f32[1024]{0} parameter(0)
  %p1 = f32[1024]{0} parameter(1)
  %p2 = f32[16]{0} parameter(2)
  %p3 = s32[] parameter(3)
  %dk = f32[1024]{0} dynamic-update-slice(f32[1024]{0} %p0, f32[16]{0} %p2, s32[] %p3)
  %dv = f32[1024]{0} dynamic-update-slice(f32[1024]{0} %p1, f32[16]{0} %p2, s32[] %p3)
  ROOT %t = (f32[1024], f32[1024]) tuple(f32[1024]{0} %dk, f32[1024]{0} %dv)
}

ENTRY %main (kp: f32[1024], vp: f32[1024], upd: f32[16], idx: s32[]) -> (f32[1024], f32[1024]) {
  %kp = f32[1024]{0} parameter(0)
  %vp = f32[1024]{0} parameter(1)
  %upd = f32[16]{0} parameter(2)
  %idx = s32[] parameter(3)
  ROOT %f = (f32[1024], f32[1024]) fusion(f32[1024]{0} %kp, f32[1024]{0} %vp, f32[16]{0} %upd, s32[] %idx), kind=kLoop, calls=%fused
}
"""
    c = _c(hlo)
    # 2 slice writes (64 each) + upd read (64) + idx read (4); neither
    # pool billed
    assert c.by_op["dynamic-update-slice"] == 128.0
    assert c.bytes == 128 + 64 + 4, c.by_op


def test_attribute_walks_conditional_branches():
    hlo = """
HloModule m

%true_b (tp: f32[1024]) -> f32[1024] {
  %tp = f32[1024]{0} parameter(0)
  ROOT %tn = f32[1024]{0} negate(f32[1024]{0} %tp)
}

%false_b (fp: f32[1024]) -> f32[1024] {
  %fp = f32[1024]{0} parameter(0)
  ROOT %fa = f32[1024]{0} add(f32[1024]{0} %fp, f32[1024]{0} %fp)
}

ENTRY %main (pr: pred[], x: f32[1024]) -> f32[1024] {
  %pr = pred[] parameter(0)
  %x = f32[1024]{0} parameter(1)
  ROOT %c = f32[1024]{0} conditional(pred[] %pr, f32[1024]{0} %x, f32[1024]{0} %x), branch_computations={%true_b, %false_b}
}
"""
    c = _c(hlo)
    tally = cost.HloCostModel(hlo).attribute(top=10, min_bytes=0)
    total = sum(v for _, v in tally)
    assert c.bytes > 0
    assert total == c.bytes, (total, c.bytes, tally)


def test_fusion_param_read_via_gather_is_sliced():
    # pool read only through (bitcast ->) gather: billed at gathered size
    hlo = """
HloModule m

%fused (p0: f32[1024,32], p1: s32[8,1]) -> f32[8,32] {
  %p0 = f32[1024,32]{1,0} parameter(0)
  %p1 = s32[8,1]{1,0} parameter(1)
  %bc = f32[1024,32]{1,0} bitcast(f32[1024,32]{1,0} %p0)
  %g = f32[8,32]{1,0} gather(f32[1024,32]{1,0} %bc, s32[8,1]{1,0} %p1), offset_dims={1}
  ROOT %n = f32[8,32]{1,0} negate(f32[8,32]{1,0} %g)
}

ENTRY %main (t: f32[1024,32], ids: s32[8,1]) -> f32[8,32] {
  %t = f32[1024,32]{1,0} parameter(0)
  %ids = s32[8,1]{1,0} parameter(1)
  ROOT %f = f32[8,32]{1,0} fusion(f32[1024,32]{1,0} %t, s32[8,1]{1,0} %ids), kind=kLoop, calls=%fused
}
"""
    c = _c(hlo)
    # result write (1024) + gathered read (1024) + index read (32);
    # the 128KB pool operand must NOT be billed
    assert c.bytes == 1024 + 1024 + 32
    assert c.by_op["gather"] == 1024.0


def test_collective_bytes_by_kind():
    hlo = """
HloModule m

ENTRY %main (x: f32[4096]) -> f32[4096] {
  %x = f32[4096]{0} parameter(0)
  %ar = f32[4096]{0} all-reduce(f32[4096]{0} %x), replica_groups={}
  ROOT %ag = f32[4096]{0} all-gather(f32[4096]{0} %ar), dimensions={0}
}
"""
    c = _c(hlo)
    assert c.coll["all-reduce"] == 4096 * 4
    assert c.coll["all-gather"] == 4096 * 4
    assert c.coll_total == 2 * 4096 * 4
    assert c.by_op["collective"] == 2 * 4096 * 4


def test_async_collective_billed_once_at_output():
    # '-start' returns a (input, output) tuple: billing its shape would
    # double-charge; the pair must be billed once, at the output size
    hlo = """
HloModule m

ENTRY %main (x: f32[1024]) -> f32[4096] {
  %x = f32[1024]{0} parameter(0)
  %ags = (f32[1024], f32[4096]) all-gather-start(f32[1024]{0} %x), dimensions={0}
  ROOT %agd = f32[4096]{0} all-gather-done((f32[1024], f32[4096]) %ags)
}
"""
    c = _c(hlo)
    assert c.coll["all-gather"] == 4096 * 4
    assert c.coll_total == 4096 * 4
    assert c.by_op["collective"] == 4096 * 4


def test_cost_add_merges_by_op():
    a = Cost()
    a.add_bytes("gather", 100.0)
    b = Cost()
    b.add_bytes("gather", 50.0)
    b.add_bytes("matmul", 10.0)
    a.add(b, times=2.0)
    assert a.by_op == {"gather": 200.0, "matmul": 20.0}
    assert a.bytes == 220.0
    assert a.dominant_op() == "gather"


def test_xla_cost_analysis_normalizes_shapes():
    class ListShaped:
        def cost_analysis(self):
            return [{"flops": 5.0}]

    class DictShaped:
        def cost_analysis(self):
            return {"flops": 7.0}

    class Broken:
        def cost_analysis(self):
            raise RuntimeError("no backend")

    class Empty:
        def cost_analysis(self):
            return []

    assert cost.xla_cost_analysis(ListShaped()) == {"flops": 5.0}
    assert cost.xla_cost_analysis(DictShaped()) == {"flops": 7.0}
    assert cost.xla_cost_analysis(Broken()) == {}
    assert cost.xla_cost_analysis(Empty()) == {}
    assert cost.xla_flops(ListShaped()) == 5.0


# ---------------------------------------------------------------------------
# compiled HLO: integration + the paper's Table-level claim
# ---------------------------------------------------------------------------
def test_compiled_embedding_gather_not_billed_at_table_size():
    T, W, n = 4096, 256, 32

    def g(table, ids):
        return table[ids]

    comp = jax.jit(g).lower(
        jax.ShapeDtypeStruct((T, W), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32)).compile()
    c = cost.analyze_compiled(comp)
    table_bytes = T * W * 4
    rows_bytes = n * W * 4
    assert c.bytes < 0.2 * table_bytes, c.by_op
    assert c.bytes >= 2 * rows_bytes


def test_paged_kv_block_write_vs_contiguous_baseline():
    """The paper's Table-level claim: a paged decode step (token DUS
    write + block-table gather read) is billed for the bytes it TOUCHES
    -- the bill must be pool-size independent and must match the
    contiguous layout's slice-sized write, not inflate to pool size
    (the overcounting trap that made paging look ~4x too expensive)."""
    B, H, D, BT, S = 4, 2, 64, 16, 128
    MB = S // BT
    token_bytes = B * H * D * 4
    gathered_bytes = B * MB * BT * H * D * 4

    def make_paged(NB):
        def paged(pool, tbl, seqlens, kv):
            blk = jnp.take_along_axis(
                tbl, (seqlens[:, None]) // BT, axis=1)[:, 0]
            off = seqlens % BT
            flat = pool.reshape(NB * BT, H, D)
            flat = flat.at[blk * BT + off].set(kv)   # paged token write
            pages = flat.reshape(NB, BT, H, D)[jnp.maximum(tbl, 0)]
            return flat.reshape(NB, BT, H, D), pages.sum(axis=(1, 2))

        return cost.analyze_compiled(
            jax.jit(paged, donate_argnums=0).lower(
                jax.ShapeDtypeStruct((NB, BT, H, D), jnp.float32),
                jax.ShapeDtypeStruct((B, MB), jnp.int32),
                jax.ShapeDtypeStruct((B,), jnp.int32),
                jax.ShapeDtypeStruct((B, H, D), jnp.float32)).compile())

    def contig(cache, seqlens, kv):
        flat = cache.reshape(B * S, H, D)
        flat = flat.at[jnp.arange(B) * S + seqlens].set(kv)
        cache = flat.reshape(B, S, H, D)
        return cache, cache.sum(axis=(1, 2))

    cc = cost.analyze_compiled(jax.jit(contig, donate_argnums=0).lower(
        jax.ShapeDtypeStruct((B, S, H, D), jnp.float32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B, H, D), jnp.float32)).compile())

    cp1 = make_paged(B * MB)          # pool == working set
    cp4 = make_paged(4 * B * MB)      # pool 4x working set
    # pool-size independence: same bill no matter how big the pool is
    assert cp1.bytes == cp4.bytes, (cp1.by_op, cp4.by_op)
    # the token write itself: slice-sized, layout-independent
    assert cp1.by_op["dynamic-update-slice"] == token_bytes
    assert cp1.by_op["dynamic-update-slice"] == \
        cc.by_op["dynamic-update-slice"]
    # total bill bounded by the touched working set (gather read +
    # materialized copy + reduce re-read), not the pool
    assert cp1.bytes < 3.5 * gathered_bytes, cp1.by_op


def test_attribute_reports_trip_multiplied_tally():
    L, B, D = 5, 16, 32

    def f(ws, x):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return x.sum()

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32)).compile()
    tally = cost.attribute(comp.as_text(), top=10, min_bytes=0)
    assert tally, "attribute() returned nothing"
    total = sum(v for _, v in tally)
    c = cost.analyze_compiled(comp)
    assert abs(total - c.bytes) / c.bytes < 0.35
