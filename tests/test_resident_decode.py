"""Resident decode path vs the eager full-rebuild fallback.

The engine's default decode tail keeps block tables / state rows
device-persistent, scatters only the slots whose mapping changed
(delta sync) and runs ONE fused, buffer-donated callable per step
(table scatter + KV append + attention + argmax, next-token vector
latched on device).  ``resident_tables=False`` is the pinned fallback:
full host rebuild + separate upload every step.  These tests pin the
two paths token-identical across everything that mutates a mapping --
forced preemption, COW forks, external compaction, live migration, a
fork-heavy arrival trace -- for all three cache disciplines, and pin
the resident path's whole point: steady-state decode stops uploading.

``check_consistency()`` runs every step: in resident mode it audits the
device-side shadow (tables/rows vs the manager's truth) and would trip
on any mapping mutation that forgot to mark its slot dirty.
"""

import numpy as np
import pytest
import jax

from repro.configs.base import get_config
from repro.models.api import build_model
from repro.serve.engine import Engine, Request
from conftest import assert_engine_quiescent


@pytest.fixture(scope="module")
def families():
    """One tiny model per discipline: paged / constant / composite."""
    out = {}
    for key, name in (("dense", "gemma_2b"), ("ssm", "mamba2_370m"),
                      ("hybrid", "zamba2_2p7b")):
        cfg = get_config(name).reduced()
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(hash(key) % 2**31))
        out[key] = (cfg, model, params)
    return out


def _engine(model, params, resident, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("num_blocks", 24)
    return Engine(model, params, eos_id=-1, prefill_budget=None,
                  resident_tables=resident, **kw)


def _prompts(cfg, seed, n=4, shared=True):
    rng = np.random.RandomState(seed)
    out = [rng.randint(2, cfg.vocab_size, size=int(rng.randint(6, 20)))
           for _ in range(n)]
    if shared and n >= 3:
        # consecutive shared-prefix pair so the child forks off a LIVE
        # parent (COW through the resident tables)
        out[2] = np.concatenate([out[1], rng.randint(2, cfg.vocab_size,
                                                     size=3)])
    return out


def _run(eng, prompts, *, max_new=6, preempt_at=3, compact=False,
         max_steps=400):
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr.copy(), max_new=max_new))
    forced = compacted = False
    while (eng.sched.has_work or eng.running) and eng.steps < max_steps:
        eng.step()
        eng.check_consistency()
        if eng.steps == preempt_at and eng.running and not forced:
            eng.preempt_latest()
            forced = True
        if (compact and forced and not compacted
                and eng.arena.fragmentation(eng.mgr.pool_class) > 0):
            assert eng.compact_now() > 0
            eng.check_consistency()
            compacted = True
    eng.sync_transfers()
    assert forced
    if compact:
        assert compacted
    return {r.rid: list(r.generated) for r in eng.done}


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_resident_matches_eager_across_preemption(families, family, rng):
    """Per-request token identity, resident vs eager, across a forced
    preemption round-trip (and a COW fork for the paged discipline) --
    with strictly fewer host uploads on the resident path."""
    cfg, model, params = families[family]
    prompts = _prompts(cfg, seed=11, shared=(family == "dense"))
    got = {}
    uploads = {}
    for resident in (True, False):
        eng = _engine(model, params, resident)
        got[resident] = _run(eng, prompts)
        uploads[resident] = eng.stats["host_uploads"]
        assert len(eng.done) == len(prompts)
        assert eng.stats["resident_tables"] is resident
        assert_engine_quiescent(eng)
    assert got[True] == got[False]
    assert uploads[True] < uploads[False]


def test_resident_identity_across_external_compaction(families, rng):
    """A mid-flight ``compact_now()`` rewrites every lease under the
    resident tables' feet; the full-dirty scatter must absorb it
    token-identically (the per-step shadow audit would catch a missed
    invalidation)."""
    cfg, model, params = families["dense"]
    rng23 = np.random.RandomState(23)
    base = rng23.randint(2, cfg.vocab_size, size=16)
    # a long-lived fork parent + early releases leave holes in the pool
    # (the shape test_serve_stack's acceptance workload uses)
    prompts = [base.copy(),
               rng23.randint(2, cfg.vocab_size, size=9),
               base.copy(),
               np.concatenate([base,
                               rng23.randint(2, cfg.vocab_size, size=5)]),
               rng23.randint(2, cfg.vocab_size, size=5)]
    max_new = [10, 6, 6, 6, 6]
    got = {}
    for resident in (True, False):
        eng = _engine(model, params, resident, slots=3, num_blocks=20,
                      watermark=1)
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr.copy(),
                               max_new=max_new[i]))
        forced = compacted = False
        while (eng.sched.has_work or eng.running) and eng.steps < 400:
            eng.step()
            eng.check_consistency()
            if eng.steps == 3 and eng.running and not forced:
                eng.preempt_latest()
                forced = True
            if (forced and not compacted
                    and eng.arena.fragmentation(eng.mgr.pool_class) > 0):
                assert eng.compact_now() > 0
                eng.check_consistency()
                compacted = True
        eng.sync_transfers()
        assert forced and compacted
        assert len(eng.done) == 5
        got[resident] = {r.rid: list(r.generated) for r in eng.done}
        assert eng.arena.compactions >= 1
        assert_engine_quiescent(eng)
    assert got[True] == got[False]


def test_resident_migrate_live_token_identity(families, tmp_path):
    """Live migration restores into a FRESH engine whose resident
    tables have never seen these requests: the adoption path must mark
    everything dirty and resume token-identical to an unmigrated
    resident control."""
    from repro.serve.disagg import migrate_live

    cfg, model, params = families["dense"]
    prompts = _prompts(cfg, seed=37)

    def drive_pre(eng):
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr.copy(), max_new=6))
        for s in range(3):
            eng.step()
            eng.check_consistency()
            if s == 1 and eng.running:
                eng.preempt_latest()

    control = _engine(model, params, True)
    drive_pre(control)
    control.run(400)
    want = {r.rid: list(r.generated) for r in control.done}

    src = _engine(model, params, True)
    drive_pre(src)
    dst, _ = migrate_live(src, lambda: _engine(model, params, True),
                          str(tmp_path / "resident.npz"))
    while (dst.sched.has_work or dst.running) and dst.steps < 400:
        dst.step()
        dst.check_consistency()
    dst.sync_transfers()
    assert {r.rid: list(r.generated) for r in dst.done} == want
    assert_engine_quiescent(dst)


def test_resident_prefixheavy_trace_matches_eager(families):
    """Fork-heavy live traffic (the ``prefixheavy`` arrival trace):
    COW forks, suffix-only prefill and continuous admission all land on
    the delta-sync path; decodes must match the eager fallback
    per-request."""
    from repro.serve.traffic import make_trace

    cfg, model, params = families["dense"]
    got = {}
    for resident in (True, False):
        eng = _engine(model, params, resident, slots=3)
        source = make_trace("prefixheavy", 8, cfg.vocab_size, seed=3,
                            mean_gap=2.0, tenants=2, max_new=6,
                            prompt_cap=24)
        n = len(source)
        eng.serve(source, max_steps=10_000)
        eng.sync_transfers()
        assert len(eng.done) == n
        assert eng.stats["prefix_hits"] > 0          # forks really happened
        got[resident] = {r.rid: list(r.generated) for r in eng.done}
        assert_engine_quiescent(eng)
    assert got[True] == got[False]


def test_resident_steady_state_stops_uploading(families, rng):
    """The headline property: once admissions settle, decode steps stop
    shipping state to the device -- no table rows (outside block-growth
    steps) and no next-token vector (latched on device).  The eager
    fallback pays exactly two uploads every step."""
    cfg, model, params = families["dense"]
    pr = rng.randint(2, cfg.vocab_size, size=8)

    eng = _engine(model, params, True, slots=1)
    eng.submit(Request(rid=0, prompt=pr, max_new=24))
    deltas = []
    last = 0
    while (eng.sched.has_work or eng.running) and eng.steps < 100:
        eng.step()
        eng.check_consistency()
        deltas.append(eng.host_uploads - last)
        last = eng.host_uploads
    assert len(eng.done) == 1
    # after the placement step, upload-free steps dominate: only block-
    # growth steps scatter anything, and the token vector never leaves
    # the device again
    steady = deltas[1:]
    assert steady.count(0) > len(steady) // 2
    assert eng.stats["host_uploads_per_step"] < 1.0

    eng2 = _engine(model, params, False, slots=1)
    eng2.submit(Request(rid=0, prompt=pr, max_new=24))
    eng2.run(100)
    assert eng2.stats["host_uploads"] == 2 * eng2.steps
    assert list(eng2.done[0].generated) == list(eng.done[0].generated)


def test_report_renders_decode_path_section():
    """BENCH_serve.json rendering: populated section AND the n/a
    degradation contract for pre-resident snapshots."""
    from repro.report import fmt_decode_path_table

    doc = {"decode_path": {
               "resident": {"tokens_per_s": 547.6, "completed": 9,
                            "host_uploads_per_step": 0.667,
                            "table_sync_bytes": 760,
                            "table_rows_updated": 19},
               "eager": {"tokens_per_s": 439.9, "completed": 9,
                         "host_uploads_per_step": 2.0,
                         "table_sync_bytes": 2592,
                         "table_rows_updated": 72},
               "token_identical": True},
           "phase_time_s": {"dispatch": 0.03, "sync": 0.001,
                            "decode": 0.09, "retire": 0.001},
           "host_uploads_per_step": 0.7, "table_sync_bytes": 800}
    table = fmt_decode_path_table(doc)
    assert "| resident | 547.6 | 0.667 |" in table
    assert "| eager | 439.9 | 2.0 |" in table
    assert "token identical: True" in table
    assert "step-phase wall share" in table and "decode" in table

    old = fmt_decode_path_table({"tokens_per_s": 1.0})
    assert "n/a" in old and "pre-resident-path" in old
