"""MoE: routing properties, dropless dispatch, grouped-matmul custom VJP."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models import moe as MOE
from repro.models.moe import grouped_matmul


def _one_hot_moe_ref(p, x, cfg):
    """Dense one-hot reference for the dropless MoE layer."""
    e = cfg.moe
    weights, experts, aux = MOE.route(p["router"], x, e)
    T = x.shape[0]
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for kk in range(e.top_k):
        sel = experts[:, kk]                         # (T,)
        wi = p["wi"][sel]                            # (T, d, f)
        wg = p["wg"][sel]
        wo = p["wo"][sel]
        h = jax.nn.silu(jnp.einsum("td,tdf->tf", x, wg)) * \
            jnp.einsum("td,tdf->tf", x, wi)
        yk = jnp.einsum("tf,tfd->td", h, wo)
        y = y + yk.astype(jnp.float32) * weights[:, kk][:, None]
    if e.num_shared_experts:
        h = jax.nn.silu(x @ p["shared_wg"]) * (x @ p["shared_wi"])
        y = y + (h @ p["shared_wo"]).astype(jnp.float32)
    return y.astype(x.dtype), aux


def test_moe_ffn_matches_one_hot_reference(rng):
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    p, _ = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(32, cfg.d_model).astype(np.float32))
    y, aux = MOE.moe_ffn(p, x, cfg)
    y_ref, aux_ref = _one_hot_moe_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-5)


def test_moe_with_shared_experts(rng):
    cfg = get_config("deepseek_v2_lite_16b").reduced()
    p, _ = MOE.init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.randn(16, cfg.d_model).astype(np.float32))
    y, _ = MOE.moe_ffn(p, x, cfg)
    y_ref, _ = _one_hot_moe_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(1, 64), st.integers(2, 8), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_routing_properties(T, E, k):
    k = min(k, E)
    rng = np.random.RandomState(T * 31 + E)
    import dataclasses as dc
    from repro.configs.base import MoEConfig
    e = MoEConfig(num_experts=E, top_k=k, d_ff_expert=8)
    router = jnp.asarray(rng.randn(16, E).astype(np.float32))
    x = jnp.asarray(rng.randn(T, 16).astype(np.float32))
    weights, experts, aux = MOE.route(router, x, e)
    w = np.asarray(weights)
    ex = np.asarray(experts)
    assert w.shape == (T, k) and ex.shape == (T, k)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)   # normalized
    assert (w >= 0).all()
    for t in range(T):                                      # distinct experts
        assert len(set(ex[t])) == k
    assert np.isfinite(float(aux)) and float(aux) > 0.0


def test_grouped_matmul_vjp_exact(rng):
    x = jnp.asarray(rng.randn(20, 6).astype(np.float32))
    w = jnp.asarray(rng.randn(4, 6, 5).astype(np.float32))
    gs = jnp.asarray(np.array([7, 0, 9, 4], np.int32))   # includes empty

    def f(x, w):
        return jnp.sum(jnp.sin(grouped_matmul(x, w, gs)))

    def f_ref(x, w):
        segs = np.repeat(np.arange(4), [7, 0, 9, 4])
        oh = jax.nn.one_hot(jnp.asarray(segs), 4)
        y = jnp.einsum("td,te,edf->tf", x, oh, w)
        return jnp.sum(jnp.sin(y))

    np.testing.assert_allclose(float(f(x, w)), float(f_ref(x, w)), rtol=1e-5)
    g = jax.grad(f, argnums=(0, 1))(x, w)
    g_ref = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(g_ref[0]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(g_ref[1]),
                               rtol=1e-4, atol=1e-5)


def test_dropless_conservation(rng):
    """Every token-replica lands in exactly one expert group."""
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    e = cfg.moe
    p, _ = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(50, cfg.d_model).astype(np.float32))
    weights, experts, _ = MOE.route(p["router"], x, e)
    gs = np.zeros(e.num_experts, np.int64)
    np.add.at(gs, np.asarray(experts).reshape(-1), 1)
    assert gs.sum() == 50 * e.top_k
