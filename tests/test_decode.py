"""The strong serving test: prefill + paged decode reproduces the full
forward logits EXACTLY (position by position) for every family."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.paged_kv import PagedKVCache, PagedKVManager
from repro.models.api import build_model, make_concrete_batch

B, S, S0 = 2, 24, 16
ATOL, RTOL = 4e-3, 2e-2


def _tables(kv, B, S):
    mgr = PagedKVManager(kv.config)
    tb = []
    for sid in range(B):
        mgr.admit(sid, S)
        tb.append(mgr.device_table(sid))
    return dataclasses.replace(kv, block_tables=jnp.asarray(np.stack(tb)))


def _check_lm(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, B, S)
    logits, _, _ = m.forward(p, batch, q_chunk=8)
    cache = _tables(PagedKVCache.create(m.kv_config(max_seq=S, batch=B), B),
                    B, S)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S0]
    last, cache = m.prefill(p, pre, cache, jnp.full((B,), S0, jnp.int32))
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, S0 - 1]),
                               atol=ATOL, rtol=RTOL)
    for t in range(S0, S):
        lg, cache = m.decode_step(p, batch["tokens"][:, t], cache)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("arch", [
    "gemma_2b",               # MQA + geglu + embed scale
    "qwen3_moe_30b_a3b",      # MoE + qk-norm
    "deepseek_v2_lite_16b",   # MLA latent cache + shared experts + dense L0
    "minicpm3_4b",            # MLA with q-lora
    "gemma2_27b",             # local/global + softcaps + post-norms
    "gemma3_27b",             # 5:1 local + dual rope theta
])
def test_decoder_lm_decode_matches_forward(arch):
    _check_lm(arch)


def test_rwkv_decode_matches_forward():
    cfg = get_config("rwkv6_7b").reduced()
    m = build_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, B, S)
    logits, _, _ = m.forward(p, batch)
    st = m.init_state(B)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S0]
    last, st = m.prefill(p, pre, st)
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, S0 - 1]),
                               atol=ATOL, rtol=RTOL)
    for t in range(S0, S):
        lg, st = m.decode_step(p, batch["tokens"][:, t], st)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   atol=ATOL, rtol=RTOL)


def test_zamba_decode_matches_forward():
    cfg = get_config("zamba2_2p7b").reduced()
    m = build_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, B, S)
    logits, _, _ = m.forward(p, batch)
    st = m.init_state(B, max_seq=S)
    st = dataclasses.replace(st, kv=_tables(st.kv, B, S))
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S0]
    last, st = m.prefill(p, pre, st, jnp.full((B,), S0, jnp.int32))
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, S0 - 1]),
                               atol=ATOL, rtol=RTOL)
    for t in range(S0, S):
        lg, st = m.decode_step(p, batch["tokens"][:, t], st)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   atol=ATOL, rtol=RTOL)


def test_whisper_decode_matches_forward():
    cfg = get_config("whisper_tiny").reduced()
    m = build_model(cfg, max_positions=S)
    p, _ = m.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, B, S)
    logits, _, _ = m.forward(p, batch)
    st = m.init_state(B, max_seq=S)
    st = dataclasses.replace(st, self_kv=_tables(st.self_kv, B, S))
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S0]
    last, st = m.prefill(p, pre, st, jnp.full((B,), S0, jnp.int32))
    np.testing.assert_allclose(np.asarray(last), np.asarray(logits[:, S0 - 1]),
                               atol=ATOL, rtol=RTOL)
    for t in range(S0, S):
        lg, st = m.decode_step(p, batch["tokens"][:, t], st)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   atol=ATOL, rtol=RTOL)


def test_decode_with_fragmented_blocks():
    """Physical block placement must not change results (the paper's
    relocation claim): permute the pool blocks + tables, same logits."""
    cfg = get_config("gemma_2b").reduced()
    m = build_model(cfg)
    p, _ = m.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, B, S)
    kvcfg = m.kv_config(max_seq=S, batch=B,
                        num_blocks=B * (S // cfg.kv_block_tokens) + 6)

    def run(perm_seed):
        cache = PagedKVCache.create(kvcfg, B)
        mgr = PagedKVManager(kvcfg)
        rng = np.random.RandomState(perm_seed)
        # emulate fragmentation: burn a few random allocations first
        burn = []
        for _ in range(rng.randint(0, 5)):
            burn.append(mgr.allocator.alloc())
        for b in burn:
            if rng.rand() < 0.5:
                mgr.allocator.free(b)
        tb = []
        for sid in range(B):
            mgr.admit(sid, S)
            tb.append(mgr.device_table(sid))
        cache = dataclasses.replace(cache,
                                    block_tables=jnp.asarray(np.stack(tb)))
        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :S0]
        last, cache = m.prefill(p, pre, cache, jnp.full((B,), S0, jnp.int32))
        outs = [np.asarray(last)]
        for t in range(S0, S):
            lg, cache = m.decode_step(p, batch["tokens"][:, t], cache)
            outs.append(np.asarray(lg))
        return np.stack(outs)

    a, b = run(1), run(2)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
