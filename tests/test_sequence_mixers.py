"""RWKV6 / Mamba2 chunked-parallel forms vs sequential oracles, including
the numerical-stability regime (fast-decay channels) that breaks the
naively factored form."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import mamba2 as M2
from repro.models import rwkv6 as R6


def test_rwkv6_chunked_equals_sequential(rng):
    cfg = get_config("rwkv6_7b").reduced()
    p, _ = R6.init_rwkv6_mix(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model).astype(np.float32))
    y_ref = R6.rwkv6_mix_ref(p, x, cfg)
    y, _ = R6.rwkv6_mix_fwd(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-3)


def test_rwkv6_streaming_continuation(rng):
    cfg = get_config("rwkv6_7b").reduced()
    p, _ = R6.init_rwkv6_mix(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model).astype(np.float32))
    y_ref = R6.rwkv6_mix_ref(p, x, cfg)
    y1, (px, st) = R6.rwkv6_mix_fwd(p, x[:, :8], cfg)
    outs = [np.asarray(y1)]
    for t in range(8, 16):
        y, (px, st) = R6.rwkv6_mix_step(p, x[:, t], cfg, px, st)
        outs.append(np.asarray(y)[:, None])
    np.testing.assert_allclose(np.concatenate(outs, 1), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-3)


def test_rwkv6_fast_decay_stability(rng):
    """Channels with near-total per-step decay (w ~ e^-20): the log-space
    chunked form must stay finite and exact; a q*exp(+cum) factored form
    would overflow here."""
    cfg = get_config("rwkv6_7b").reduced()
    p, _ = R6.init_rwkv6_mix(jax.random.PRNGKey(0), cfg)
    p = dict(p)
    p["w0"] = jnp.full_like(p["w0"], 3.0)      # log w = -exp(3) ~ -20/step
    x = jnp.asarray(rng.randn(1, 16, cfg.d_model).astype(np.float32))
    y_ref = R6.rwkv6_mix_ref(p, x, cfg)
    y, _ = R6.rwkv6_mix_fwd(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-3)


def test_mamba2_chunked_equals_sequential(rng):
    cfg = get_config("zamba2_2p7b").reduced()
    p, _ = M2.init_mamba2(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model).astype(np.float32))
    y_ref = M2.mamba2_ref(p, x, cfg)
    y, _ = M2.mamba2_fwd(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=3e-4, rtol=1e-3)


def test_mamba2_streaming_continuation(rng):
    cfg = get_config("zamba2_2p7b").reduced()
    p, _ = M2.init_mamba2(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model).astype(np.float32))
    y_ref = M2.mamba2_ref(p, x, cfg)
    y1, (cs, ss) = M2.mamba2_fwd(p, x[:, :8], cfg)
    outs = [np.asarray(y1)]
    for t in range(8, 16):
        y, (cs, ss) = M2.mamba2_step(p, x[:, t], cfg, cs, ss)
        outs.append(np.asarray(y)[:, None])
    np.testing.assert_allclose(np.concatenate(outs, 1), np.asarray(y_ref),
                               atol=3e-4, rtol=1e-3)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_rwkv6_chunk_size_invariance(chunk, rng):
    import dataclasses
    cfg = get_config("rwkv6_7b").reduced()
    cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                           chunk=chunk))
    p, _ = R6.init_rwkv6_mix(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(1, 16, cfg.d_model).astype(np.float32))
    y, _ = R6.rwkv6_mix_fwd(p, x, cfg)
    y_ref = R6.rwkv6_mix_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-3)


def test_gradients_finite(rng):
    cfg = get_config("rwkv6_7b").reduced()
    p, _ = R6.init_rwkv6_mix(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.randn(1, 16, cfg.d_model).astype(np.float32))

    def f(pp):
        y, _ = R6.rwkv6_mix_fwd(pp, x, cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(f)(p)
    for leaf in jax.tree.leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
