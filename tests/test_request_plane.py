"""Continuous-batching request plane: pluggable admission policies
(priority classes, per-tenant deficit-round-robin fairness),
deadline-cost preemption vs the LIFO fallback, arrival-trace replay,
and the streaming ``Engine.serve`` loop.

Policy tests are device-free (the scheduler imports no jax); the
integration tests drive the real engine over seeded arrival traces and
pin TOKEN identity across replays -- never step counts, because the
default ``prefill_budget="auto"`` adapts to measured wall time."""

import numpy as np
import pytest
import jax

from repro.configs.base import get_config
from repro.models.api import build_model
from repro.serve.engine import Engine
from repro.serve.scheduler import (FairAdmission, FCFSAdmission, Request,
                                   Scheduler)
from repro.serve.traffic import RequestSource, make_trace
from conftest import assert_engine_quiescent


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma_2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class _Mem:
    """Minimal block-accounting stub for policy tests."""
    class _A:
        def __init__(self, free):
            self.num_free = free

    def __init__(self, free, bt=8):
        self.allocator = self._A(free)
        self.bt = bt

    def blocks_needed(self, tokens):
        return -(-tokens // self.bt)


# ---------------------------------------------------------------------------
# priority classes on the pinned FCFS default
# ---------------------------------------------------------------------------
def test_priority_class_ordering():
    """Lower class admits first; submission order breaks ties within a
    class (stable)."""
    sched = Scheduler()
    for rid, pc in enumerate([2, 0, 1, 0]):
        sched.submit(Request(rid=rid, prompt=np.arange(8), max_new=4,
                             priority_class=pc))
    assert [r.rid for r in sched.queue] == [1, 3, 2, 0]   # service order
    plan = sched.plan_admissions(4, _Mem(free=64), num_running=0)
    assert [r.rid for r in plan.admit] == [1, 3, 2, 0]


def test_default_priorities_are_plain_fcfs():
    """All-zero priority classes degenerate EXACTLY to the
    pre-request-plane FIFO -- the decision-identity guarantee every
    PR 2-5 pin rides on."""
    sched = Scheduler()
    assert isinstance(sched.policy, FCFSAdmission)
    for i in range(4):
        sched.submit(Request(rid=i, prompt=np.arange(8), max_new=4))
    plan = sched.plan_admissions(4, _Mem(free=64), num_running=0)
    assert [r.rid for r in plan.admit] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# per-tenant token-rate fairness (deficit round-robin)
# ---------------------------------------------------------------------------
def test_fair_admission_two_tenant_flood():
    """Tenant A floods the queue before tenant B submits anything; DRR
    still serves them in strict alternation -- B's backlog is never
    starved behind A's, and each tenant's served token rate stays
    equal."""
    sched = Scheduler(policy=FairAdmission(quantum=32))
    for i in range(6):                         # the flood, all first
        sched.submit(Request(rid=i, prompt=np.arange(8), max_new=32,
                             tenant="flood"))
    for i in range(6, 12):
        sched.submit(Request(rid=i, prompt=np.arange(8), max_new=32,
                             tenant="victim"))
    served = []
    while sched.has_work:
        plan = sched.plan_admissions(1, _Mem(free=10 ** 6), num_running=0)
        assert len(plan.admit) == 1
        served.append(plan.admit[0].tenant)
    assert served == ["flood", "victim"] * 6
    # spent queues reset their deficit: no banked credit survives
    assert sched.policy.deficit == {"flood": 0.0, "victim": 0.0}


def test_fair_admission_work_conserving():
    """A lone tenant is never throttled by its own deficit: credit
    accrues until the head is affordable, every single time."""
    sched = Scheduler(policy=FairAdmission(quantum=8))
    for i in range(5):
        sched.submit(Request(rid=i, prompt=np.arange(16), max_new=48,
                             tenant="solo"))
    order = []
    while sched.has_work:
        plan = sched.plan_admissions(1, _Mem(free=10 ** 6), num_running=0)
        assert len(plan.admit) == 1            # never an empty plan
        order.append(plan.admit[0].rid)
    assert order == [0, 1, 2, 3, 4]            # FIFO within the tenant


def test_fair_admission_respects_block_gates():
    """Fairness only reorders the queue -- the worst-case-fit gate
    still ends admission when the candidate cannot fit."""
    sched = Scheduler(policy=FairAdmission())
    sched.submit(Request(rid=0, prompt=np.arange(8), max_new=56,
                         tenant="a"))          # 8 blocks worst case
    plan = sched.plan_admissions(1, _Mem(free=4), num_running=0)
    assert not plan
    plan = sched.plan_admissions(1, _Mem(free=8), num_running=0)
    assert [r.rid for r in plan.admit] == [0]


# ---------------------------------------------------------------------------
# deadline-cost preemption vs the LIFO fallback
# ---------------------------------------------------------------------------
def test_deadline_cost_victim_selection():
    """The victim is the running request with the MOST deadline slack
    (least SLO damage), measured on the scheduler's virtual clock."""
    sched = Scheduler()
    sched.now = 10.0
    relaxed = Request(rid=0, prompt=np.arange(8), max_new=8,
                      generated=[1] * 4, deadline=50.0, admit_order=0)
    urgent = Request(rid=1, prompt=np.arange(8), max_new=8,
                     generated=[1] * 4, deadline=16.0, admit_order=1)
    # slack: relaxed = 50-10-4 = 36, urgent = 16-10-4 = 2 -- LIFO would
    # have evicted slot 1 (newest), deadline cost protects it
    assert sched.pick_victim({0: relaxed, 1: urgent}) == 0
    # a request with no deadline has infinite slack: first to go
    best_effort = Request(rid=2, prompt=np.arange(8), max_new=8,
                          admit_order=2)
    assert sched.pick_victim({0: relaxed, 1: urgent, 2: best_effort}) == 2


def test_deadline_fallback_is_exact_lifo():
    """With no deadlines anywhere, every slack is infinite and the
    choice reduces to max ``admit_order`` -- bit-identical to the PR 2
    LIFO rule, including the resubmitted-early/re-admitted-late case."""
    sched = Scheduler()
    reqs = {s: Request(rid=s, prompt=np.arange(8), max_new=8,
                       admit_order=o)
            for s, o in [(0, 3), (1, 7), (2, 5)]}
    assert sched.pick_victim(reqs) == 1        # highest admit stamp
    with pytest.raises(ValueError):
        sched.pick_victim({})


# ---------------------------------------------------------------------------
# arrival traces: the RequestSource contract and seeded replay
# ---------------------------------------------------------------------------
def test_request_source_polls_by_virtual_time():
    reqs = [Request(rid=i, prompt=np.arange(4), max_new=2,
                    arrival_time=t) for i, t in enumerate([0.0, 2.0,
                                                           2.0, 5.0])]
    src = RequestSource(reqs)
    assert len(src) == 4 and src.has_more
    assert [r.rid for r in src.poll(0.0)] == [0]
    assert src.poll(1.0) == []
    assert [r.rid for r in src.poll(3.0)] == [1, 2]
    assert [r.rid for r in src.poll(100.0)] == [3]
    assert not src.has_more and src.poll(200.0) == []


def test_make_trace_seeded_and_replayable():
    """Same seed -> byte-identical prompts, arrivals, tenants and
    deadlines; different seed -> a different trace."""
    a = make_trace("poisson", 8, vocab=100, seed=7, tenants=3,
                   deadline_slack=4.0)
    b = make_trace("poisson", 8, vocab=100, seed=7, tenants=3,
                   deadline_slack=4.0)
    ra, rb = a._trace, b._trace
    assert [r.arrival_time for r in ra] == [r.arrival_time for r in rb]
    assert [r.tenant for r in ra] == [r.tenant for r in rb]
    assert [r.deadline for r in ra] == [r.deadline for r in rb]
    for x, y in zip(ra, rb):
        np.testing.assert_array_equal(x.prompt, y.prompt)
    c = make_trace("poisson", 8, vocab=100, seed=8, tenants=3)
    assert ([r.arrival_time for r in ra]
            != [r.arrival_time for r in c._trace])
    for kind in ("static", "bursty", "heavytail"):
        src = make_trace(kind, 6, vocab=100, seed=1)
        assert len(src) == 6
    with pytest.raises(ValueError):
        make_trace("diurnal", 4, vocab=100)


# ---------------------------------------------------------------------------
# the streaming serve loop, end to end
# ---------------------------------------------------------------------------
def test_serve_replay_token_identical(setup):
    """Two runs over the same seeded Poisson trace decode identical
    per-request tokens -- even though the adaptive prefill budget is
    wall-clock-driven and may re-time admissions between runs."""
    cfg, model, params = setup

    def run_once():
        eng = Engine(model, params, slots=3, max_seq=64, num_blocks=24,
                     eos_id=-1)
        src = make_trace("poisson", 7, cfg.vocab_size, seed=11,
                         tenants=2, max_new=6, mean_gap=1.5,
                         shared_frac=0.3)
        eng.serve(src, max_steps=2_000)
        assert len(eng.done) == 7
        assert_engine_quiescent(eng)
        return {r.rid: list(r.generated) for r in eng.done}

    assert run_once() == run_once()


def test_serve_admits_midflight_and_reports_latency(setup):
    """Arrivals land mid-decode (the batch never drains between
    requests), every tenant completes, and the latency report carries
    per-tenant TTFT/ITL percentiles."""
    cfg, model, params = setup
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=24,
                 eos_id=-1)
    src = make_trace("poisson", 6, cfg.vocab_size, seed=3, tenants=2,
                     max_new=5, mean_gap=2.0)
    arrivals = {r.rid: r.arrival_time for r in src._trace}
    assert max(arrivals.values()) > 0.0        # genuinely streamed
    eng.serve(src, max_steps=2_000)
    assert len(eng.done) == 6
    rep = eng.latency_report()
    assert set(rep) == {"tenant0", "tenant1"}
    for row in rep.values():
        assert row["requests"] >= 1
        assert row["ttft_p50_ms"] is not None and row["ttft_p50_ms"] >= 0
        assert row["itl_p50_ms"] is not None and row["itl_p50_ms"] >= 0
        assert row["ttft_p99_ms"] >= row["ttft_p50_ms"]
    assert_engine_quiescent(eng)


def test_serve_empty_source_matches_run(setup):
    """``run()`` is a shim over ``serve(None)``: a pre-loaded queue
    drains identically through either entry point."""
    cfg, model, params = setup

    def drive(entry):
        eng = Engine(model, params, slots=2, max_seq=32, num_blocks=12,
                     eos_id=-1, prefill_budget=None)
        for i in range(3):
            rng = np.random.RandomState(20 + i)
            eng.submit(Request(rid=i, prompt=rng.randint(2, 100, size=6),
                               max_new=4))
        done = (eng.run(max_steps=200) if entry == "run"
                else eng.serve(None, max_steps=200))
        assert_engine_quiescent(eng)
        return eng.steps, {r.rid: list(r.generated) for r in done}

    assert drive("run") == drive("serve")
