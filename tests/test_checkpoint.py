"""Block-based checkpointing: roundtrip, atomicity, GC, elastic restore."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train import checkpoint as CKPT


def _tree(rng):
    return {"a": jnp.asarray(rng.randn(1000, 3).astype(np.float32)),
            "b": {"c": jnp.asarray(rng.randn(7).astype(np.float16)),
                  "d": jnp.asarray(rng.randint(0, 9, (4, 4)))},
            "e": [jnp.asarray(rng.randn(2, 2, 2).astype(np.float32))]}


def test_roundtrip(tmp_path, rng):
    t = _tree(rng)
    CKPT.save(str(tmp_path), 7, t, block_bytes=4096)  # force multi-block
    assert CKPT.latest_step(str(tmp_path)) == 7
    r = CKPT.restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_blocks_are_fixed_size(tmp_path, rng):
    t = {"w": jnp.asarray(rng.randn(5000).astype(np.float32))}  # 20 KB
    CKPT.save(str(tmp_path), 1, t, block_bytes=4096)
    bdir = os.path.join(str(tmp_path), "step_00000001", "blocks")
    sizes = sorted(os.path.getsize(os.path.join(bdir, f))
                   for f in os.listdir(bdir))
    assert sizes[-1] == 4096 and len(sizes) == 5     # 4 full + 1 tail


def test_keep_last_gc(tmp_path, rng):
    t = _tree(rng)
    for s in range(6):
        CKPT.save(str(tmp_path), s, t, keep_last=2)
    steps = sorted(d for d in os.listdir(str(tmp_path)))
    assert steps == ["step_00000004", "step_00000005"]


def test_crash_during_write_preserves_previous(tmp_path, rng):
    t = _tree(rng)
    CKPT.save(str(tmp_path), 1, t)
    # simulate a crashed writer: orphaned tmp dir with partial junk
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp", "blocks"))
    assert CKPT.latest_step(str(tmp_path)) == 1
    r = CKPT.restore(str(tmp_path), 1, t)
    np.testing.assert_array_equal(np.asarray(r["a"]), np.asarray(t["a"]))
    # next save cleans the orphan
    CKPT.save(str(tmp_path), 2, t)
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))


_ELASTIC = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    sys.path.insert(0, "src")
    from repro.train import checkpoint as CKPT
    mesh = jax.make_mesh((%d, %d), ("data", "model"))
    t = {"w": jnp.arange(64*8, dtype=jnp.float32).reshape(64, 8),
         "b": jnp.arange(32, dtype=jnp.float32)}
    sh = {"w": NamedSharding(mesh, P("data", "model")),
          "b": NamedSharding(mesh, P(None))}
    if "%s" == "save":
        t = jax.device_put(t, sh)
        CKPT.save(sys.argv[1], 3, t)
    else:
        r = CKPT.restore(sys.argv[1], 3, t, shardings=sh)
        assert np.array_equal(np.asarray(r["w"]),
                              np.arange(64*8, dtype=np.float32).reshape(64, 8))
        for d, idx in r["w"].sharding.devices_indices_map(r["w"].shape).items():
            pass
    print("OK")
""")


@pytest.mark.slow
def test_elastic_restore_different_mesh(tmp_path):
    """Save on an 8-device (4x2) mesh, restore on 4 devices (2x2):
    the block remap is pure metadata; contents bitwise equal."""
    env = dict(os.environ)
    r1 = subprocess.run([sys.executable, "-c", _ELASTIC % (8, 4, 2, "save"),
                         str(tmp_path)], capture_output=True, text=True,
                        env=env, cwd=os.path.dirname(os.path.dirname(
                            os.path.abspath(__file__))))
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, "-c",
                         _ELASTIC % (4, 2, 2, "restore"), str(tmp_path)],
                        capture_output=True, text=True, env=env,
                        cwd=os.path.dirname(os.path.dirname(
                            os.path.abspath(__file__))))
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "OK" in r2.stdout
