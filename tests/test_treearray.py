"""TreeArray (arrays-as-trees) invariants (property-based)."""

import math

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, strategies as st

from repro.core.blockpool import BlockAllocator
from repro.core.treearray import TreeArray, tree_depth_for


@given(st.integers(1, 2000), st.sampled_from([4, 8, 16, 64]),
       st.sampled_from([2, 4, 8]), st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_roundtrip_any_shape(n, leaf, fanout, seed):
    """to_dense(from_dense(x)) == x for all sizes/geometries/placements."""
    x = np.arange(n, dtype=np.float32)
    t = TreeArray.from_dense(x, leaf_size=leaf, fanout=fanout,
                             shuffle_seed=seed)
    assert t.depth == tree_depth_for(n, leaf, fanout)
    np.testing.assert_array_equal(np.asarray(t.to_dense()), x)


@given(st.integers(1, 500), st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_naive_get_matches_dense(n, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(n).astype(np.float32)
    t = TreeArray.from_dense(x, leaf_size=8, fanout=4, shuffle_seed=seed)
    idx = rng.randint(0, n, size=min(64, n))
    np.testing.assert_array_equal(
        np.asarray(t.get_naive(jnp.asarray(idx))), x[idx])


@given(st.integers(1, 300), st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_iterator_sum_equals_naive_sum(n, seed):
    """The paper's core equivalence: iterator and naive disciplines
    compute the same result."""
    rng = np.random.RandomState(seed)
    x = rng.randn(n).astype(np.float32)
    t = TreeArray.from_dense(x, leaf_size=8, fanout=4, shuffle_seed=seed)
    s_iter = float(t.scan_sum_iter())
    s_naive = float(t.scan_sum_naive())
    np.testing.assert_allclose(s_iter, s_naive, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(s_iter, x.sum(), rtol=1e-4, atol=1e-3)


def test_gups_scatter_add(rng):
    n = 300
    x = rng.randn(n).astype(np.float32)
    t = TreeArray.from_dense(x, leaf_size=16, fanout=4, shuffle_seed=1)
    idx = rng.randint(0, n, size=128)
    upd = rng.randn(128).astype(np.float32)
    t2 = t.add(jnp.asarray(idx), jnp.asarray(upd))
    ref = x.copy()
    np.add.at(ref, idx, upd)
    np.testing.assert_allclose(np.asarray(t2.to_dense()), ref, rtol=1e-5,
                               atol=1e-5)


def test_shared_arena_tenants():
    """Many trees share one unified Arena (radix mappings) without
    interference, and free back to a quiescent address space."""
    from repro.mem import Arena
    arena = Arena()
    arena.register_class("tree", num_blocks=64, block_shape=(8,),
                         dtype=np.float32)
    xs = [np.arange(i * 13 + 1, dtype=np.float32) for i in range(5)]
    ts = [TreeArray.from_dense(x, leaf_size=8, fanout=4, arena=arena,
                               pool_class="tree", owner=f"t{i}")
          for i, x in enumerate(xs)]
    st = arena.stats()["tree"]
    assert st.mappings_by_kind == {"radix": 5}
    assert st.num_used == sum(t.num_logical_leaves for t in ts)
    for x, t in zip(xs, ts):
        np.testing.assert_array_equal(np.asarray(t.to_dense()), x)
    for t in ts:
        t.arena_mapping.free()
    arena.assert_quiescent()


def test_set_updates_single_element(rng):
    x = rng.randn(100).astype(np.float32)
    t = TreeArray.from_dense(x, leaf_size=8, fanout=4, shuffle_seed=2)
    t = t.set(jnp.asarray(42), jnp.asarray(7.0))
    y = np.asarray(t.to_dense())
    assert y[42] == 7.0
    mask = np.arange(100) != 42
    np.testing.assert_array_equal(y[mask], x[mask])


def test_overhead_bytes_small():
    """Paper footnote 1: indirection overhead is tiny vs data."""
    n = 1 << 16
    t = TreeArray.from_dense(np.zeros(n, np.float32), leaf_size=1024,
                             fanout=256)
    assert t.overhead_bytes < 0.02 * n * 4
