"""Serving engine: continuous batching, admission by blocks, preemption
and swap, COW fork -- against step-by-step single-request decoding.

Every pinned schedule here runs with ``prefill_budget=None``: the
engine's default is the adaptive ``"auto"`` budget, which derives
admission pacing from MEASURED wall time and is deliberately not
deterministic across runs (live-traffic coverage lives in
test_request_plane.py)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.api import build_model
from repro.serve.engine import Engine, Request
from conftest import assert_engine_quiescent


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma_2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def greedy_reference(model, params, prompt, max_new, max_seq=64):
    """Single-request greedy decode via prefill + decode_step."""
    import dataclasses
    from repro.core.paged_kv import PagedKVCache, PagedKVManager
    kvcfg = model.kv_config(max_seq=max_seq, batch=1)
    cache = PagedKVCache.create(kvcfg, 1)
    mgr = PagedKVManager(kvcfg)
    mgr.admit(0, max_seq)
    cache = dataclasses.replace(
        cache, block_tables=jnp.asarray(mgr.device_table(0))[None])
    bt = kvcfg.block_tokens
    pad = (-len(prompt)) % bt
    toks = jnp.asarray(np.pad(prompt, (0, pad)))[None]
    last, cache = model.prefill(params, {"tokens": toks}, cache,
                                jnp.asarray([len(prompt)], jnp.int32))
    out = [int(jnp.argmax(last[0]))]
    for _ in range(max_new - 1):
        lg, cache = model.decode_step(params,
                                      jnp.asarray([out[-1]]), cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


def test_engine_matches_reference(setup, rng):
    cfg, model, params = setup
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=24,
                 eos_id=-1, prefill_budget=None)
    prompts = [rng.randint(2, cfg.vocab_size, size=n) for n in (5, 9, 3)]
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, max_new=6))
    done = eng.run(max_steps=200)
    assert len(done) == 3
    for req in sorted(done, key=lambda r: r.rid):
        ref = greedy_reference(model, params, req.prompt, 6)
        assert req.generated == ref, (req.rid, req.generated, ref)
    assert_engine_quiescent(eng)


def test_engine_admission_pressure(setup, rng):
    """More requests than pool capacity: queueing + eventual completion,
    pool never over-committed."""
    cfg, model, params = setup
    eng = Engine(model, params, slots=2, max_seq=32, num_blocks=10,
                 eos_id=-1, prefill_budget=None)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=rng.randint(2, 100, size=6),
                           max_new=4))
    peak = 0
    while (eng.sched.has_work or eng.running) and eng.steps < 300:
        eng.step()
        eng.check_consistency()
        peak = max(peak, eng.mgr.allocator.num_used)
    assert len(eng.done) == 5
    assert peak <= 10
    assert_engine_quiescent(eng)


def test_engine_swap_out_in(setup, rng):
    cfg, model, params = setup
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=32,
                 eos_id=-1, prefill_budget=None)
    pr = rng.randint(2, 100, size=8)
    eng.submit(Request(rid=0, prompt=pr, max_new=8))
    for _ in range(3):
        eng.step()
    partial = list(eng.running.values())[0].generated[:]
    eng.preempt_latest()
    assert len(eng.preempted) == 1 and not eng.running
    done = eng.run(max_steps=100)
    assert len(done) == 1
    ref = greedy_reference(model, params, pr, 8)
    assert done[0].generated == ref
    assert done[0].generated[: len(partial)] == partial
    assert eng.store.stats.swap_outs == 1 and eng.store.stats.swap_ins == 1
    assert_engine_quiescent(eng)


def test_engine_preempt_keys_on_admission_order(setup, rng):
    """LIFO preemption evicts the most recently ADMITTED request, not
    the largest rid: a request submitted early but resumed late is the
    first victim."""
    cfg, model, params = setup
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=32,
                 eos_id=-1, prefill_budget=None)
    eng.submit(Request(rid=0, prompt=rng.randint(2, 100, size=6),
                       max_new=8))
    eng.submit(Request(rid=1, prompt=rng.randint(2, 100, size=6),
                       max_new=8))
    eng.step()
    assert len(eng.running) == 2
    eng.preempt_latest()           # evicts rid=1 (admitted second)
    eng.step()                     # resumes rid=1 -> NOW newest by admission
    assert sorted(r.rid for r in eng.running.values()) == [0, 1]
    orders = {r.rid: r.admit_order for r in eng.running.values()}
    assert orders[1] > orders[0]
    eng.preempt_latest()
    assert {r.rid for r in eng.running.values()} == {0}
    done = eng.run(max_steps=200)
    assert len(done) == 2
    for req in done:
        ref = greedy_reference(model, params, req.prompt, 8)
        assert req.generated == ref
    assert_engine_quiescent(eng)


def test_engine_preempt_during_extend_consistent(setup, rng):
    """Regression: growth-pressure preemption mid-extend must leave
    running/seq_lens/tables consistent every step, and everything still
    completes token-identically."""
    cfg, model, params = setup
    # pool sized so concurrent growth forces extend-time preemption:
    # 2 slots x ceil(20/8)=3 blocks worst case + sink = 7 > 6
    eng = Engine(model, params, slots=2, max_seq=32, num_blocks=6,
                 eos_id=-1, prefill_budget=None)
    prompts = [rng.randint(2, 100, size=n) for n in (8, 7, 6)]
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, max_new=12))
    while (eng.sched.has_work or eng.running) and eng.steps < 400:
        eng.step()
        eng.check_consistency()
    assert len(eng.done) == 3
    assert eng.preemptions > 0     # pressure actually fired
    for req in sorted(eng.done, key=lambda r: r.rid):
        ref = greedy_reference(model, params, req.prompt, 12, max_seq=32)
        assert req.generated == ref, (req.rid, req.generated, ref)
    assert_engine_quiescent(eng)


def test_engine_cow_fork(setup, rng):
    """A duplicate prompt forks instead of re-prefilling: prefix blocks
    shared (refcount 2), divergence resolved by the COW barrier, both
    outputs token-identical to the reference."""
    cfg, model, params = setup
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=32,
                 eos_id=-1, prefill_budget=None)
    pr = rng.randint(2, 100, size=16)   # 2 full blocks
    eng.submit(Request(rid=0, prompt=pr, max_new=4))
    eng.step()
    eng.submit(Request(rid=1, prompt=pr.copy(), max_new=4))
    eng.step()
    assert eng.prefix_hits == 1
    shared = eng.mgr.tables[1][:2]
    assert shared == eng.mgr.tables[0][:2]
    assert all(eng.mgr.allocator.refcount(b) == 2 for b in shared)
    done = eng.run(max_steps=100)
    assert len(done) == 2
    ref = greedy_reference(model, params, pr, 4)
    for req in done:
        assert req.generated == ref
    assert_engine_quiescent(eng)
