"""Layered serving stack: swap transfer-size pins, COW divergence, and
the scripted mixed workload (queueing + forced preemption + forked
prompts) against the single-request greedy reference.

The swap-size tests mirror ``test_cost_model.py``'s pool-size-
independence pin: the paper's claim only holds if management traffic
scales with what a sequence HOLDS, never with how big the pool is.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.paged_kv import PagedKVCache, PagedKVConfig, PagedKVManager
from repro.models.api import build_model
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import (PoolGroupMismatchError, Scheduler,
                                   slot_group)
from repro.serve.swap import HostBlockStore
from conftest import assert_engine_quiescent


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma_2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def greedy_reference(model, params, prompt, max_new, max_seq=64):
    kvcfg = model.kv_config(max_seq=max_seq, batch=1)
    cache = PagedKVCache.create(kvcfg, 1)
    mgr = PagedKVManager(kvcfg)
    mgr.admit(0, max_seq)
    cache = dataclasses.replace(
        cache, block_tables=jnp.asarray(mgr.device_table(0))[None])
    bt = kvcfg.block_tokens
    toks = jnp.asarray(np.pad(prompt, (0, (-len(prompt)) % bt)))[None]
    last, cache = model.prefill(params, {"tokens": toks}, cache,
                                jnp.asarray([len(prompt)], jnp.int32))
    out = [int(jnp.argmax(last[0]))]
    for _ in range(max_new - 1):
        lg, cache = model.decode_step(params, jnp.asarray([out[-1]]), cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


# ---------------------------------------------------------------------------
# swap transfer size: proportional to blocks held, independent of pool size
# ---------------------------------------------------------------------------
def _swap_bytes_for(model, params, num_blocks, rng):
    eng = Engine(model, params, slots=2, max_seq=64,
                 num_blocks=num_blocks, eos_id=-1, prefill_budget=None)
    pr = rng.randint(2, 100, size=13)          # 2 blocks of prompt (bt=8)
    eng.submit(Request(rid=0, prompt=pr, max_new=8))
    for _ in range(4):
        eng.step()
    blocks_held = len(eng.mgr.tables[0])
    eng.preempt_latest()
    eng.sync_transfers()     # fence: the d2h plan's host copy lands here
    return blocks_held, eng.store.stats.last_swap_out_bytes, eng.cache.config


@pytest.mark.parametrize("num_blocks", [16, 64, 256])
def test_swap_out_bytes_scale_with_blocks_held(setup, num_blocks):
    cfg, model, params = setup
    held, nbytes, kvcfg = _swap_bytes_for(model, params, num_blocks,
                                          np.random.RandomState(7))
    # exact proportionality: blocks * (layers * streams * block bytes)
    assert nbytes == held * kvcfg.swap_nbytes_per_block()
    # and the pool-sized alternative would have been this much bigger:
    assert nbytes * (num_blocks / held) == pytest.approx(
        num_blocks * kvcfg.swap_nbytes_per_block())


def test_swap_out_bytes_independent_of_pool_size(setup):
    """Same sequence, 16x bigger pool -> byte-identical swap traffic."""
    cfg, model, params = setup
    held_a, bytes_a, _ = _swap_bytes_for(model, params, 16,
                                         np.random.RandomState(7))
    held_b, bytes_b, _ = _swap_bytes_for(model, params, 256,
                                         np.random.RandomState(7))
    assert held_a == held_b
    assert bytes_a == bytes_b


# ---------------------------------------------------------------------------
# COW divergence at the pool level
# ---------------------------------------------------------------------------
def test_cow_fork_diverges_after_write_barrier(rng):
    """Forked child shares prefix blocks; after fork_for_write + device
    copy the two sequences hold independent tails with the common
    prefix preserved in both."""
    from repro.kernels import ops
    cfg = PagedKVConfig(num_layers=2, kv_heads=2, head_dim=4,
                        block_tokens=8, num_blocks=12,
                        max_blocks_per_seq=4, dtype=jnp.float32)
    mgr = PagedKVManager(cfg)
    mgr.admit(0, 12)                       # parent: 12 tokens, 2 blocks
    k_pool = jnp.asarray(
        rng.randn(*cfg.pool_shape()).astype(np.float32))
    parent = list(mgr.tables[0])
    mgr.fork(0, 1, shared_tokens=12)       # tail block shared mid-fill
    assert [mgr.allocator.refcount(b) for b in parent] == [2, 2]

    # child writes at pos 12 -> COW barrier -> one device block copy
    src, dst = mgr.ensure_writable(1, token_pos=12)
    k_pool = ops.copy_pool_blocks(
        k_pool, jnp.asarray([src], jnp.int32), jnp.asarray([dst], jnp.int32))
    before = np.asarray(k_pool).copy()
    # divergent write: child's new token at pos 12 (block 1, offset 4)
    child_val = jnp.full((cfg.num_layers, cfg.kv_heads, cfg.head_dim), 9.0)
    k_pool = k_pool.at[:, dst, 4].set(child_val)

    after = np.asarray(k_pool)
    # parent's physical block untouched by the child's write
    np.testing.assert_array_equal(after[:, src], before[:, src])
    # common prefix (offsets 0..3 of the shared tail) preserved in copy
    np.testing.assert_array_equal(after[:, dst, :4], before[:, src, :4])
    # and the divergent token landed only in the child's block
    np.testing.assert_array_equal(after[:, dst, 4],
                                  np.asarray(child_val, np.float32))
    assert mgr.tables[0][1] == src and mgr.tables[1][1] == dst


# ---------------------------------------------------------------------------
# scheduler policy unit pins (no device)
# ---------------------------------------------------------------------------
class _Mem:
    """Minimal block-accounting stub for policy tests."""
    class _A:
        def __init__(self, free):
            self.num_free = free
    def __init__(self, free, bt=8):
        self.allocator = self._A(free)
        self.bt = bt
    def blocks_needed(self, tokens):
        return -(-tokens // self.bt)


def test_scheduler_watermark_holds_back_admissions():
    sched = Scheduler(watermark=2)
    a = Request(rid=0, prompt=np.arange(8), max_new=8)    # 2 blocks
    b = Request(rid=1, prompt=np.arange(8), max_new=8)
    sched.submit(a)
    sched.submit(b)
    plan = sched.plan_admissions(2, _Mem(free=5), num_running=0)
    # first admission ignores the watermark (progress guarantee), the
    # second would leave 5-2-2=1 < 2 free and is held back
    assert [r.rid for r in plan.admit] == [0]
    plan = sched.plan_admissions(2, _Mem(free=6), num_running=0)
    assert [r.rid for r in plan.admit] == [1]


def test_scheduler_prefill_budget_chunks_admissions():
    sched = Scheduler(prefill_budget=10)
    for i in range(3):
        sched.submit(Request(rid=i, prompt=np.arange(8), max_new=4))
    plan = sched.plan_admissions(3, _Mem(free=64), num_running=0)
    # 8 tokens fit the budget; the next 8 would exceed the remaining 2
    assert [r.rid for r in plan.admit] == [0]
    plan = sched.plan_admissions(3, _Mem(free=64), num_running=1)
    assert [r.rid for r in plan.admit] == [1]


def test_scheduler_full_footprint_gate():
    """A request whose worst case cannot fit right now is not admitted,
    even though its prompt alone would fit (anti-livelock)."""
    sched = Scheduler()
    sched.submit(Request(rid=0, prompt=np.arange(8), max_new=56))  # 8 blocks
    plan = sched.plan_admissions(1, _Mem(free=4), num_running=0)
    assert not plan
    plan = sched.plan_admissions(1, _Mem(free=8), num_running=0)
    assert [r.rid for r in plan.admit] == [0]


def test_scheduler_adaptive_watermark():
    """With no static knob the watermark tracks the EWMA of observed
    blocks/step (times the lookahead horizon); the knob overrides."""
    sched = Scheduler()                        # adaptive by default
    assert sched.watermark == 0                # no growth observed yet
    for _ in range(60):
        sched.observe_growth(2)                # steady 2 blocks/step
    assert sched.watermark == 2 * sched.growth_horizon   # EWMA converged
    static = Scheduler(watermark=3)
    for _ in range(60):
        static.observe_growth(10)
    assert static.watermark == 3               # the knob still wins
    # the adaptive headroom actually holds back admissions: first
    # admission ignores the watermark (progress guarantee); the second
    # would leave 11-2-2=7 < 8 free and is deferred
    sched.submit(Request(rid=0, prompt=np.arange(8), max_new=8))  # 2 blocks
    sched.submit(Request(rid=1, prompt=np.arange(8), max_new=8))
    plan = sched.plan_admissions(2, _Mem(free=11), num_running=0)
    assert [r.rid for r in plan.admit] == [0]
    sched.submit(Request(rid=2, prompt=np.arange(8), max_new=8))
    plan = sched.plan_admissions(2, _Mem(free=11), num_running=1)
    # 11-2=9 >= 8 admits rid=1; 9-2=7 < 8 defers rid=2
    assert [r.rid for r in plan.admit] == [1]


def test_scheduler_adaptive_prefill_budget():
    """``prefill_budget="auto"`` (now the constructor DEFAULT) derives
    the per-step prompt-token budget from MEASURED latency EWMAs (the
    watermark pattern: adapt by default, knob overrides) -- sized so
    one step's prefill costs at most ``prefill_slack`` decode-steps of
    wall time.  Unlimited until both EWMAs have data (the first
    admission is never starved)."""
    sched = Scheduler()                            # "auto" is the default
    assert sched.prefill_budget is None        # no observations yet
    sched.observe_decode(0.1)
    assert sched.prefill_budget is None        # still missing prefill data
    sched.observe_prefill(100, 1.0)            # 10 ms / prompt token
    for _ in range(60):                        # converge both EWMAs
        sched.observe_decode(0.1)
        sched.observe_prefill(100, 1.0)
    # 4 decode-steps of slack * 0.1 s / 0.01 s-per-token = 40 tokens
    assert sched.prefill_budget == 4 * 10
    # and the derived budget actually chunks admissions
    for i in range(3):
        sched.submit(Request(rid=i, prompt=np.arange(32), max_new=4))
    plan = sched.plan_admissions(3, _Mem(free=64), num_running=1)
    assert [r.rid for r in plan.admit] == [0]  # 32 <= 40; next 32 > 8 left
    # the static knob still overrides the adaptive path entirely
    static = Scheduler(prefill_budget=10)
    static.observe_decode(5.0)
    static.observe_prefill(10, 0.001)
    assert static.prefill_budget == 10
    # explicit None opts out entirely -- the deterministic schedule the
    # equivalence pins run on -- no matter what is observed
    off = Scheduler(prefill_budget=None)
    off.observe_decode(0.1)
    off.observe_prefill(100, 1.0)
    assert off.prefill_budget is None
    with pytest.raises(ValueError):
        Scheduler(prefill_budget="fast")
    with pytest.raises(ValueError):
        Scheduler(prefill_budget=0)


def test_scheduler_resume_candidates_peek():
    """``resume_candidates`` exposes the top-k LIFO window without
    popping -- the surface the engine's speculative prefetch rides,
    most-likely-next first (the ordering is also the cancellation
    ranking under pressure)."""
    sched = Scheduler()
    assert sched.resume_candidates() == []
    a = Request(rid=0, prompt=np.arange(8), max_new=8)
    b = Request(rid=1, prompt=np.arange(8), max_new=8)
    c = Request(rid=2, prompt=np.arange(8), max_new=8)
    sched.on_preempt(a)
    assert [r.rid for r in sched.resume_candidates()] == [0]
    sched.on_preempt(b)
    sched.on_preempt(c)
    # top-k=2 window, LIFO top first; deeper entries stay invisible
    assert [r.rid for r in sched.resume_candidates()] == [2, 1]
    assert len(sched.preempted) == 3           # peek does not pop
    assert sched.resume_candidates()[0] is sched.preempted.peek()


def test_scheduler_rejects_cross_group_fork():
    """dp_groups > 1: block tables hold group-local ids, so a fork may
    only alias a parent in its own pool group -- anything else fails
    loudly at admission instead of silently corrupting tables."""
    # 4 slots over 2 groups: slots 0,1 -> group 0; slots 2,3 -> group 1
    assert [slot_group(s, 4, 2) for s in range(4)] == [0, 0, 1, 1]
    Scheduler.validate_fork(0, 1, 4, 2)        # same group: fine
    Scheduler.validate_fork(0, 3, 4, 1)        # dp_groups == 1: no-op
    with pytest.raises(PoolGroupMismatchError):
        Scheduler.validate_fork(0, 2, 4, 2)
    with pytest.raises(PoolGroupMismatchError):
        Scheduler.validate_fork(3, 0, 4, 2)


def test_engine_rejects_group_oblivious_dp_serving(setup):
    """dp_groups > 1 serving fails LOUDLY at construction: the Arena
    still hands out global ids while group-batched caches read tables
    as group-local -- running would corrupt the pool silently."""
    cfg, model, params = setup
    with pytest.raises(NotImplementedError):
        Engine(model, params, slots=2, max_seq=32, num_blocks=8,
               eos_id=-1, dp_groups=2)


def test_cow_barrier_under_pool_exhaustion(setup, rng):
    """Regression: the COW copy target is a deferred claim admission
    cannot reserve; when concurrent growth drains the pool first, the
    barrier must preempt (LIFO) instead of crashing Engine.step()."""
    cfg, model, params = setup
    eng = Engine(model, params, slots=4, max_seq=32, num_blocks=10,
                 eos_id=-1, prefill_budget=None)
    parent = rng.randint(2, 100, size=20)     # partial tail block (bt=8)
    eng.submit(Request(rid=0, prompt=parent, max_new=4))
    eng.submit(Request(rid=1, prompt=rng.randint(2, 100, size=14),
                       max_new=4))
    eng.submit(Request(rid=2, prompt=rng.randint(2, 100, size=14),
                       max_new=4))
    for _ in range(2):
        eng.step()
        eng.check_consistency()
    # child is the parent's 12-token prefix -> forks, allocates nothing
    eng.submit(Request(rid=3, prompt=parent[:12].copy(), max_new=4))
    done = eng.run(max_steps=300)             # must not raise
    assert len(done) == 4
    assert eng.prefix_hits >= 1
    for req in sorted(done, key=lambda r: r.rid):
        ref = greedy_reference(model, params, req.prompt, 4, max_seq=32)
        assert req.generated == ref, (req.rid, req.generated, ref)
    assert_engine_quiescent(eng)


# ---------------------------------------------------------------------------
# the transfer plane, engine-level: the overlapped schedule (dispatch at
# step N, fence at N+1) is token- AND byte-identical to drain()
# ---------------------------------------------------------------------------
def _drive_overlap_workload(model, params, overlap):
    eng = Engine(model, params, slots=2, max_seq=32, num_blocks=6,
                 eos_id=-1, prefill_budget=None,
                 overlap_transfers=overlap)
    rngl = np.random.RandomState(3)
    prompts = [rngl.randint(2, 100, size=n) for n in (8, 7, 6)]
    for i, pr in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=pr, max_new=12))
    while (eng.sched.has_work or eng.running) and eng.steps < 400:
        eng.step()
        eng.check_consistency()
    eng.sync_transfers()
    toks = {r.rid: list(r.generated) for r in eng.done}
    st = eng.store.stats
    return eng, toks, (st.swap_outs, st.swap_ins,
                       st.swap_out_bytes, st.swap_in_bytes)


def test_overlapped_schedule_token_and_byte_identical(setup):
    """Growth-pressure preemptions under double-buffering: same tokens,
    same swap traffic as the synchronous drain() schedule -- and at
    least one host copy genuinely overlapped a decode step."""
    cfg, model, params = setup
    eng_async, toks_async, bytes_async = _drive_overlap_workload(
        model, params, overlap=True)
    eng_sync, toks_sync, bytes_sync = _drive_overlap_workload(
        model, params, overlap=False)
    assert len(toks_async) == 3
    assert toks_async == toks_sync
    assert bytes_async == bytes_sync
    assert eng_async.preemptions > 0            # pressure actually fired
    # the double-buffer win: a swap-out host copy fenced at step N+1 --
    # attributed to the d2h ENGINE (per-engine since the multi-queue
    # refactor: h2d prefetch overlap must not inflate this counter)
    assert eng_async.transfers.stats.overlapped["d2h"] >= 1
    assert all(v == 0 for v in
               eng_sync.transfers.stats.overlapped.values())
    assert_engine_quiescent(eng_async)
    assert_engine_quiescent(eng_sync)


# ---------------------------------------------------------------------------
# speculative swap-in prefetch: a LIFO resume served from a COMPLETED
# background-lane scatter, token-identical to the drain() schedule
# ---------------------------------------------------------------------------
def _drive_prefetch_workload(model, params, overlap):
    """Forced-preemption workload whose LIFO victim waits in the
    prefetch window: two long growers fill two slots, a short filler's
    completion admits a YOUNG victim, and the forced eviction at step
    34 leaves the victim's worst-case footprint blocked
    (free - wc < watermark) while its current blocks fit
    (free - cur >= watermark).  The background h2d scatter completes
    during the multi-step wait; the resume commits it."""
    eng = Engine(model, params, slots=3, max_seq=64, num_blocks=20,
                 eos_id=-1, watermark=2, prefill_budget=None,
                 overlap_transfers=overlap)
    rngl = np.random.RandomState(3)
    shapes = [(8, 48), (8, 48), (8, 8), (8, 40)]
    reqs = [Request(rid=i, prompt=rngl.randint(2, 100, size=pl),
                    max_new=mn) for i, (pl, mn) in enumerate(shapes)]
    for r in reqs:
        eng.submit(r)
    forced = False
    while (eng.sched.has_work or eng.running) and eng.steps < 400:
        eng.step()
        eng.check_consistency()
        if eng.steps == 34 and eng.running and not forced:
            eng.preempt_latest()
            forced = True
    eng.sync_transfers()
    assert forced
    return eng


def test_lifo_resume_served_from_completed_prefetch(setup):
    """Acceptance pin: on the forced-preemption workload, at least one
    LIFO resume is served from a COMPLETED speculative prefetch -- and
    the prefetching schedule stays per-request-token- and
    swap-byte-identical to the single-queue drain() fallback
    (speculation never changes a decision; step counts are not pinned
    -- tokens and bytes are the decision surface)."""
    cfg, model, params = setup
    eng = _drive_prefetch_workload(model, params, overlap=True)
    assert len(eng.done) == 4
    assert eng.preemptions >= 1
    assert eng.prefetches >= 1
    assert eng.prefetch_hits >= 1            # resume skipped the swap-in
    assert eng.prefetch_cancels == 0         # speculation was never wrong
    # the h2d scatter genuinely overlapped decode steps while waiting,
    # attributed to the h2d engine (the per-engine stats bugfix)
    assert eng.transfers.stats.overlapped["h2d"] >= 1
    # decision-identical to the synchronous single-queue schedule
    eng_sync = _drive_prefetch_workload(model, params, overlap=False)
    assert eng_sync.prefetches == 0          # prefetch off under drain()
    assert ({r.rid: list(r.generated) for r in eng.done}
            == {r.rid: list(r.generated) for r in eng_sync.done})
    st, st2 = eng.store.stats, eng_sync.store.stats
    assert (st.swap_out_bytes, st.swap_in_bytes) \
        == (st2.swap_out_bytes, st2.swap_in_bytes)
    # token-identical to the single-request greedy reference
    for req in sorted(eng.done, key=lambda r: r.rid):
        ref = greedy_reference(model, params, req.prompt, req.max_new)
        assert req.generated == ref, (req.rid, req.generated, ref)
    assert_engine_quiescent(eng)
    assert_engine_quiescent(eng_sync)


# ---------------------------------------------------------------------------
# the swap ledger's two-phase speculative accounting syncs through the
# queue's commit/abandon re-notifications -- no engine glue required
# ---------------------------------------------------------------------------
def test_ledger_syncs_on_direct_migrate_commit_and_cancel():
    """Regression: resuming a prefetched mapping through the PUBLIC
    ``migrate("device")`` path (not the engine's guarded commit) must
    still fold the parked speculative bytes into swap_ins -- and a
    cancelled executed prefetch must write them off as waste, never
    leave them parked to corrupt a later resume's accounting."""
    from repro.mem import Arena as _Arena

    def make(n=8):
        a = _Arena()
        a.register_class("kv", num_blocks=n, block_nbytes=8)
        cell = {"s": [jnp.zeros((1, n, 2), jnp.float32)]}
        a.transfers.register_executor(
            "kv", lambda: list(cell["s"]),
            lambda s: cell.update(s=list(s)))
        return a, HostBlockStore(a, "kv")

    # commit path: direct migrate("device") of a prefetched mapping
    a, store = make()
    m = a.mapping("kv", owner=0)
    m.ensure_capacity(2)
    m.migrate("host")
    a.transfers.drain()
    m.prefetch()
    a.transfers.dispatch()                      # scatter completes
    assert store.stats.swap_ins == 0            # parked, not yet demand
    m.migrate("device")                         # auto-commits
    assert store.stats.swap_ins == 1
    assert store.stats.prefetch_commits == 1
    assert store.stats.swap_in_bytes == store.stats.by_engine[
        "h2d-prefetch"]["bytes"]
    m.free()
    a.transfers.drain()
    a.assert_quiescent()

    # cancel path: executed speculation written off, later real resume
    # counted exactly once
    a2, store2 = make()
    m2 = a2.mapping("kv", owner=0)
    m2.ensure_capacity(2)
    m2.migrate("host")
    a2.transfers.drain()
    m2.prefetch()
    a2.transfers.dispatch()
    m2.cancel_prefetch()
    assert store2.stats.prefetch_cancels == 1
    assert store2.stats.prefetch_wasted_bytes > 0
    assert store2.stats.swap_ins == 0
    m2.migrate("device")                        # real (demand) swap-in
    a2.transfers.drain()
    assert store2.stats.swap_ins == 1
    assert store2.stats.prefetch_commits == 0
    m2.free()
    a2.assert_quiescent()


# ---------------------------------------------------------------------------
# checkpoint-on-arena: a restarted engine resumes a preempted sequence
# ---------------------------------------------------------------------------
def test_restart_resumes_decoding(setup, rng, tmp_path):
    cfg, model, params = setup
    eng = Engine(model, params, slots=2, max_seq=64, num_blocks=24,
                 eos_id=-1, prefill_budget=None)
    pr = rng.randint(2, 100, size=9)
    eng.submit(Request(rid=0, prompt=pr, max_new=8))
    for _ in range(4):
        eng.step()
    eng.preempt_latest()
    old = eng.sched.preempted.peek()
    assert old.rid == 0 and len(old.generated) > 0
    path = str(tmp_path / "arena.npz")
    eng.arena.snapshot(path)        # drains the in-transit swap payload

    # "restart": fresh process state -- new engine, new arena; the
    # serving layer re-creates the Request from its own durable queue
    eng2 = Engine(model, params, slots=2, max_seq=64, num_blocks=24,
                  eos_id=-1, prefill_budget=None)
    restored = eng2.arena.restore(path)
    assert ("kv", 0) in restored
    req = Request(rid=0, prompt=pr, max_new=8,
                  generated=list(old.generated),
                  pending_tok=old.pending_tok)
    eng2.restore_preempted(req)
    done = eng2.run(max_steps=200)
    assert len(done) == 1
    ref = greedy_reference(model, params, pr, 8)
    assert done[0].generated == ref
    assert done[0].generated[: len(old.generated)] == list(old.generated)
    assert_engine_quiescent(eng2)


# ---------------------------------------------------------------------------
# the acceptance workload: mixed prompts, forced preemption, forked
# prompts, and at least one Arena compact() cycle mid-flight
# ---------------------------------------------------------------------------
def test_scripted_workload_token_identical(setup, rng):
    cfg, model, params = setup
    eng = Engine(model, params, slots=3, max_seq=64, num_blocks=20,
                 eos_id=-1, watermark=1, prefill_budget=None)
    base = rng.randint(2, cfg.vocab_size, size=16)
    reqs = [
        # rid=0 generates longest so it is still resident (a live fork
        # parent) when rid=3 is admitted into a freed slot
        Request(rid=0, prompt=base.copy(), max_new=10),
        Request(rid=1, prompt=rng.randint(2, cfg.vocab_size, size=9),
                max_new=6),
        Request(rid=2, prompt=base.copy(), max_new=6),          # forked
        Request(rid=3, prompt=np.concatenate(
            [base, rng.randint(2, cfg.vocab_size, size=5)]),    # shared prefix
                max_new=6),
        Request(rid=4, prompt=rng.randint(2, cfg.vocab_size, size=5),
                max_new=6),
    ]
    for r in reqs:
        eng.submit(r)
    forced = False
    while (eng.sched.has_work or eng.running) and eng.steps < 400:
        eng.step()
        eng.check_consistency()
        if eng.steps == 3 and eng.running and not forced:
            eng.preempt_latest()               # forced mid-flight preemption
            forced = True
        if forced and eng.arena.compactions == 0 \
                and eng.arena.fragmentation(eng.mgr.pool_class) > 0:
            # force one defrag cycle mid-flight: live blocks move to the
            # dense prefix, tables absorb the relocation
            moved = eng.compact_now()
            assert moved > 0
            assert eng.arena.fragmentation(eng.mgr.pool_class) == 0.0
            eng.check_consistency()
    assert len(eng.done) == 5
    assert forced and eng.store.stats.swap_outs >= 1
    assert eng.prefix_hits >= 2                # rid=2 and rid=3 forked
    assert eng.arena.compactions >= 1          # the defrag pass really ran
    # every swap-out moved exactly blocks_held * block bytes -- never more
    per_block = eng.cache.config.swap_nbytes_per_block()
    for seq_id, nblocks, nbytes in eng.store.stats.out_log:
        assert nbytes <= nblocks * per_block
        assert nbytes == nblocks * per_block
    # token-identical to the pre-refactor engine's verified reference
    for req in sorted(eng.done, key=lambda r: r.rid):
        ref = greedy_reference(model, params, req.prompt, req.max_new)
        assert req.generated == ref, (req.rid, req.generated, ref)
    assert_engine_quiescent(eng)


# ---------------------------------------------------------------------------
# suffix-only prefill: forked children recompute only the un-cached tail,
# attending through the COW-shared prefix blocks via the paged prefill
# kernel -- pinned token-identical to full recompute AND the greedy
# reference across fork depth, partial-tail aliasing, windowed/softcapped
# layers, and preemption round-trips
# ---------------------------------------------------------------------------
def _run_engine(eng, reqs, max_steps=400):
    for r in reqs:
        eng.submit(r)
    while (eng.sched.has_work or eng.running) and eng.steps < max_steps:
        eng.step()
        eng.check_consistency()
    eng.sync_transfers()
    return {r.rid: list(r.generated) for r in eng.done}


def _suffix_vs_full(model, params, prompts, max_new, **eng_kw):
    """Serve the same prompt set with suffix-only prefill on and off;
    returns (tokens by mode, engine by mode)."""
    toks, engines = {}, {}
    for flag in (True, False):
        eng = Engine(model, params, eos_id=-1, prefill_budget=None,
                     suffix_prefill=flag, **eng_kw)
        reqs = [Request(rid=i, prompt=p.copy(), max_new=m)
                for i, (p, m) in enumerate(zip(prompts, max_new))]
        toks[flag] = _run_engine(eng, reqs)
        engines[flag] = eng
    return toks, engines


def test_suffix_prefill_fork_depth_token_identical(setup, rng):
    """Fork chains two deep: the grandchild aliases blocks the child
    itself aliased from the root.  Depth-1 saves the root's 2 blocks
    (16 tokens), depth-2 saves the child's 3 (24): exactly 40 prefix
    tokens never recomputed."""
    cfg, model, params = setup
    base = rng.randint(2, cfg.vocab_size, size=16)        # 2 full blocks
    mid = np.concatenate([base, rng.randint(2, cfg.vocab_size, size=8)])
    top = np.concatenate([mid, rng.randint(2, cfg.vocab_size, size=5)])
    prompts, max_new = [base, mid, top], [10, 8, 6]
    toks, engines = _suffix_vs_full(model, params, prompts, max_new,
                                    slots=3, max_seq=64, num_blocks=24)
    assert len(toks[True]) == 3
    assert toks[True] == toks[False]
    for rid, pr in enumerate(prompts):
        ref = greedy_reference(model, params, pr, max_new[rid])
        assert toks[True][rid] == ref, (rid, toks[True][rid], ref)
    eng = engines[True]
    assert eng.prefix_hits >= 2
    assert eng.prefill_tokens_saved == 40
    assert engines[False].prefill_tokens_saved == 0
    assert eng.prefill_tokens < engines[False].prefill_tokens
    assert_engine_quiescent(eng)
    assert_engine_quiescent(engines[False])


def test_suffix_prefill_partial_tail_alias(setup, rng):
    """Partial-tail aliasing, both directions: a child fully contained
    in the parent shares the parent's half-filled tail block (its
    recomputed last block scatters to the sink; attention reads the
    aliased original), and a child EXTENDING a mid-block parent has its
    share rounded DOWN to the block boundary so its private tail is
    recomputed, never lost."""
    cfg, model, params = setup
    parent = rng.randint(2, cfg.vocab_size, size=20)      # tail mid-block
    inner = parent[:12].copy()                            # contained child
    longer = np.concatenate([parent,
                             rng.randint(2, cfg.vocab_size, size=6)])
    prompts, max_new = [parent, inner, longer], [10, 6, 6]
    toks, engines = _suffix_vs_full(model, params, prompts, max_new,
                                    slots=3, max_seq=64, num_blocks=24)
    assert len(toks[True]) == 3
    assert toks[True] == toks[False]
    for rid, pr in enumerate(prompts):
        ref = greedy_reference(model, params, pr, max_new[rid])
        assert toks[True][rid] == ref, (rid, toks[True][rid], ref)
    # inner shares 12 but recomputes the aliased tail block (saves 8);
    # longer's share of 20 rounds down to 16 (saves 16)
    assert engines[True].prefill_tokens_saved == 8 + 16
    assert_engine_quiescent(engines[True])


def test_suffix_prefill_sliding_window_softcap(rng):
    """Suffix attention through shared blocks under a sliding window
    PLUS logit softcap (gemma2-style layers): the window crosses the
    cached-prefix boundary, so windowed masking must be applied in
    ABSOLUTE positions inside the paged prefill kernel."""
    cfg = get_config("gemma2_27b").reduced()
    assert cfg.local_window and cfg.attn_softcap    # the shape under test
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    base = rng.randint(2, cfg.vocab_size, size=24)  # > window (16) tokens
    child = np.concatenate([base, rng.randint(2, cfg.vocab_size, size=7)])
    prompts, max_new = [base, child], [8, 8]
    toks, engines = _suffix_vs_full(model, params, prompts, max_new,
                                    slots=2, max_seq=64, num_blocks=24)
    assert len(toks[True]) == 2
    assert toks[True] == toks[False]
    for rid, pr in enumerate(prompts):
        ref = greedy_reference(model, params, pr, max_new[rid])
        assert toks[True][rid] == ref, (rid, toks[True][rid], ref)
    assert engines[True].prefill_tokens_saved == 24
    assert_engine_quiescent(engines[True])


def test_suffix_prefill_cow_exhaustion_resume(setup, rng):
    """The suffix path composes with the COW barrier under pool
    exhaustion AND a forced preemption round-trip: the forked child is
    swapped out mid-decode, resumed from the host tier, and still
    decodes token-identically to the greedy reference."""
    cfg, model, params = setup
    parent = rng.randint(2, cfg.vocab_size, size=20)
    prompts = [parent,
               rng.randint(2, cfg.vocab_size, size=14),
               parent[:12].copy()]                 # forked, suffix path
    max_new = [4, 4, 6]
    for flag in (True, False):
        eng = Engine(model, params, slots=4, max_seq=32, num_blocks=10,
                     eos_id=-1, prefill_budget=None, suffix_prefill=flag)
        reqs = [Request(rid=i, prompt=p.copy(), max_new=m)
                for i, (p, m) in enumerate(zip(prompts, max_new))]
        for r in reqs:
            eng.submit(r)
        forced = False
        while (eng.sched.has_work or eng.running) and eng.steps < 300:
            eng.step()
            eng.check_consistency()
            if eng.steps == 2 and eng.running and not forced:
                eng.preempt_latest()       # evict; resume via swap-in
                forced = True
        eng.sync_transfers()
        assert forced and len(eng.done) == 3
        if flag:
            assert eng.prefix_hits >= 1
            assert eng.prefill_tokens_saved > 0
        for req in sorted(eng.done, key=lambda r: r.rid):
            ref = greedy_reference(model, params, req.prompt, req.max_new,
                                   max_seq=32)
            assert req.generated == ref, (flag, req.rid, req.generated, ref)
        assert_engine_quiescent(eng)
