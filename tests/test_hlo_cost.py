"""The trip-count-aware HLO cost model vs analytically known programs."""

import numpy as np
import jax
import jax.numpy as jnp

from repro import hlo_cost


def _cost(fn, *specs):
    comp = jax.jit(fn).lower(*specs).compile()
    return hlo_cost.analyze_text(comp.as_text()), comp


def test_scan_matmul_flops_exact():
    L, B, D = 7, 32, 64

    def f(ws, x):
        def body(x, w):
            return x @ w, None
        x, _ = jax.lax.scan(body, x, ws)
        return x.sum()

    cost, comp = _cost(f, jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                       jax.ShapeDtypeStruct((B, D), jnp.float32))
    expected = L * 2 * B * D * D
    assert abs(cost.flops - expected) / expected < 0.01
    # XLA's own counter misses the trip count (documents the motivation);
    # xla_cost_analysis normalizes the list-vs-dict return across versions
    xla = hlo_cost.xla_cost_analysis(comp)
    assert xla["flops"] < 0.5 * expected


def test_grad_flops_3x():
    L, B, D = 5, 16, 32

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return (x ** 2).sum()

    fwd, _ = _cost(f, jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, D), jnp.float32))
    bwd, _ = _cost(jax.grad(f, argnums=0),
                   jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, D), jnp.float32))
    ratio = bwd.flops / fwd.flops
    assert 2.5 < ratio < 3.5, ratio


def test_nested_scan_trip_multiplication():
    Lo, Li, D = 4, 6, 16

    def f(w, x):
        def outer(x, _):
            def inner(x, __):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, None, length=Li)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=Lo)
        return x.sum()

    cost, _ = _cost(f, jax.ShapeDtypeStruct((D, D), jnp.float32),
                    jax.ShapeDtypeStruct((8, D), jnp.float32))
    expected = Lo * Li * 2 * 8 * D * D
    assert abs(cost.flops - expected) / expected < 0.01


def test_collective_bytes_detected():
    from jax.sharding import NamedSharding, PartitionSpec as P
    if jax.device_count() < 2:
        import pytest
        pytest.skip("needs >1 device")


def test_dynamic_update_slice_not_overcounted():
    N = 1 << 20

    def f(big, small):
        def body(b, i):
            return jax.lax.dynamic_update_index_in_dim(
                b, small, i * 16, 0), None
        b, _ = jax.lax.scan(body, big, jnp.arange(8))
        return b.sum()

    cost, _ = _cost(f, jax.ShapeDtypeStruct((N,), jnp.float32),
                    jax.ShapeDtypeStruct((16,), jnp.float32))
    # traffic must be ~N (the final sum), not 8 * N from the DUS loop
    assert cost.bytes < 6 * N * 4, cost.bytes


def test_shape_bytes_tuple():
    s = "(s32[], f32[4,8]{1,0}, bf16[2,2]{1,0})"
    assert hlo_cost.shape_bytes(s) == 4 + 128 + 8
