"""The unified software address space (repro.mem): Arena/Lease/Mapping.

Three layers of pins:

  * the grep-enforced API rule: NOTHING outside ``src/repro/mem``
    constructs ``BlockAllocator``/``BlockPool`` directly -- every client
    allocates through one shared ``Arena``;
  * unit semantics: typed leases (exclusive/COW-shared/pinned), mapping
    verbs (``fork`` / ``ensure_writable`` / ``migrate``), pressure-time
    reclaim (the LIFO-preemption fallback as Arena policy), compaction
    lease rewrite, and the leak invariant ``assert_quiescent``;
  * regressions: ``OutOfBlocksError`` mid fork+extend must not leak or
    corrupt, exhaustion without a reclaimer must leave state untouched.
"""

import re
from pathlib import Path

import numpy as np
import pytest

from repro.mem import (COW_SHARED, EXCLUSIVE, PINNED, Arena,
                       LeaseRevokedError, OutOfBlocksError)

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# the API rule, grep-enforced
# ---------------------------------------------------------------------------
def test_no_direct_allocator_construction_outside_mem():
    """Zero direct BlockAllocator/BlockPool construction outside
    src/repro/mem: the Arena is the only allocator factory."""
    pattern = re.compile(
        r"\b(?:BlockAllocator|BlockPool)\s*\(|\bBlockPool\.create\s*\(")
    mem_dir = REPO / "src" / "repro" / "mem"
    offenders = []
    for root in ("src/repro", "benchmarks", "examples"):
        for path in sorted((REPO / root).rglob("*.py")):
            if mem_dir in path.parents:
                continue
            for i, line in enumerate(path.read_text().splitlines(), 1):
                if pattern.search(line):
                    offenders.append(
                        f"{path.relative_to(REPO)}:{i}: {line.strip()}")
    assert not offenders, (
        "direct BlockAllocator/BlockPool construction outside repro.mem "
        "(allocate through a shared Arena instead):\n" + "\n".join(offenders))


# ---------------------------------------------------------------------------
# leases
# ---------------------------------------------------------------------------
def _arena(n=8, cls="kv"):
    a = Arena()
    a.register_class(cls, num_blocks=n, block_nbytes=64)
    return a


def test_register_class_idempotent_and_loud_on_conflict():
    a = _arena()
    assert a.register_class("kv", num_blocks=8, block_nbytes=64) == "kv"
    with pytest.raises(ValueError):
        a.register_class("kv", num_blocks=16, block_nbytes=64)
    with pytest.raises(KeyError):
        a.num_free("unregistered")


def test_lease_kinds_and_refcounts():
    a = _arena()
    [lease] = a.lease_blocks("kv", owner=0)
    assert lease.kind == EXCLUSIVE and not lease.shared
    alias = lease.share(owner=1)
    assert lease.kind == COW_SHARED == alias.kind
    assert a.refcount("kv", lease.block) == 2
    alias.release()
    assert lease.kind == EXCLUSIVE
    lease.release()
    with pytest.raises(ValueError):
        lease.release()                     # double release is loud
    a.assert_quiescent()


def test_pinned_lease_survives_quiescence():
    a = _arena()
    sink = a.pin("kv", owner="sink")
    assert sink.kind == PINNED
    with pytest.raises(ValueError):
        sink.share(owner=1)                 # pinned blocks never alias
    a.assert_quiescent()                    # pinned is not a leak
    a.unpin(sink)
    assert a.num_used("kv") == 0


# ---------------------------------------------------------------------------
# mapping verbs
# ---------------------------------------------------------------------------
def test_mapping_fork_and_write_barrier():
    a = _arena()
    parent = a.mapping("kv", owner=0)
    parent.ensure_capacity(3)
    used = a.num_used("kv")
    child = parent.fork(owner=1, nblocks=2)     # pure refcount traffic
    assert a.num_used("kv") == used
    assert child.block_ids() == parent.block_ids()[:2]
    assert parent.locality() == 1.0             # fresh allocs are adjacent

    plan = child.ensure_writable(1)             # divergent write -> copy
    src, dst = plan
    assert src == parent.block_ids()[1] and dst == child.block_ids()[1]
    assert dst not in parent.block_ids()
    assert child.ensure_writable(1) is None     # now exclusive
    child.free()
    parent.free()
    a.assert_quiescent()


def test_mapping_migrate_roundtrip_relocates():
    a = _arena()
    m = a.mapping("kv", owner=7)
    m.ensure_capacity(3)
    # stranger occupies the vacated ids so re-materialization relocates
    old = m.migrate("host")
    assert m.placement == "host" and len(m) == 3
    assert a.host_counts("kv") == {7: 3}
    stranger = a.mapping("kv", owner=8)
    stranger.ensure_capacity(2)
    new = m.migrate("device")
    assert m.placement == "device" and len(new) == 3
    assert set(new) & set(stranger.block_ids()) == set()
    assert new != old                           # tables absorb relocation
    m.free()
    stranger.free()
    a.assert_quiescent()


def test_fork_oob_during_extend_regression():
    """OutOfBlocksError between fork() and the child's extension must
    leave the address space consistent: the child holds only its shared
    prefix, the parent is untouched, and releasing both drains to zero."""
    a = _arena(n=4)
    parent = a.mapping("kv", owner=0)
    parent.ensure_capacity(3)                   # 3 of 4 used
    filler = a.mapping("kv", owner=9)
    filler.ensure_capacity(1)                   # pool now full
    child = parent.fork(owner=1, nblocks=3)     # shares: needs no blocks
    with pytest.raises(OutOfBlocksError):
        child.ensure_capacity(4)                # +1 block: exhausted
    # nothing leaked, nothing corrupted
    assert child.block_ids() == parent.block_ids()
    assert all(a.refcount("kv", b) == 2 for b in parent.block_ids())
    with pytest.raises(OutOfBlocksError):
        child.ensure_writable(0)                # COW target also exhausted
    assert child.block_ids() == parent.block_ids()   # barrier rolled back
    child.free()
    assert all(a.refcount("kv", b) == 1 for b in parent.block_ids())
    parent.free()
    filler.free()
    a.assert_quiescent()


# ---------------------------------------------------------------------------
# pressure protocol: the LIFO-preemption fallback as Arena policy
# ---------------------------------------------------------------------------
def test_pressure_reclaims_victims_until_fit():
    a = _arena(n=4)
    victim = a.mapping("kv", owner="victim")
    victim.ensure_capacity(3)
    reclaimed = []

    def reclaimer(requester):
        reclaimed.append(requester)
        victim.migrate("host")                  # frees 3 blocks
        return "victim"

    a.set_reclaimer(reclaimer)
    m = a.mapping("kv", owner="req")
    m.ensure_capacity(3)                        # 3 > 1 free -> reclaim
    assert reclaimed == ["req"]
    assert a.host_counts("kv") == {"victim": 3}
    victim.free()
    m.free()
    a.assert_quiescent()


def test_pressure_self_reclaim_raises_lease_revoked():
    a = _arena(n=2)
    m = a.mapping("kv", owner="self")
    m.ensure_capacity(2)

    def reclaimer(requester):
        m.migrate("host")                       # the requester itself
        return "self"

    a.set_reclaimer(reclaimer)
    with pytest.raises(LeaseRevokedError):
        m.ensure_capacity(3)
    assert m.placement == "host"                # already swapped out
    # LeaseRevokedError IS an OutOfBlocksError for legacy catch sites
    assert issubclass(LeaseRevokedError, OutOfBlocksError)
    m.free()
    a.assert_quiescent()


def test_no_reclaimer_means_plain_oob():
    a = _arena(n=2)
    m = a.mapping("kv", owner=0)
    with pytest.raises(OutOfBlocksError):
        m.ensure_capacity(3)
    assert len(m) == 0 and a.num_used("kv") == 0    # atomic failure
    m.free()
    a.assert_quiescent()


# ---------------------------------------------------------------------------
# compaction: the ROADMAP defrag pass
# ---------------------------------------------------------------------------
def test_compact_rewrites_leases_to_dense_prefix():
    a = _arena(n=16)
    keep = a.mapping("kv", owner="keep")
    keep.ensure_capacity(2)
    hole = a.mapping("kv", owner="hole")
    hole.ensure_capacity(4)
    tail = a.mapping("kv", owner="tail")
    tail.ensure_capacity(3)
    shared = tail.fork(owner="alias", nblocks=2)
    hole.free()                                 # 4-block hole mid-pool
    assert a.fragmentation("kv") > 0
    assert a.should_compact("kv", min_free_frac=0.25, frag_threshold=0.1)

    before = {"keep": keep.block_ids(), "tail": tail.block_ids()}
    src, dst = a.compact("kv")
    assert len(src) > 0 and set(src).isdisjoint(set(dst))
    assert a.fragmentation("kv") == 0.0
    # live blocks now form the dense prefix
    used = a.allocator("kv").used_ids()
    assert list(used) == list(range(len(used)))
    # every mapping rewritten in place; aliasing preserved
    assert tail.block_ids()[:2] == shared.block_ids()
    remap = dict(zip(src.tolist(), dst.tolist()))
    for name, m in (("keep", keep), ("tail", tail)):
        assert m.block_ids() == [remap.get(b, b) for b in before[name]]
    assert a.compactions == 1 and a.blocks_compacted == len(src)
    for m in (shared, tail, keep):
        m.free()
    a.assert_quiescent()


def test_compact_refuses_untracked_blocks():
    a = _arena(n=8)
    m = a.mapping("kv", owner=0)
    m.ensure_capacity(1)
    m2 = a.mapping("kv", owner=1)
    m2.ensure_capacity(2)
    m.free()
    a.allocator("kv").alloc()                   # raw escape hatch
    with pytest.raises(RuntimeError):
        a.compact("kv")


# ---------------------------------------------------------------------------
# stats surface
# ---------------------------------------------------------------------------
def test_arena_stats_surface():
    a = _arena(n=8)
    a.register_class("meta", num_blocks=4, block_nbytes=8)
    sink = a.pin("kv", owner="sink")
    m = a.mapping("kv", owner=3)
    m.ensure_capacity(2)
    child = m.fork(owner=4, nblocks=1)
    swapped = a.mapping("kv", owner=5)
    swapped.ensure_capacity(2)
    swapped.migrate("host")

    st = a.stats()
    kv = st["kv"]
    assert kv.num_blocks == 8 and kv.num_used == 3 and kv.pinned == 1
    assert kv.blocks_by_owner == {"sink": 1, "3": 2, "4": 1}
    assert kv.host_blocks_by_owner == {"5": 2} and kv.host_blocks == 2
    # refcount histogram: 5 free, 2 at refcount 1 (sink + private), 1
    # shared at refcount 2
    assert kv.refcount_histogram[0] == 5
    assert kv.refcount_histogram[1] == 2
    assert kv.refcount_histogram[2] == 1
    assert kv.mappings_by_kind == {"flat": 3}
    assert st["meta"].num_used == 0
    d = st.to_dict()
    assert d["classes"]["kv"]["num_used"] == 3
    for obj in (child, m, swapped):
        obj.free()
    a.unpin(sink)
    a.assert_quiescent()


def test_assert_quiescent_catches_leaks():
    a = _arena()
    m = a.mapping("kv", owner=0)
    m.ensure_capacity(1)
    with pytest.raises(AssertionError):
        a.assert_quiescent()
    m.migrate("host")
    with pytest.raises(AssertionError):
        a.assert_quiescent()                    # host tier counts too
    m.free()
    a.assert_quiescent()
