"""PagedKVCache semantics: append/prefill/gather, manager policies, swap."""

import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.blockpool import OutOfBlocksError
from repro.core.paged_kv import PagedKVCache, PagedKVConfig, PagedKVManager


def make(B=3, S=32, layers=2, kvh=2, hd=4, bt=8, arena=None):
    cfg = PagedKVConfig(num_layers=layers, kv_heads=kvh, head_dim=hd,
                        block_tokens=bt, num_blocks=B * S // bt + 4,
                        max_blocks_per_seq=S // bt, dtype=jnp.float32)
    cache = PagedKVCache.create(cfg, B)
    mgr = PagedKVManager(cfg, arena=arena)
    tables = []
    for sid in range(B):
        mgr.admit(sid, S)
        tables.append(mgr.device_table(sid))
    cache = dataclasses.replace(cache,
                                block_tables=jnp.asarray(np.stack(tables)))
    return cfg, cache, mgr


def test_append_then_gather_equals_dense(rng):
    cfg, cache, _ = make()
    L, B, T = cfg.num_layers, 3, 20
    ks = rng.randn(T, L, B, cfg.kv_heads, cfg.head_dim).astype(np.float32)
    vs = rng.randn(T, L, B, cfg.kv_heads, cfg.head_dim).astype(np.float32)
    for t in range(T):
        cache = cache.append_token(jnp.asarray(ks[t]), jnp.asarray(vs[t]))
    for l in range(L):
        k, v = cache.gather_layer(cache.k_pool[l], cache.v_pool[l])
        np.testing.assert_allclose(np.asarray(k)[:, :T],
                                   ks[:, l].transpose(1, 0, 2, 3), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(v)[:, :T],
                                   vs[:, l].transpose(1, 0, 2, 3), rtol=1e-6)


def test_prefill_equals_appends(rng):
    cfg, cache1, _ = make()
    _, cache2, _ = make()
    L, B, T = cfg.num_layers, 3, 16   # block aligned
    k = rng.randn(L, B, T, cfg.kv_heads, cfg.head_dim).astype(np.float32)
    v = rng.randn(L, B, T, cfg.kv_heads, cfg.head_dim).astype(np.float32)
    cache1 = cache1.write_prefill(jnp.asarray(k), jnp.asarray(v),
                                  jnp.full((B,), T, jnp.int32))
    for t in range(T):
        cache2 = cache2.append_token(jnp.asarray(k[:, :, t]),
                                     jnp.asarray(v[:, :, t]))
    np.testing.assert_allclose(np.asarray(cache1.k_pool),
                               np.asarray(cache2.k_pool), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(cache1.seq_lens),
                                  np.asarray(cache2.seq_lens))


def test_manager_admission_by_blocks():
    cfg = PagedKVConfig(num_layers=1, kv_heads=1, head_dim=4,
                        block_tokens=8, num_blocks=4, max_blocks_per_seq=4)
    mgr = PagedKVManager(cfg)
    assert mgr.can_admit(32)           # exactly 4 blocks
    mgr.admit(0, 24)                   # 3 blocks
    assert mgr.can_admit(8) and not mgr.can_admit(16)
    with pytest.raises(OutOfBlocksError):
        mgr.admit(1, 17)               # needs 3 blocks, 1 free
    mgr.release(0)
    assert mgr.can_admit(32)


def test_swap_out_in_relocates(rng):
    """Swap-in may land on different physical blocks; tables absorb it.

    The payload rides the Arena's transfer plane (migrate enqueues the
    d2h/h2d plans, the registered executor moves ONLY the sequence's
    blocks -- never the whole pool); the serve-layer store is the byte
    ledger over completed plans."""
    from repro.mem import Arena
    from repro.serve.swap import HostBlockStore
    arena = Arena()
    cfg, cache, mgr = make(B=2, S=16, arena=arena)
    k_np = rng.randn(*cache.k_pool.shape).astype(np.float32)
    cell = {"cache": dataclasses.replace(cache, k_pool=jnp.asarray(k_np))}
    arena.transfers.register_executor(
        mgr.pool_class,
        lambda: [cell["cache"].k_pool, cell["cache"].v_pool],
        lambda s: cell.update(cache=dataclasses.replace(
            cell["cache"], k_pool=s[0], v_pool=s[1])))
    store = HostBlockStore(arena, mgr.pool_class)
    blocks_before = list(mgr.tables[0])
    mgr.swap_out(0)
    arena.transfers.drain()
    assert 0 not in mgr.tables and mgr.swapped[0] == len(blocks_before)
    # occupy some freed blocks so swap-in must relocate
    mgr.admit(99, 8)
    new_ids = mgr.swap_in(0)
    assert new_ids != blocks_before
    arena.transfers.drain()
    np.testing.assert_array_equal(
        np.asarray(cell["cache"].k_pool)[:, np.asarray(new_ids)],
        k_np[:, np.asarray(blocks_before)])
    # transfer cost: blocks held, never pool size
    assert store.stats.swap_out_bytes == \
        len(blocks_before) * cfg.swap_nbytes_per_block()
    assert store.stats.swap_in_bytes == store.stats.swap_out_bytes


def test_cow_fork_shares_blocks():
    cfg, cache, mgr = make(B=2, S=32)
    used_before = mgr.allocator.num_used
    mgr.fork(0, 7, shared_tokens=16)   # 2 full blocks shared
    assert mgr.allocator.num_used == used_before  # no new blocks
    assert mgr.tables[7] == mgr.tables[0][:2]
    mgr.release(7)                      # refcount drop, parent intact
    assert all(mgr.allocator.is_allocated(b) for b in mgr.tables[0])


def test_cow_fork_shared_tail_write_barrier():
    """fork() aliases a partially-filled tail block; the first write into
    it (either party) triggers fork_for_write via ensure_writable."""
    cfg, cache, mgr = make(B=2, S=32)          # bt=8
    parent = list(mgr.tables[0])
    mgr.fork(0, 7, shared_tokens=12)           # block 1 only partially full
    assert mgr.tables[7] == parent[:2]
    assert mgr.allocator.refcount(parent[1]) == 2
    # write at pos 12 (inside shared tail) -> private copy for the child
    plan = mgr.ensure_writable(7, token_pos=12)
    assert plan is not None
    src, dst = plan
    assert src == parent[1] and dst != src
    assert mgr.tables[7][1] == dst and mgr.tables[0][1] == src
    assert mgr.allocator.refcount(src) == 1
    assert mgr.allocator.refcount(dst) == 1
    # parent now owns its tail exclusively: no further copy
    assert mgr.ensure_writable(0, token_pos=12) is None


def test_dp_grouped_semantics(rng):
    """dp_groups>1 with group-local ids == dp_groups=1 with global ids."""
    from repro.models.attention import _grouped_gather
    B, MB, NB, BT, K, H = 4, 2, 8, 4, 2, 3
    pool = jnp.asarray(rng.randn(NB, BT, K, H).astype(np.float32))
    # group-local tables: groups of 2 sequences, each group owns NB/2=4
    tbl_local = jnp.asarray(rng.randint(0, 4, (B, MB)).astype(np.int32))
    tbl_global = np.asarray(tbl_local).copy()
    tbl_global[2:] += 4
    out_dp = _grouped_gather(pool, tbl_local, 2)
    out_ref = pool[jnp.asarray(tbl_global)]
    np.testing.assert_array_equal(np.asarray(out_dp), np.asarray(out_ref))
