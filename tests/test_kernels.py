"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops


@pytest.mark.parametrize("nblocks,leaf", [(4, 128), (16, 256), (7, 512)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_tree_gather_sweep(nblocks, leaf, dtype, rng):
    leaves = jnp.asarray(rng.randn(nblocks, leaf).astype(dtype))
    table = jnp.asarray(rng.permutation(nblocks).astype(np.int32))
    out = ops.tree_gather(leaves, table, interpret=True)
    ref = ops.tree_gather_ref(leaves, table)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(ref, np.float32))


@pytest.mark.parametrize("nblocks,leaf", [(4, 128), (9, 1024)])
def test_tree_block_sum_sweep(nblocks, leaf, rng):
    leaves = jnp.asarray(rng.randn(nblocks, leaf).astype(np.float32))
    table = jnp.asarray(rng.permutation(nblocks)[: nblocks - 1].astype(np.int32))
    out = ops.tree_block_sum(leaves, table, interpret=True)
    ref = ops.tree_block_sum_ref(leaves, table)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


@pytest.mark.parametrize("rows_per_block,width,n", [(8, 128, 17), (16, 64, 64)])
def test_tree_gather_rows_sweep(rows_per_block, width, n, rng):
    nb = 6
    pool = jnp.asarray(rng.randn(nb, rows_per_block, width).astype(np.float32))
    ltab = jnp.asarray(rng.permutation(nb).astype(np.int32))
    rows = jnp.asarray(rng.randint(0, nb * rows_per_block, n).astype(np.int32))
    out = ops.tree_gather_rows(pool, rows, ltab, rows_per_block,
                               interpret=True)
    ref = ops.tree_gather_rows_ref(pool, rows, ltab, rows_per_block)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("B,KVH,G,HD,BT,MB", [
    (2, 1, 8, 64, 16, 4),      # MQA
    (3, 2, 4, 128, 32, 3),     # GQA
    (1, 4, 1, 64, 8, 8),       # MHA-ish
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_paged_attention_sweep(B, KVH, G, HD, BT, MB, dtype, rng):
    NB = B * MB + 2
    q = jnp.asarray(rng.randn(B, KVH, G, HD).astype(dtype))
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(dtype))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(dtype))
    tables = jnp.asarray(rng.permutation(NB)[: B * MB].reshape(B, MB)
                         .astype(np.int32))
    lens = jnp.asarray(rng.randint(1, MB * BT + 1, B).astype(np.int32))
    out = ops.paged_attention(q, k_pool, v_pool, tables, lens,
                              interpret=True)
    ref = ops.paged_attention_ref(q, k_pool, v_pool, tables, lens)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("softcap,window", [(None, None), (30.0, None),
                                            (None, 40), (50.0, 24)])
def test_paged_attention_softcap_window(softcap, window, rng):
    B, KVH, G, HD, BT, MB = 2, 2, 2, 64, 16, 5
    NB = B * MB
    q = jnp.asarray(rng.randn(B, KVH, G, HD).astype(np.float32))
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    tables = jnp.asarray(np.arange(NB).reshape(B, MB).astype(np.int32))
    lens = jnp.asarray(np.array([61, 33], np.int32))
    out = ops.paged_attention(q, k_pool, v_pool, tables, lens,
                              softcap=softcap, window=window, interpret=True)
    ref = ops.paged_attention_ref(q, k_pool, v_pool, tables, lens,
                                  softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_paged_attention_mla_latent(rng):
    """Absorbed-MLA mode: values are the first v_dim lanes of the latent."""
    B, H, LAT, VD, BT, MB = 2, 8, 96, 64, 16, 4
    NB = B * MB
    q = jnp.asarray(rng.randn(B, 1, H, LAT).astype(np.float32))
    c_pool = jnp.asarray(rng.randn(NB, BT, 1, LAT).astype(np.float32))
    tables = jnp.asarray(rng.permutation(NB).reshape(B, MB).astype(np.int32))
    lens = jnp.asarray(np.array([50, 17], np.int32))
    out = ops.paged_attention(q, c_pool, c_pool, tables, lens, v_dim=VD,
                              interpret=True)
    ref = ops.paged_attention_ref(q, c_pool, c_pool, tables, lens, v_dim=VD)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_paged_attention_matches_model_decode_path(rng):
    """Kernel contract == the model's reference decode attention
    (_paged_ref + self-token merge)."""
    from repro.models.attention import _merge_self, _paged_ref
    B, KVH, G, HD, BT, MB = 2, 2, 3, 32, 8, 4
    NB = B * MB
    q = jnp.asarray(rng.randn(B, KVH, G, HD).astype(np.float32))
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    tables = jnp.asarray(np.arange(NB).reshape(B, MB).astype(np.int32))
    lens = jnp.asarray(np.array([20, 9], np.int32))
    out = ops.paged_attention(q, k_pool, v_pool, tables, lens,
                              interpret=True)
    o, l, m = _paged_ref(q, k_pool, v_pool, tables, lens, scale=HD ** -0.5,
                         softcap=None, window=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("B,SQ,KVH,G,HD,BT,MB,QC", [
    (2, 16, 1, 8, 64, 16, 4, 8),    # MQA, chunked queries
    (3, 8, 2, 4, 128, 8, 3, 8),     # GQA, single chunk
    (1, 32, 4, 1, 64, 8, 8, 4),     # MHA-ish, deep sweep
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_paged_prefill_sweep(B, SQ, KVH, G, HD, BT, MB, QC, dtype, rng):
    NB = B * MB + 2
    q = jnp.asarray(rng.randn(B, SQ, KVH, G, HD).astype(dtype))
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(dtype))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(dtype))
    tables = jnp.asarray(rng.permutation(NB)[: B * MB].reshape(B, MB)
                         .astype(np.int32))
    starts = jnp.asarray(rng.randint(0, MB * BT - SQ + 1, B).astype(np.int32))
    lens = starts + jnp.asarray(rng.randint(1, SQ + 1, B).astype(np.int32))
    out = ops.paged_prefill_attention(q, k_pool, v_pool, tables, lens,
                                      starts, q_chunk=QC, interpret=True)
    ref = ops.paged_prefill_attention_ref(q, k_pool, v_pool, tables, lens,
                                          starts)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("softcap,window", [(None, None), (30.0, None),
                                            (None, 12), (50.0, 7)])
def test_paged_prefill_softcap_window(softcap, window, rng):
    B, SQ, KVH, G, HD, BT, MB = 2, 16, 2, 2, 64, 8, 5
    NB = B * MB
    q = jnp.asarray(rng.randn(B, SQ, KVH, G, HD).astype(np.float32))
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    tables = jnp.asarray(np.arange(NB).reshape(B, MB).astype(np.int32))
    starts = jnp.asarray(np.array([17, 0], np.int32))
    lens = jnp.asarray(np.array([17 + 16, 9], np.int32))
    out = ops.paged_prefill_attention(q, k_pool, v_pool, tables, lens,
                                      starts, softcap=softcap, window=window,
                                      q_chunk=8, interpret=True)
    ref = ops.paged_prefill_attention_ref(q, k_pool, v_pool, tables, lens,
                                          starts, softcap=softcap,
                                          window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_paged_prefill_last_token_matches_decode_kernel(rng):
    """A 1-token suffix at position len-1 is exactly a decode step: the
    prefill kernel must agree with the decode kernel on it."""
    B, KVH, G, HD, BT, MB = 2, 2, 4, 64, 8, 4
    NB = B * MB
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    tables = jnp.asarray(rng.permutation(NB).reshape(B, MB).astype(np.int32))
    lens = jnp.asarray(np.array([29, 13], np.int32))
    q = jnp.asarray(rng.randn(B, 1, KVH, G, HD).astype(np.float32))
    out = ops.paged_prefill_attention(q, k_pool, v_pool, tables, lens,
                                      lens - 1, interpret=True)
    dec = ops.paged_attention(q[:, 0], k_pool, v_pool, tables, lens,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(dec),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,KVH,G,HD,BT,MB", [
    (2, 1, 8, 64, 16, 4),      # MQA
    (3, 2, 4, 128, 32, 3),     # GQA
    (1, 4, 1, 64, 8, 8),       # MHA-ish
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_paged_attention_append_sweep(B, KVH, G, HD, BT, MB, dtype, rng):
    """Fused append-then-attend: the kernel writes the new token's K/V
    rows into the tail block (aliased in place) and attends over
    ``lens + 1`` in the same pass.  Pools must match the oracle's
    exactly -- the splice is a dtype-roundtrip write, every other row
    of the tail block is read and written back unchanged."""
    NB = B * MB + 2
    q = jnp.asarray(rng.randn(B, KVH, G, HD).astype(dtype))
    k_new = jnp.asarray(rng.randn(B, KVH, HD).astype(dtype))
    v_new = jnp.asarray(rng.randn(B, KVH, HD).astype(dtype))
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(dtype))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(dtype))
    # distinct blocks across rows: live tails are exclusively owned
    # (the engine's COW barrier guarantees this before every decode)
    tables = jnp.asarray(rng.permutation(NB)[: B * MB].reshape(B, MB)
                         .astype(np.int32))
    lens = jnp.asarray(rng.randint(0, MB * BT, B).astype(np.int32))
    # oracle first: the jitted fused step DONATES the pools
    ref_o, ref_k, ref_v = ops.paged_attention_append_ref(
        q, k_new, v_new, k_pool, v_pool, tables, lens)
    out, k_out, v_out = ops.paged_attention_append(
        q, k_new, v_new, k_pool, v_pool, tables, lens, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_o, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_array_equal(np.asarray(k_out, np.float32),
                                  np.asarray(ref_k, np.float32))
    np.testing.assert_array_equal(np.asarray(v_out, np.float32),
                                  np.asarray(ref_v, np.float32))


@pytest.mark.parametrize("softcap,window", [(None, None), (30.0, None),
                                            (None, 40), (50.0, 24)])
def test_paged_attention_append_softcap_window(softcap, window, rng):
    B, KVH, G, HD, BT, MB = 2, 2, 2, 64, 16, 5
    NB = B * MB
    q = jnp.asarray(rng.randn(B, KVH, G, HD).astype(np.float32))
    k_new = jnp.asarray(rng.randn(B, KVH, HD).astype(np.float32))
    v_new = jnp.asarray(rng.randn(B, KVH, HD).astype(np.float32))
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    tables = jnp.asarray(np.arange(NB).reshape(B, MB).astype(np.int32))
    lens = jnp.asarray(np.array([61, 33], np.int32))
    ref_o, ref_k, ref_v = ops.paged_attention_append_ref(
        q, k_new, v_new, k_pool, v_pool, tables, lens,
        softcap=softcap, window=window)
    out, k_out, v_out = ops.paged_attention_append(
        q, k_new, v_new, k_pool, v_pool, tables, lens,
        softcap=softcap, window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(k_out), np.asarray(ref_k))
    np.testing.assert_array_equal(np.asarray(v_out), np.asarray(ref_v))


def test_paged_attention_append_edges(rng):
    """lens == 0 writes position 0 of the first block; lens == MB * BT
    (full table) drops the write and attends the whole table -- both
    must match the oracle's ``mode=\"drop\"`` discipline."""
    B, KVH, G, HD, BT, MB = 2, 2, 2, 64, 8, 3
    NB = B * MB
    q = jnp.asarray(rng.randn(B, KVH, G, HD).astype(np.float32))
    k_new = jnp.asarray(rng.randn(B, KVH, HD).astype(np.float32))
    v_new = jnp.asarray(rng.randn(B, KVH, HD).astype(np.float32))
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    tables = jnp.asarray(rng.permutation(NB).reshape(B, MB).astype(np.int32))
    lens = jnp.asarray(np.array([0, MB * BT], np.int32))
    k_before = np.asarray(k_pool).copy()
    ref_o, ref_k, ref_v = ops.paged_attention_append_ref(
        q, k_new, v_new, k_pool, v_pool, tables, lens)
    out, k_out, v_out = ops.paged_attention_append(
        q, k_new, v_new, k_pool, v_pool, tables, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(k_out), np.asarray(ref_k))
    np.testing.assert_array_equal(np.asarray(v_out), np.asarray(ref_v))
    # row 0: the new K row really landed at block tables[0, 0], offset 0
    np.testing.assert_allclose(
        np.asarray(k_out)[int(tables[0, 0]), 0],
        np.asarray(k_new)[0], rtol=0, atol=0)
    # row 1 (full): pools untouched anywhere row 1's table points
    for j in range(MB):
        np.testing.assert_array_equal(
            np.asarray(k_out)[int(tables[1, j])],
            k_before[int(tables[1, j])])


def test_paged_attention_append_matches_write_then_attend(rng):
    """The fused step == scatter the rows yourself, then run the plain
    decode kernel over ``lens + 1`` (the eager path's two dispatches)."""
    B, KVH, G, HD, BT, MB = 2, 2, 4, 64, 8, 4
    NB = B * MB
    q = jnp.asarray(rng.randn(B, KVH, G, HD).astype(np.float32))
    k_new = jnp.asarray(rng.randn(B, KVH, HD).astype(np.float32))
    v_new = jnp.asarray(rng.randn(B, KVH, HD).astype(np.float32))
    k_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    v_pool = jnp.asarray(rng.randn(NB, BT, KVH, HD).astype(np.float32))
    tables = jnp.asarray(rng.permutation(NB).reshape(B, MB).astype(np.int32))
    lens = jnp.asarray(np.array([19, 7], np.int32))
    jt = np.asarray(lens) // BT
    phys = np.asarray(tables)[np.arange(B), jt]
    off = np.asarray(lens) - jt * BT
    k_ref = np.asarray(k_pool).copy()
    v_ref = np.asarray(v_pool).copy()
    k_ref[phys, off] = np.asarray(k_new)
    v_ref[phys, off] = np.asarray(v_new)
    out, k_out, v_out = ops.paged_attention_append(
        q, k_new, v_new, k_pool, v_pool, tables, lens, interpret=True)
    dec = ops.paged_attention(q, jnp.asarray(k_ref), jnp.asarray(v_ref),
                              tables, lens + 1, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dec),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(k_out), k_ref)
    np.testing.assert_array_equal(np.asarray(v_out), v_ref)


@pytest.mark.parametrize("nb,blk", [(10, (4, 8)), (6, (16,)), (12, (2, 4, 8))])
def test_block_copy_plan(nb, blk, rng):
    """Device-side compaction/swap-in: apply a (src, dst) copy plan."""
    from repro.core.block_table import apply_compaction, compaction_plan
    from repro.kernels.block_copy import block_copy
    pool = jnp.asarray(rng.randn(nb, *blk).astype(np.float32))
    live = sorted(rng.permutation(nb)[: nb // 2].tolist())
    plan = compaction_plan(live)
    if not plan:
        return
    src = jnp.asarray(np.array([s for s, _ in plan], np.int32))
    dst = jnp.asarray(np.array([d for _, d in plan], np.int32))
    out = block_copy(pool, src, dst, interpret=True)
    ref = np.asarray(pool).copy()
    for s, d in plan:
        ref[d] = np.asarray(pool)[s]
    np.testing.assert_array_equal(np.asarray(out), ref)
    # tables rewritten to the dense prefix address the same contents
    tables = {0: list(live)}
    apply_compaction(tables, plan)
    for old, new in zip(live, tables[0]):
        np.testing.assert_array_equal(ref[new], np.asarray(pool)[old])


@pytest.mark.parametrize("layers,nb,blk", [(1, 8, (4, 2, 5)), (3, 10, (8,)),
                                           (2, 6, (4, 3))])
def test_gather_blocks_compact(layers, nb, blk, rng):
    """Swap-out gather: output is COMPACT (L, n, *blk) -- bytes scale
    with the id list, never the pool."""
    from repro.kernels.block_copy import gather_blocks
    pool = jnp.asarray(rng.randn(layers, nb, *blk).astype(np.float32))
    ids = rng.permutation(nb)[: nb // 2].astype(np.int32)
    out = gather_blocks(pool, jnp.asarray(ids), interpret=True)
    assert out.shape == (layers, len(ids), *blk)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(pool)[:, ids])


@pytest.mark.parametrize("layers,nb,blk", [(2, 8, (4, 2, 5)), (1, 6, (8,))])
def test_copy_pool_blocks_plan(layers, nb, blk, rng):
    """COW fulfilment: a (src, dst) plan applied across the layer axis."""
    from repro.kernels.block_copy import copy_pool_blocks
    pool = jnp.asarray(rng.randn(layers, nb, *blk).astype(np.float32))
    src = np.array([1, 4, 2], np.int32)
    dst = np.array([5, 0, 3], np.int32)
    out = copy_pool_blocks(pool, jnp.asarray(src), jnp.asarray(dst),
                           interpret=True)
    ref = np.asarray(pool).copy()
    ref[:, dst] = np.asarray(pool)[:, src]
    np.testing.assert_array_equal(np.asarray(out), ref)


@pytest.mark.parametrize("layers,nb,blk", [(2, 8, (4, 2, 5)), (1, 6, (8,)),
                                           (3, 5, (4, 3))])
def test_scatter_blocks_inverse_of_gather(layers, nb, blk, rng):
    """Swap-in scatter: payload[l, i] lands at pool[l, idx[i]], untouched
    blocks preserved; gathering the same ids returns the payload."""
    from repro.kernels.block_copy import gather_blocks, scatter_blocks
    pool = jnp.asarray(rng.randn(layers, nb, *blk).astype(np.float32))
    ids = np.array([3, 0, 2], np.int32)
    payload = jnp.asarray(rng.randn(layers, len(ids), *blk)
                          .astype(np.float32))
    out = scatter_blocks(pool, jnp.asarray(ids), payload, interpret=True)
    ref = np.asarray(pool).copy()
    ref[:, ids] = np.asarray(payload)
    np.testing.assert_array_equal(np.asarray(out), ref)
    back = gather_blocks(out, jnp.asarray(ids), interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(payload))
