"""Fault tolerance: a killed-and-resumed run reproduces the uninterrupted
run bitwise; straggler monitor; data pipeline resumability."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticLM
from repro.models.api import build_model
from repro.optim import adamw as OPT
from repro.train import checkpoint as CKPT
from repro.train.loop import StragglerMonitor, TrainLoopConfig, run


def _setup(tmp_path, total, ckpt_every):
    cfg = get_config("gemma_2b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    # schedule horizon fixed independently of how far this invocation
    # runs -- resuming must not change the LR schedule
    opt_cfg = OPT.AdamWConfig(lr_peak=1e-3, warmup_steps=2,
                              total_steps=8)
    opt_state = OPT.init_state(params)

    @jax.jit
    def step(p, o, b):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        (loss, mets), g = jax.value_and_grad(
            lambda pp: model.loss(pp, batch), has_aux=True)(p)
        p, o, om = OPT.apply_updates(opt_cfg, p, g, o)
        return p, o, {"loss": loss}

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=2, seed=3)
    loop_cfg = TrainLoopConfig(total_steps=total, ckpt_every=ckpt_every,
                               ckpt_dir=str(tmp_path), log_every=1000)
    return step, params, opt_state, data_cfg, loop_cfg


def test_resume_is_bitwise_identical(tmp_path):
    # uninterrupted run, 8 steps
    step, p0, o0, dcfg, lcfg = _setup(tmp_path / "a", 8, 4)
    full = run(step, p0, o0, dcfg, lcfg, log=lambda *_: None)

    # interrupted: run to step 4 (ckpt), then 'crash' and resume to 8
    step, p0, o0, dcfg, lcfg = _setup(tmp_path / "b", 4, 4)
    run(step, p0, o0, dcfg, lcfg, log=lambda *_: None)
    # resume with total 8 -- loop restores step 4 automatically
    step8, p0, o0, dcfg, lcfg8 = _setup(tmp_path / "b", 8, 4)
    resumed = run(step8, p0, o0, dcfg, lcfg8, log=lambda *_: None)

    for a, b in zip(jax.tree.leaves(full["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_nonfinite_loss_aborts_with_checkpoint(tmp_path):
    step, p0, o0, dcfg, lcfg = _setup(tmp_path, 8, 100)

    calls = {"n": 0}

    def bad_step(p, o, b):
        calls["n"] += 1
        p, o, m = step(p, o, b)
        if calls["n"] == 3:
            m = dict(m)
            m["loss"] = jnp.asarray(float("nan"))
        return p, o, m

    with pytest.raises(FloatingPointError):
        run(bad_step, p0, o0, dcfg, lcfg, log=lambda *_: None)
    # last good state checkpointed
    assert CKPT.latest_step(str(tmp_path)) == 2


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0, ewma=0.5)
    events = []
    mon.on_straggler = lambda s, dt, wm: events.append((s, dt))
    for s in range(10):
        mon.observe(s, 0.1)
    mon.observe(10, 1.0)      # 10x watermark
    assert mon.n_stragglers == 1 and events[0][0] == 10
    # watermark not poisoned by the straggler sample
    assert mon.watermark < 0.2
    mon.observe(11, 0.1)
    assert mon.n_stragglers == 1


def test_pipeline_step_addressable():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2, seed=5)
    src = SyntheticLM(cfg)
    b1 = src.batch_at(17)
    b2 = src.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(src.batch_at(18)["tokens"], b1["tokens"])


def test_prefetch_resume_matches_direct():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2, seed=5)
    src = SyntheticLM(cfg)
    it = PrefetchIterator(src, start_step=5)
    try:
        step, batch = next(it)
        assert step == 5
        np.testing.assert_array_equal(batch["tokens"],
                                      src.batch_at(5)["tokens"])
    finally:
        it.close()
