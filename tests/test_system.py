"""End-to-end behaviour: the full train loop (data pipeline -> sharded
step -> checkpoints) reduces loss on learnable synthetic data."""

import numpy as np
import pytest
import jax

from repro.launch.train import main as train_main


def test_train_loop_end_to_end(tmp_path):
    out = train_main([
        "--arch", "gemma_2b", "--reduced", "--steps", "30",
        "--batch", "4", "--seq", "32", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
    ])
    losses = out["losses"]
    # synthetic motifs are learnable: loss must drop substantially
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
    # checkpoints exist
    from repro.train import checkpoint as CKPT
    assert CKPT.latest_step(str(tmp_path)) == 30
