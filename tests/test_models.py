"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and finiteness.  (The FULL configs are only
exercised by the dry-run.)"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config
from repro.models.api import build_model, make_concrete_batch
from repro.optim import adamw as OPT

B, S = 2, 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, max_positions=S)
    params, axes = model.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, B, S)

    logits, aux, _ = model.forward(params, batch)
    toks = batch["tokens"].shape[1]
    exp_seq = toks + (cfg.num_image_tokens or 0)
    assert logits.shape == (B, exp_seq, cfg.vocab_size), arch
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch

    opt_cfg = OPT.AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    opt_state = OPT.init_state(params)

    def step(p, o, b):
        (loss, mets), grads = jax.value_and_grad(
            lambda pp: model.loss(pp, b), has_aux=True)(p)
        p, o, _ = OPT.apply_updates(opt_cfg, p, grads, o)
        return p, o, loss

    p2, o2, loss = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(loss)), arch
    # parameters actually moved
    moved = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_match_init(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg, max_positions=S)
    params, _ = model.init(jax.random.PRNGKey(0))
    shapes, axes = model.param_specs()
    real = jax.tree.map(lambda x: (x.shape, str(x.dtype)), params)
    spec = jax.tree.map(lambda x: (x.shape, str(x.dtype)), shapes)
    assert real == spec, arch
    # every param leaf has a logical-axes annotation of matching rank
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_ax = {tuple(str(k) for k in path): v for path, v in
               jax.tree_util.tree_leaves_with_path(
                   axes, is_leaf=lambda t: isinstance(t, tuple))}
    for path, leaf in flat_p:
        key = tuple(str(k) for k in path)
        assert key in flat_ax, (arch, key)
        assert len(flat_ax[key]) == leaf.ndim, (arch, key)


def test_loss_decreases_qwen_moe():
    """A few steps on a fixed batch must reduce loss (end-to-end sanity
    including router + grouped matmul gradients)."""
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = make_concrete_batch(cfg, 2, 16)
    opt_cfg = OPT.AdamWConfig(lr_peak=3e-3, warmup_steps=1, total_steps=30,
                              weight_decay=0.0)
    opt = OPT.init_state(params)

    @jax.jit
    def step(p, o):
        (loss, _), g = jax.value_and_grad(
            lambda pp: model.loss(pp, batch), has_aux=True)(p)
        p, o, _ = OPT.apply_updates(opt_cfg, p, g, o)
        return p, o, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses
